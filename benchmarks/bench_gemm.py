"""Paper Figure 3: DeepBench-sized GEMMs — ISAM-scheduled kernels vs the
hand-optimized kernel library ("KL").

The KL is modeled faithfully to Section 6.2.1: a library hand-tuned for its
*intended* sizes — perfect double-buffered overlap (time = max(compute,
memory)) on shapes that are multiples of its 128-tile blocking, but padding
odd shapes up to the next tile (wasted MACs — the paper's configuration (d)
effect).  ISAM's time is the static scheduler's modeled makespan on the same
system graph (real copy/compute overlap, no padding, but scheduling
overhead).

CSV: name, us_per_call = measured jnp.dot wall time (CPU), derived =
"isam=<s>/kl=<s>/ratio=<kl/isam>" in modeled seconds on the v5e graph.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions
from repro.core.scheduler import schedule
from repro.core.sysgraph import V5E_HBM_BW, V5E_PEAK_FLOPS, tpu_v5e

# (m, n, k) from DeepBench train/inference GEMM lists — a library-friendly
# head and an awkward tail (odd m / tiny n — RNN + attention shapes).  The
# canonical list lives with the autotuner so the tuned/gemm suites and the
# tune CLI always cover the same shapes.
from repro.search.tune import DEEPBENCH_GEMM_SIZES as SIZES

# The library's intended focus: large 512-aligned GEMMs (its hand-tuned
# blocking).  Odd / skinny shapes pay the full padding cost — the paper's
# "shapes which do not currently fit the algorithm used in the kernel
# library" (Figure 3 (d)).
TILE = 512


def kl_time(m: int, n: int, k: int) -> float:
    """Kernel-library model: pad to the library's blocking, then perfectly
    overlapped execution at peak."""
    mp = math.ceil(m / TILE) * TILE
    np_ = math.ceil(n / TILE) * TILE
    kp = math.ceil(k / TILE) * TILE
    flops = 2.0 * mp * np_ * kp
    nbytes = 4.0 * (m * k + k * n + m * n)
    return max(flops / V5E_PEAK_FLOPS, nbytes / V5E_HBM_BW)


def isam_time(m: int, n: int, k: int) -> float:
    prog = K.matmul(m, n, k)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    sched = schedule(sel, tpu_v5e(1))
    return sched.makespan


def run() -> list[tuple[str, float, str]]:
    rows = []
    for m, n, k in SIZES:
        a = jnp.zeros((m, k), jnp.float32)
        b = jnp.zeros((k, n), jnp.float32)
        f = jax.jit(jnp.dot)
        f(a, b).block_until_ready()
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        wall_us = (time.perf_counter() - t0) * 1e6

        t_isam = isam_time(m, n, k)
        t_kl = kl_time(m, n, k)
        ratio = t_kl / t_isam
        rows.append((f"gemm_{m}x{n}x{k}", wall_us,
                     f"isam={t_isam:.3e}s/kl={t_kl:.3e}s/"
                     f"kl_over_isam={ratio:.2f}"))
    return rows
