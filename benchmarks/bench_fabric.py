"""Fabric strong-scaling suite: distributed GEMM makespans vs chip count.

For each of two DeepBench GEMM shapes, reports the 1-chip modeled makespan
and then the best distributed makespan (over partition axis x collective
algorithm, default greedy per-chip tiles) on 2/4/8-chip ICI rings — the
``repro.fabric`` event-driven simulator is the measurement device.

CSV: name, us_per_call = modeled makespan (us), derived =
"speedup=<vs 1 chip>/axis=<m|n|k>/alg=<ring|bidir>/comm_end=<s>".
"""
from __future__ import annotations

from repro.fabric.collectives import ALGORITHMS
from repro.fabric.partition import partition, partition_axes
from repro.fabric.simulate import simulate_partition, single_chip_makespan
from repro.fabric.topology import Topology, ring
from repro.search.tune import FABRIC_GEMM_SIZES

CHIP_COUNTS = (2, 4, 8)


def run() -> list[tuple[str, float, str]]:
    rows = []
    chip_graph = Topology.chip_graph()
    for m, n, k in FABRIC_GEMM_SIZES:
        pp1 = partition("gemm", (m, n, k), "m", 1)
        one = single_chip_makespan(pp1, chip_graph)
        rows.append((f"fabric_gemm_{m}x{n}x{k}_x1", one * 1e6,
                     "1-chip reference (scheduler.cost_model)"))
        for chips in CHIP_COUNTS:
            topo = ring(chips)
            best = None
            for axis in partition_axes("gemm"):
                pp = partition("gemm", (m, n, k), axis, chips)
                for alg in ALGORITHMS:
                    res = simulate_partition(pp, topo, None, alg, chip_graph)
                    if best is None or res.makespan < best.makespan:
                        best = res
            rows.append((
                f"fabric_gemm_{m}x{n}x{k}_x{chips}", best.makespan * 1e6,
                f"speedup={one / best.makespan:.2f}x/axis={best.axis}"
                f"/alg={best.algorithm}/comm_end={best.comm_end:.3e}"))
    return rows
