"""Cross-backend roofline suite — the same ISAMIR programs compiled onto
every registered hardware target (the paper's hardware-agnosticity claim,
measured).

Each DeepBench GEMM shape and each conv->matmul extraction case is built
ONCE as an ISAMIR program + instruction selection, then costed per target
with that target's own ``SystemGraph`` (tpu_v5e vs the modeled gpu_sm
cluster machine): the tile sizes, staging budgets and bandwidths all come
from the graph, nothing in the program changes.  Rows report the modeled
makespan plus the fraction of the target's peak FLOP/s the mapping
sustains — comparable utilization numbers across backends, which is the
portability statement.

Rows carry the target name as a 4th element, so ``run.py`` keys the perf
baseline per (suite, name, target): a gpu row can never be silently
compared against a tpu baseline row.

CSV: name, us_per_call = greedy modeled time (us), derived =
"util=<frac of peak>/flops=<workload flops>/peak=<target flop/s>".
"""
from __future__ import annotations

from repro.compile import conv_selection, gemm_selection
from repro.core.sysgraph import resolve_target
from repro.search.evaluate import CostModelEvaluator
from repro.search.space import SearchSpace
from repro.search.tune import CONV_CASES, DEEPBENCH_GEMM_SIZES

#: targets the suite sweeps (every registered family with a modeled graph).
PORTABILITY_TARGETS = ("tpu_v5e", "gpu_sm")


def _cases():
    """(name, selection, workload flops) — built once, shared across
    targets."""
    cases = []
    for m, n, k in DEEPBENCH_GEMM_SIZES:
        _, sel = gemm_selection(m, n, k)
        cases.append((f"gemm_{m}x{n}x{k}", sel, 2.0 * m * n * k))
    for cname, kw in CONV_CASES:
        _, sel = conv_selection(**kw)
        flops = (2.0 * kw["batch"] * kw["h"] * kw["w"] * kw["cout"]
                 * kw["kh"] * kw["kw"] * kw["cin"])
        cases.append((f"{cname}_{kw['batch']}x{kw['h']}x{kw['w']}"
                      f"x{kw['cin']}x{kw['cout']}", sel, flops))
    return cases


def run() -> list[tuple[str, float, str, str]]:
    rows = []
    cases = _cases()
    for target in PORTABILITY_TARGETS:
        graph = resolve_target(target)
        peak = sum(c.flops_per_sec for c in graph.computes.values())
        space = SearchSpace.for_graph(graph)
        for name, sel, flops in cases:
            evaluate = CostModelEvaluator(sel, graph)
            cost = evaluate(space.baseline())
            util = flops / (cost * peak) if cost > 0 else 0.0
            rows.append((f"port_{name}", cost * 1e6,
                         f"util={util:.4f}/flops={flops:.3e}/peak={peak:.3e}",
                         target))
    return rows
