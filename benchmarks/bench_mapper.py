"""Paper Section 6.1 (mapper coverage): ISAM must automatically map every
evaluation kernel onto the tensor ISA — matmul, conv1d/2d, depthwise,
separable-depthwise (via factorization), GRU, attention, gated MLP.

CSV: name, us_per_call = mapping+selection wall time, derived =
"<complete>/<n_instrs>/<n_calls>[/T<transforms>]".
"""
from __future__ import annotations

import time

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions

CASES = [
    ("map_matmul", lambda: K.matmul(256, 256, 256)),
    ("map_conv1d", lambda: K.conv1d(4, 32, 3, 64, 64)),
    ("map_conv2d", lambda: K.conv2d(2, 14, 14, 3, 3, 32, 64)),
    ("map_depthwise", lambda: K.depthwise_conv2d(1, 7, 7, 3, 3, 32)),
    ("map_separable_depthwise",
     lambda: K.separable_depthwise_conv(1, 7, 7, 3, 3, 16, 2, 32)),
    ("map_gru_cell", lambda: K.gru_cell(32, 128, 64)),
    ("map_attention_scores", lambda: K.attention_scores(4, 8, 64, 64, 64)),
    ("map_mlp_gate", lambda: K.mlp_gate(32, 128, 256)),
]


def run() -> list[tuple[str, float, str]]:
    isa = I.tpu_isa()
    rows = []
    for name, make in CASES:
        prog = make()
        t0 = time.perf_counter()
        sel = select_instructions(prog, isa)
        dt_us = (time.perf_counter() - t0) * 1e6
        derived = (f"complete={int(sel.complete)}/instrs={len(sel.instrs)}"
                   f"/calls={sel.total_calls()}")
        if sel.steps:
            derived += f"/transforms={len(sel.steps)}"
        assert sel.complete, f"{name}: mapper failed to cover {sel.uncovered}"
        rows.append((name, dt_us, derived))
    return rows
