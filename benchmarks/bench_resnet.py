"""Paper Figure 5: all inner ResNet-50 layers at minibatch 28 — ISAM maps
each convolution onto matmul instructions (the ISAM-TVM path of Section 7)
and schedules them on the v5e graph; we report the achieved fraction of peak
(the paper reports ISAM-TVM at up to 85% of LIBXSMM, both near peak).

CSV: name, us_per_call = modeled layer time (us), derived =
"gflops=<achieved>/peak_frac=<frac>/calls=<instruction calls>".
"""
from __future__ import annotations

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.scheduler import schedule
from repro.core.sysgraph import V5E_PEAK_FLOPS, tpu_v5e

BATCH = 28  # the paper's "very small minibatch 28"

# (name, H, W, kh, kw, cin, cout, stride) — ResNet-50 inner layer shapes
LAYERS = [
    ("conv2_1x1a", 56, 56, 1, 1, 64, 64, 1),
    ("conv2_3x3", 56, 56, 3, 3, 64, 64, 1),
    ("conv2_1x1b", 56, 56, 1, 1, 64, 256, 1),
    ("conv3_3x3", 28, 28, 3, 3, 128, 128, 1),
    ("conv3_1x1b", 28, 28, 1, 1, 128, 512, 1),
    ("conv4_3x3", 14, 14, 3, 3, 256, 256, 1),
    ("conv4_1x1b", 14, 14, 1, 1, 256, 1024, 1),
    ("conv5_3x3", 7, 7, 3, 3, 512, 512, 1),
    ("conv5_1x1b", 7, 7, 1, 1, 512, 2048, 1),
]


def run() -> list[tuple[str, float, str]]:
    from repro.core.transforms import fuse_axes_for_calls
    rows = []
    graph = tpu_v5e(1)
    for name, h, w, kh, kw, cin, cout, stride in LAYERS:
        # NHWC conv in ISAMIR; the mapper extracts the matmul (k -> cin) and
        # the fusion pass folds batch/spatial loops into the GEMM M dim
        # (1x1 convs collapse to a single call — the ISAM-TVM reordering).
        prog = K.conv2d(BATCH, h, w, kh, kw, cin, cout, stride)
        prog, sel, steps = fuse_axes_for_calls(prog, [I.mxu_matmul()])
        assert sel.complete, name
        sched = schedule(sel, graph)
        flops = 2.0 * BATCH * h * w * kh * kw * cin * cout
        gflops = flops / sched.makespan / 1e9
        frac = flops / sched.makespan / V5E_PEAK_FLOPS
        rows.append((f"resnet50_{name}", sched.makespan * 1e6,
                     f"gflops={gflops:.0f}/peak_frac={frac:.3f}"
                     f"/calls={sel.total_calls()}"))
    return rows
