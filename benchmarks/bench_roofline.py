"""Roofline summary over the multi-pod dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints one
row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPs.

CSV: name, us_per_call = roofline-bound step time (us), derived =
"dom=<term>/comp=<s>/mem=<s>/coll=<s>/useful=<model/hlo ratio>".
"""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(art_dir: str = ART_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> list[tuple[str, float, str, str]]:
    rows = []
    for rec in load_records():
        name = f"roofline_{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        target = rec.get("target", "tpu_v5e")
        if rec.get("status") == "skipped":
            rows.append((name, 0.0, "skipped=long_500k_full_attention",
                         target))
            continue
        if rec.get("status") != "ok":
            rows.append((name, 0.0, f"error={rec.get('error', '?')[:60]}",
                         target))
            continue
        r = rec["roofline"]
        ratio = rec.get("model_flops_ratio")
        derived = (f"dom={r['dominant'].replace('_s', '')}"
                   f"/comp={r['compute_s']:.3e}"
                   f"/mem={r['memory_s']:.3e}"
                   f"/coll={r['collective_s']:.3e}"
                   f"/useful={ratio:.3f}" if ratio is not None else "")
        rows.append((name, r["roofline_s"] * 1e6, derived, target))
    return rows
