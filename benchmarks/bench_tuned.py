"""Tuned-vs-greedy suite: what the repro.search autotuner buys on the
DeepBench GEMM shapes (paper Section 4's search framework applied to the
Figure 3 workload).

For each shape the suite reports the GreedyApproach modeled makespan against
the tuned one.  Tuned configs come from the persistent tuning cache when a
matching record exists (``src=cache`` — run ``python -m repro.search.tune
--suite gemm`` first); on a miss a small in-process hill-climb runs instead
(``src=search``) without touching the cache, so the benchmark is read-only.

CSV: name, us_per_call = tuned modeled time (us), derived =
"greedy=<s>/tuned=<s>/speedup=<greedy/tuned>/src=<cache|search>".
"""
from __future__ import annotations

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions
from repro.core.sysgraph import tpu_v5e
from repro.search.cache import get_default_cache
from repro.search.evaluate import CostModelEvaluator
from repro.search.space import SearchSpace, tuning_key
from repro.search.strategies import hill_climb
from repro.search.tune import DEEPBENCH_GEMM_SIZES

SEARCH_TRIALS = 12


def run() -> list[tuple[str, float, str]]:
    rows = []
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    cache = get_default_cache()
    for m, n, k in DEEPBENCH_GEMM_SIZES:
        prog = K.matmul(m, n, k)
        sel = select_instructions(prog, [I.mxu_matmul()],
                                  allow_transforms=False)
        evaluate = CostModelEvaluator(sel, graph)
        greedy = evaluate(space.baseline())

        rec = cache.lookup(tuning_key(prog, graph, "cost"))
        if rec is not None and rec.config:
            tuned = evaluate(rec.config)
            src = "cache"
        else:
            outcome = hill_climb(space, evaluate, trials=SEARCH_TRIALS,
                                 seed=0)
            tuned = outcome.best_cost
            src = "search"
        tuned = min(tuned, greedy)   # a stale cache entry never regresses
        rows.append((f"tuned_gemm_{m}x{n}x{k}", tuned * 1e6,
                     f"greedy={greedy:.3e}s/tuned={tuned:.3e}s/"
                     f"speedup={greedy / tuned:.2f}/src={src}"))
    return rows
