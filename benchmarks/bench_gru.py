"""Paper Figure 4: GRU RNN over 128 steps — ISAM's recurrent schedule
(priming / recursive / finish, Section 3.6) vs composed kernel-library calls.

The KL composition executes each operation as an isolated library kernel:
every call streams its operands from HBM and writes results back (no
cross-call reuse — exactly the "kernels written and called in isolation"
limitation of Section 1).  ISAM keeps weights resident in the register files
across the recursive iterations and fuses matmul+bias+activation.

CSV: name, us_per_call = ISAM modeled time per step (us), derived =
"isam=<s>/kl=<s>/speedup=<kl/isam>" for the full 128-step execution.
"""
from __future__ import annotations

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions
from repro.core.recurrent import schedule_recurrent
from repro.core.scheduler import DTYPE_BYTES, compute_time
from repro.core.sysgraph import paper_accelerator

STEPS = 128
# DeepBench RNN sizes: (batch, hidden) with input = hidden
SIZES = [(32, 512), (32, 1024), (16, 1536), (32, 1792)]

GRU_WEIGHTS = ("Wr", "Ur", "Wz", "Uz", "Wn", "Un", "br", "bz", "bnx", "bnh")


def kl_time_per_step(prog, graph) -> float:
    """Composed library calls: each selected instruction becomes an isolated
    kernel — operands in from HBM, result out to HBM, no reuse."""
    sel = select_instructions(prog, I.tpu_isa(include_fused=False))
    dev = next(iter(graph.computes.values()))
    hbm_rf = None
    for e in graph.edges:
        if e.dst == dev.memory:
            hbm_rf = e
            break
    total = 0.0
    for si in sel.instrs:
        calls = si.mapping.calls(sel.program)
        bm = dict(si.mapping.buffer_map)
        nbytes = 0
        for nb in si.needle.buffers:
            if nb.temp or nb.name not in bm:
                continue
            b = sel.program.buffer(bm[nb.name])
            n = 1
            for s in b.shape:
                n *= s
            nbytes += n * DTYPE_BYTES.get(b.dtype, 4)
        move = nbytes / hbm_rf.bandwidth + hbm_rf.latency
        # compute: use the scheduler's device model on a full-size tile
        from repro.core.scheduler import ComputeTile, Region
        sizes = {a: sel.program.axis(a).size
                 for a in si.mapping.mapped_axes()}
        tile = ComputeTile(0, si.needle.name, {k: 0 for k in sizes}, sizes,
                           [(nb.name,
                             Region(bm[nb.name],
                                    tuple((0, s) for s in
                                          sel.program.buffer(bm[nb.name]).shape)),
                             True, nb.name == si.needle.outputs[0]
                             if si.needle.outputs else False)
                            for nb in si.needle.buffers
                            if not nb.temp and nb.name in bm])
        total += calls * (move + compute_time(dev, tile))
    return total


def run() -> list[tuple[str, float, str]]:
    rows = []
    for batch, hidden in SIZES:
        prog = K.gru_cell(batch, hidden, hidden)
        graph = paper_accelerator(2)
        sel = select_instructions(prog, I.tpu_isa())
        rs = schedule_recurrent(sel, graph, carry={"Hout": "H"},
                                streamed=("X",))
        t_isam = rs.total_time(STEPS)
        t_kl = kl_time_per_step(prog, graph) * STEPS
        per_step_us = t_isam / STEPS * 1e6
        rows.append((f"gru_{batch}x{hidden}", per_step_us,
                     f"isam={t_isam:.3e}s/kl={t_kl:.3e}s/"
                     f"speedup={t_kl / t_isam:.2f}"))
    return rows
