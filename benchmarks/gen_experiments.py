"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from
artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def main():
    recs = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(path))
        recs[(d["arch"], d["shape"], d.get("mesh", "?"))] = d

    archs, shapes = [], []
    for (a, s, m) in recs:
        if a not in archs:
            archs.append(a)
        if s not in shapes:
            shapes.append(s)
    shapes = [s for s in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k") if s in shapes]

    print("### Dry-run status (40 cells x 2 meshes)\n")
    print("| arch | " + " | ".join(f"{s} (1pod/2pod)" for s in shapes) + " |")
    print("|---|" + "---|" * len(shapes))
    for a in sorted(archs):
        row = [a]
        for s in shapes:
            cells = []
            for m in ("single", "multi"):
                d = recs.get((a, s, m), {})
                st = d.get("status", "?")
                cells.append({"ok": "OK", "skipped": "skip",
                              "error": "ERR"}.get(st, "?"))
            row.append("/".join(cells))
        print("| " + " | ".join(row) + " |")

    print("\n### Per-device memory & collective schedule "
          "(single-pod, 256 chips)\n")
    print("| arch | shape | args GiB | temps GiB | collectives "
          "(count: by kind) |")
    print("|---|---|---|---|---|")
    for a in sorted(archs):
        for s in shapes:
            d = recs.get((a, s, "single"))
            if not d or d.get("status") != "ok":
                continue
            mem = d.get("memory", {})
            coll = d.get("collectives", {})
            kinds = ", ".join(f"{k}:{int(v)}" for k, v in
                              sorted(coll.get("count_by_kind", {}).items()))
            print(f"| {a} | {s} | {fmt_bytes(mem.get('argument_bytes'))} | "
                  f"{fmt_bytes(mem.get('temp_bytes'))} | {kinds} |")

    print("\n### Roofline terms (single-pod, v5e constants: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s/link ICI)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL/HLO flops | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    NOTES = {
        "memory_s": "less f32 activation traffic (remat policy saving "
                    "bf16; fused norms)",
        "compute_s": "remat policy recomputing fewer dots; larger "
                     "microbatch per device",
        "collective_s": "collective-matmul overlap; wider TP tiles; "
                        "gradient-compression on the DP all-reduce",
    }
    for a in sorted(archs):
        for s in shapes:
            d = recs.get((a, s, "single"))
            if not d or d.get("status") != "ok":
                continue
            r = d["roofline"]
            ratio = d.get("model_flops_ratio")
            print(f"| {a} | {s} | {r['compute_s']:.3e} | "
                  f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                  f"{r['dominant'].replace('_s','')} | "
                  f"{ratio:.3f} | {NOTES[r['dominant']]} |")

    print("\n### Multi-pod (512-chip) deltas\n")
    print("| arch | shape | coll bytes 1pod | coll bytes 2pod | "
          "pod-axis traffic visible |")
    print("|---|---|---|---|---|")
    for a in sorted(archs):
        for s in shapes:
            d1 = recs.get((a, s, "single"))
            d2 = recs.get((a, s, "multi"))
            if not d1 or not d2 or d1.get("status") != "ok" \
                    or d2.get("status") != "ok":
                continue
            c1 = d1["collectives"]["total_bytes"]
            c2 = d2["collectives"]["total_bytes"]
            print(f"| {a} | {s} | {c1:.3e} | {c2:.3e} | "
                  f"{'yes' if abs(c2 - c1) > 0.01 * max(c1, 1) else 'same'} |")


if __name__ == "__main__":
    main()
