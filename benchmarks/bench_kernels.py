"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness +
relative wall time vs the jnp oracle; on TPU the same harness times the real
kernels).

CSV: name, us_per_call = kernel wall time (us), derived =
"ref_us=<oracle>/max_err=<abs err>".
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.gemm import gemm
from repro.kernels.gru import gru_cell
from repro.kernels.ops import plan_gemm


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return out, (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []

    for m, n, k in [(256, 256, 256), (128, 512, 256)]:
        a = jnp.asarray(rng.uniform(-1, 1, (m, k)), jnp.float32)
        b = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
        tile, _ = plan_gemm(m, n, k)
        out, us = _time(lambda x, y: gemm(x, y, block=tile, interpret=True),
                        a, b)
        want, ref_us = _time(ref.gemm_ref, a, b)
        err = float(jnp.max(jnp.abs(out - want)))
        rows.append((f"pallas_gemm_{m}x{n}x{k}_tile{tile[0]}", us,
                     f"ref_us={ref_us:.1f}/max_err={err:.2e}"))

    B, E, H = 8, 64, 128
    params = {}
    for name in ("Wr", "Wz", "Wn"):
        params[name] = jnp.asarray(rng.uniform(-0.3, 0.3, (E, H)), jnp.float32)
    for name in ("Ur", "Uz", "Un"):
        params[name] = jnp.asarray(rng.uniform(-0.3, 0.3, (H, H)), jnp.float32)
    for name in ("br", "bz", "bnx", "bnh"):
        params[name] = jnp.zeros((H,), jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (B, E)), jnp.float32)
    h = jnp.asarray(rng.uniform(-1, 1, (B, H)), jnp.float32)
    out, us = _time(lambda xx, hh: gru_cell(xx, hh, params, block=(8, 128),
                                            interpret=True), x, h)
    want, ref_us = _time(lambda xx, hh: ref.gru_cell_ref(xx, hh, params),
                         x, h)
    err = float(jnp.max(jnp.abs(out - want)))
    rows.append((f"pallas_gru_{B}x{H}", us, f"ref_us={ref_us:.1f}"
                                            f"/max_err={err:.2e}"))
    return rows
