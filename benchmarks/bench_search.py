"""Search-throughput suite: configs/sec of the batched evaluation tier
(``repro.search.batch`` + schedule-key memo + incremental re-scheduling)
against the scalar per-config path, on the DeepBench GEMM search spaces.

Lanes per shape:

  * **sequential** — ``CostModelEvaluator.__call__`` per config (guard +
    full compile each time) over a seeded sample of the space,
  * **batched**    — ``evaluate_many`` over the **full enumerated space**
    (one vectorized guard pass, one schedule per distinct schedule key).

Scores are bit-identical between the lanes (asserted in-suite on the
sample), so the ratio is pure throughput.  The suite **fails** if the
batched lane is not at least ``MIN_SPEEDUP``x the sequential configs/sec —
this is the CI search-throughput gate.

Every ``us_per_call`` is the **deterministic modeled** best makespan over
the full space (microseconds) — stable across machines, so the perf
baseline can hold these rows to its tight tolerance.  Wall-clock rates
live in ``derived`` only.

CSV: name, us_per_call = modeled best-over-space makespan (us), derived =
"space=<n>/keys=<k>/seq=<c/s>/batch=<c/s>/speedup=<x>[/fresh=<f>/delta=<d>]".
"""
from __future__ import annotations

import random
import time

from repro.compile.driver import gemm_selection, gru_selection
from repro.core.sysgraph import tpu_v5e
from repro.search.evaluate import CostModelEvaluator
from repro.search.space import SearchSpace

#: CI gate: batched configs/sec must beat sequential by at least this much.
MIN_SPEEDUP = 10.0

#: sequential-lane sample size (full spaces are 5760 configs; timing the
#: scalar path on all of them would dominate the whole benchmark run).
SEQ_SAMPLE = 16

GEMM_SHAPES = [(1024, 128, 1024), (2048, 64, 2048), (35, 700, 2048)]

#: heterogeneous GRU (input dim != hidden dim): instruction 0's reduction
#: is cap-invariant, so tile_k/vmem sweeps share an unchanged instruction
#: prefix with their anchor — the incremental re-scheduling showcase.
GRU_DELTA_SHAPE = (16, 512, 64)


def _lanes(sel, graph, space) -> tuple[float, str]:
    """(best modeled cost over the full space, derived string) — and the
    in-suite throughput gate."""
    configs = list(space.enumerate_configs())
    sample_idx = random.Random(0).sample(range(len(configs)), SEQ_SAMPLE)
    sample = [configs[i] for i in sample_idx]

    seq = CostModelEvaluator(sel, graph)
    t0 = time.perf_counter()
    seq_scores = [seq(c) for c in sample]
    seq_s = time.perf_counter() - t0

    batch = CostModelEvaluator(sel, graph)
    t0 = time.perf_counter()
    scores = batch.evaluate_many(configs)
    batch_s = time.perf_counter() - t0

    for i, s in zip(sample_idx, seq_scores):
        if scores[i] != s:
            raise RuntimeError(f"batched score diverged at config {i}: "
                               f"{scores[i]} != scalar {s}")
    seq_rate = len(sample) / max(seq_s, 1e-9)
    batch_rate = len(configs) / max(batch_s, 1e-9)
    speedup = batch_rate / seq_rate
    if speedup < MIN_SPEEDUP:
        raise RuntimeError(
            f"search throughput regression: batched evaluation is only "
            f"{speedup:.1f}x sequential (gate: {MIN_SPEEDUP}x) — "
            f"seq={seq_rate:.0f}/s batch={batch_rate:.0f}/s")
    best = min(s for s in scores if s != float("inf"))
    st = batch.stats
    derived = (f"space={len(configs)}/keys={st.fresh + st.delta}/"
               f"seq={seq_rate:.0f}/batch={batch_rate:.0f}/"
               f"speedup={speedup:.0f}x/fresh={st.fresh}/delta={st.delta}")
    return best * 1e6, derived


def _delta_row() -> tuple[str, float, str]:
    """Incremental re-scheduling on the heterogeneous GRU: a same-policy
    tile_k/vmem/grow sweep must resume from the anchor's unchanged prefix
    (delta > 0), bit-identical to from-scratch (the evaluator's contract)."""
    batch, hidden, inp = GRU_DELTA_SHAPE
    _, sel = gru_selection(batch, hidden, inp)
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    base = space.baseline()
    choices = {a.name: a.choices for a in space.axes}
    sweep = [dict(base, tile_k=tk, vmem_frac=vf, grow_j=gj)
             for tk in choices["tile_k"]
             for vf in choices["vmem_frac"]
             for gj in choices["grow_j"]]
    ev = CostModelEvaluator(sel, graph)
    scores = ev.evaluate_many(sweep)
    if ev.stats.delta == 0:
        raise RuntimeError("incremental re-scheduling never fired on the "
                           "heterogeneous GRU sweep (delta == 0)")
    check = CostModelEvaluator(sel, graph, incremental=False)
    for cfg, s in zip(sweep[:4], scores[:4]):
        ref = check.evaluate_many([cfg])[0]
        if s != ref:
            raise RuntimeError(f"incremental score diverged: {s} != {ref}")
    best = min(s for s in scores if s != float("inf"))
    derived = (f"sweep={len(sweep)}/fresh={ev.stats.fresh}/"
               f"delta={ev.stats.delta}/memo={ev.stats.memo_hits}")
    return (f"search_gru_{batch}x{hidden}x{inp}_delta", best * 1e6, derived)


def run() -> list[tuple[str, float, str]]:
    rows = []
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    for m, n, k in GEMM_SHAPES:
        _, sel = gemm_selection(m, n, k)
        us, derived = _lanes(sel, graph, space)
        rows.append((f"search_gemm_{m}x{n}x{k}", us, derived))
    rows.append(_delta_row())
    return rows
