"""Serving suite: p50/p99 latency and goodput-vs-load for the online
continuous-batching scheduler vs the one-shot static baseline
(``repro.serve``), on a seeded workload against the warmed bucket lattice.

Every ``us_per_call`` is **deterministic modeled** time (microseconds) —
seeded arrivals + simulated makespans — so the CI perf gate holds these
rows to its tight tolerance like the graph suite.  The goodput-vs-load
curve (higher-better, so not gateable as a latency) rides in ``derived``,
and the suite *fails* if the online scheduler ever loses to static at the
highest load point — the ISSUE 8 acceptance criterion runs inside the
bench.

CSV: name, us_per_call = modeled latency (us), derived = workload and
goodput context.
"""
from __future__ import annotations

from repro.serve.bucket import ServingPool
from repro.serve.scheduler import FifoOnlineScheduler, StaticBatchScheduler
from repro.serve.simulate import ServeParams, simulate_serving
from repro.serve.workload import generate_requests

N_REQUESTS = 32
SEED = 0
RATE = 400.0                 # the mid-load point the latency rows pin
SWEEP_RATES = (200.0, 1000.0, 5000.0)
BUCKETS = (4, 8, 16)
PARAMS = ServeParams(max_batch=4, kv_budget=1 << 15)


def _run_pair(pool, rate: float):
    reqs = generate_requests(N_REQUESTS, seed=SEED, rate=rate)
    online = simulate_serving(reqs, pool, FifoOnlineScheduler(), PARAMS)
    static = simulate_serving(reqs, pool, StaticBatchScheduler(), PARAMS)
    return online, static


def run() -> list[tuple[str, float, str]]:
    rows = []
    pool = ServingPool(archs=("olmo-1b",), buckets=BUCKETS, use_cache=False)
    warm = pool.warmup()

    # the per-iteration cost oracle itself: the largest bucket's block
    # makespan — this row inherits the double-buffering win directly.
    art = pool.get("olmo-1b", max(BUCKETS))
    rows.append(("serve_block_iter", art.makespan * 1e6,
                 f"bucket=T{art.bucket}/nodes={warm['nodes']}/"
                 f"compiles={warm['unique_programs']}"))

    online, static = _run_pair(pool, RATE)
    om, sm = online.metrics, static.metrics
    ctx = (f"n={N_REQUESTS}/rate={RATE:g}/completed={om['completed']}/"
           f"goodput={om['goodput_tps']:.1f}tps")
    rows.append(("serve_online_p50", om["p50_latency_s"] * 1e6, ctx))
    rows.append(("serve_online_p99", om["p99_latency_s"] * 1e6, ctx))
    rows.append(("serve_static_p99", sm["p99_latency_s"] * 1e6,
                 f"n={N_REQUESTS}/rate={RATE:g}/"
                 f"goodput={sm['goodput_tps']:.1f}tps"))

    # goodput-vs-load curve; the highest point is the acceptance check.
    curve = []
    top = None
    for rate in SWEEP_RATES:
        on, st = _run_pair(pool, rate)
        curve.append(f"gp@r{rate:g}={on.metrics['goodput_tps']:.0f}"
                     f"vs{st.metrics['goodput_tps']:.0f}")
        top = (on, st)
    on, st = top
    if on.metrics["goodput_tps"] <= st.metrics["goodput_tps"]:
        raise AssertionError(
            "online continuous batching lost to the static baseline at "
            f"rate {SWEEP_RATES[-1]:g}: "
            f"{on.metrics['goodput_tps']:.1f} <= "
            f"{st.metrics['goodput_tps']:.1f} tok/s")
    rows.append(("serve_goodput_curve", on.metrics["p99_latency_s"] * 1e6,
                 ";".join(curve)))
    return rows
