"""Benchmark harness — one suite per paper table/figure (+ the roofline).

    PYTHONPATH=src python -m benchmarks.run [--only <suite>] [--json <path>]

Prints ``name,us_per_call,derived`` CSV; ``--json`` additionally writes the
rows (plus per-suite errors) as machine-readable JSON so the perf trajectory
is comparable across PRs (e.g. ``BENCH_mapper.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

#: suite name -> (module under benchmarks/, one-line description).  The
#: modules import lazily in main() (several pull in jax); this table is
#: what --help shows.
SUITES = {
    "mapper": ("bench_mapper", "paper Section 6.1 (mapping coverage)"),
    "gemm": ("bench_gemm",
             "paper Figure 3 (DeepBench GEMM, ISAM vs kernel library)"),
    "gru": ("bench_gru",
            "paper Figure 4 (128-step GRU, fusion + persistent weights)"),
    "resnet": ("bench_resnet",
               "paper Figure 5 (ResNet-50 layers via conv->matmul mapping)"),
    "kernels": ("bench_kernels", "Pallas kernel microbenchmarks vs jnp"),
    "roofline": ("bench_roofline",
                 "dry-run roofline terms per (arch x shape x mesh)"),
    "tuned": ("bench_tuned",
              "repro.search autotuner vs GreedyApproach (DeepBench GEMMs)"),
    "fabric": ("bench_fabric",
               "repro.fabric 2/4/8-chip strong scaling (DeepBench GEMMs)"),
}


def _epilog() -> str:
    lines = ["suites:"]
    lines += [f"  {name:<9} {desc}" for name, (_, desc) in SUITES.items()]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=_epilog())
    ap.add_argument("--only", default=None, metavar="SUITE",
                    help="run a single suite (see list below)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (machine-readable "
                         "perf trajectory)")
    args = ap.parse_args()

    if args.only and args.only not in SUITES:
        print(f"unknown suite {args.only!r}; available: "
              f"{', '.join(sorted(SUITES))}", file=sys.stderr)
        raise SystemExit(2)
    selected = {args.only: SUITES[args.only]} if args.only else SUITES

    import importlib
    suites = {name: importlib.import_module(f".{mod}", package=__package__)
              for name, (mod, _) in selected.items()}

    print("name,us_per_call,derived")
    records: list[dict] = []
    failures = 0
    for name, module in suites.items():
        try:
            for row_name, us, derived in module.run():
                print(f"{row_name},{us:.2f},{derived}", flush=True)
                records.append({"suite": name, "name": row_name,
                                "us_per_call": us, "derived": derived})
        except Exception as e:
            failures += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            records.append({"suite": name, "name": name, "us_per_call": -1.0,
                            "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "failures": failures, "rows": records},
                      f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
