"""Benchmark harness — one suite per paper table/figure (+ the roofline).

    PYTHONPATH=src python -m benchmarks.run [--only <suite>] [--json <path>]

Prints ``name,us_per_call,derived`` CSV; ``--json`` additionally writes the
rows (plus per-suite errors) as machine-readable JSON so the perf trajectory
is comparable across PRs (e.g. ``BENCH_mapper.json``).
Suites:
    mapper    — paper Section 6.1 (mapping coverage)
    gemm      — paper Figure 3 (DeepBench GEMM, ISAM vs kernel library)
    gru       — paper Figure 4 (128-step GRU, fusion + persistent weights)
    resnet    — paper Figure 5 (ResNet-50 layers via conv->matmul mapping)
    kernels   — Pallas kernel microbenchmarks vs jnp oracles
    roofline  — dry-run roofline terms per (arch x shape x mesh)
    tuned     — repro.search autotuner vs GreedyApproach (DeepBench GEMMs)
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (machine-readable "
                         "perf trajectory)")
    args = ap.parse_args()

    from . import (bench_gemm, bench_gru, bench_kernels, bench_mapper,
                   bench_resnet, bench_roofline, bench_tuned)
    suites = {
        "mapper": bench_mapper.run,
        "gemm": bench_gemm.run,
        "gru": bench_gru.run,
        "resnet": bench_resnet.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "tuned": bench_tuned.run,
    }
    if args.only:
        if args.only not in suites:
            print(f"unknown suite {args.only!r}; available: "
                  f"{', '.join(sorted(suites))}", file=sys.stderr)
            raise SystemExit(2)
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    records: list[dict] = []
    failures = 0
    for name, fn in suites.items():
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.2f},{derived}", flush=True)
                records.append({"suite": name, "name": row_name,
                                "us_per_call": us, "derived": derived})
        except Exception as e:
            failures += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            records.append({"suite": name, "name": name, "us_per_call": -1.0,
                            "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "failures": failures, "rows": records},
                      f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
