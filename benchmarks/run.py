"""Benchmark harness — one suite per paper table/figure (+ the roofline).

    PYTHONPATH=src python -m benchmarks.run [--only <suite>[,<suite>...]]
        [--json <path>] [--baseline <path> --tolerance <pct>]

Prints ``name,us_per_call,derived`` CSV; ``--json`` additionally writes the
rows (plus per-suite errors) as machine-readable JSON so the perf trajectory
is comparable across PRs (e.g. ``BENCH_mapper.json``).

``--baseline`` turns the run into a **perf-regression gate**: every row of
the baseline JSON must reappear (matched by suite + name + hardware
target, so a gpu row never gates against a tpu one) with
``us_per_call`` no more than ``--tolerance`` percent above the recorded
value.  Missing rows and regressions fail the run (exit 1) with one line per
violation; new rows not in the baseline are reported but pass — they become
part of the baseline when it is next regenerated.  CI gates the
deterministic modeled-cost suites (``tuned``, ``fabric``, ``graph``,
``serve``, ``portability``)
against the committed ``benchmarks/baselines/BENCH_ci.json``; see README
for how to update it.

A suite that yields **zero rows** is an error (exit 1), not a pass — the
gate must never go green on vacuous output.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

#: suite name -> (module under benchmarks/, one-line description).  The
#: modules import lazily in main() (several pull in jax); this table is
#: what --help shows.
SUITES = {
    "mapper": ("bench_mapper", "paper Section 6.1 (mapping coverage)"),
    "gemm": ("bench_gemm",
             "paper Figure 3 (DeepBench GEMM, ISAM vs kernel library)"),
    "gru": ("bench_gru",
            "paper Figure 4 (128-step GRU, fusion + persistent weights)"),
    "resnet": ("bench_resnet",
               "paper Figure 5 (ResNet-50 layers via conv->matmul mapping)"),
    "kernels": ("bench_kernels", "Pallas kernel microbenchmarks vs jnp"),
    "roofline": ("bench_roofline",
                 "dry-run roofline terms per (arch x shape x mesh)"),
    "tuned": ("bench_tuned",
              "repro.search autotuner vs GreedyApproach (DeepBench GEMMs)"),
    "fabric": ("bench_fabric",
               "repro.fabric 2/4/8-chip strong scaling (DeepBench GEMMs)"),
    "graph": ("bench_graph",
              "repro.graph whole-block compilation (fusion + dedupe)"),
    "serve": ("bench_serve",
              "repro.serve online batching p50/p99 + goodput vs load"),
    "search": ("bench_search",
               "repro.search batched-evaluation throughput vs scalar "
               "(gated >= 10x)"),
    "portability": ("bench_portability",
                    "cross-backend roofline: DeepBench GEMM + conv on "
                    "every hardware target"),
}


def _epilog() -> str:
    lines = ["suites:"]
    lines += [f"  {name:<9} {desc}" for name, (_, desc) in SUITES.items()]
    return "\n".join(lines)


def compare_to_baseline(records: list[dict], baseline: dict,
                        tolerance_pct: float,
                        out=sys.stderr, ran_suites=None) -> list[str]:
    """Violations of ``records`` against a previously written ``--json``
    payload: baseline rows that disappeared or got slower than the
    tolerance.  Baseline rows that recorded an error (us_per_call < 0)
    gate nothing — a fixed suite reports real rows under real names, so
    the synthetic error row would otherwise read as "missing" forever.
    With ``ran_suites``, baseline rows of suites that were not selected
    this run (``--only``) gate nothing either — one committed baseline
    serves both the full perf gate and single-suite lanes."""
    got = {}
    for r in records:
        got[(r.get("suite"), r.get("name"), r.get("target", ""))] = r
    violations = []
    tol = 1.0 + tolerance_pct / 100.0
    for b in baseline.get("rows", []):
        key = (b.get("suite"), b.get("name"), b.get("target", ""))
        if ran_suites is not None and key[0] not in ran_suites:
            continue
        base_us = float(b.get("us_per_call", -1.0))
        if base_us < 0:
            continue    # baseline recorded an error for this row: nothing
            # to gate — a later run that fixed the suite reports real rows
            # under real names, so the synthetic error key never matches
        label = f"{key[0]}/{key[1]}" + (f"@{key[2]}" if key[2] else "")
        row = got.get(key)
        if row is None:
            violations.append(f"{label}: row missing "
                              f"(baseline {base_us:.2f}us)")
            continue
        new_us = float(row.get("us_per_call", -1.0))
        if new_us < 0:
            violations.append(f"{label}: now errors "
                              f"({row.get('error', 'unknown')}), baseline "
                              f"{base_us:.2f}us")
        elif new_us > base_us * tol:
            violations.append(
                f"{label}: {new_us:.2f}us exceeds baseline "
                f"{base_us:.2f}us by {(new_us / base_us - 1) * 100:.1f}% "
                f"(tolerance {tolerance_pct:.1f}%)")
    baseline_keys = {(b.get("suite"), b.get("name"), b.get("target", ""))
                     for b in baseline.get("rows", [])}
    new_rows = [k for k in got if k not in baseline_keys]
    if new_rows:
        print(f"# {len(new_rows)} row(s) not in baseline (pass; regenerate "
              "the baseline to gate them)", file=out)
    return violations


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=_epilog())
    ap.add_argument("--only", default=None, metavar="SUITE[,SUITE...]",
                    help="run selected suites (comma-separated; see list "
                         "below)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (machine-readable "
                         "perf trajectory)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare against a previous --json payload and "
                         "fail on regressions (the CI perf gate)")
    ap.add_argument("--tolerance", type=float, default=5.0, metavar="PCT",
                    help="allowed us_per_call increase over the baseline, "
                         "in percent (default 5)")
    args = ap.parse_args()

    if args.only:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        if not names:
            print(f"--only {args.only!r} selects no suites; available: "
                  f"{', '.join(sorted(SUITES))}", file=sys.stderr)
            raise SystemExit(2)
        unknown = [s for s in names if s not in SUITES]
        if unknown:
            print(f"unknown suite(s) {', '.join(map(repr, unknown))}; "
                  f"available: {', '.join(sorted(SUITES))}", file=sys.stderr)
            raise SystemExit(2)
        selected = {name: SUITES[name] for name in names}
    else:
        selected = dict(SUITES)

    import importlib
    suites = {name: importlib.import_module(f".{mod}", package=__package__)
              for name, (mod, _) in selected.items()}

    print("name,us_per_call,derived")
    records: list[dict] = []
    failures = 0
    for name, module in suites.items():
        n_rows = 0
        try:
            for row in module.run():
                # Rows are (name, us, derived) or, for multi-target suites,
                # (name, us, derived, target) — the target rides into the
                # JSON records so the perf gate keys per backend.
                row_name, us, derived = row[0], row[1], row[2]
                target = row[3] if len(row) > 3 else ""
                n_rows += 1
                shown = f"{row_name}@{target}" if target else row_name
                print(f"{shown},{us:.2f},{derived}", flush=True)
                rec = {"suite": name, "name": row_name,
                       "us_per_call": us, "derived": derived}
                if target:
                    rec["target"] = target
                records.append(rec)
        except Exception as e:
            failures += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            records.append({"suite": name, "name": name, "us_per_call": -1.0,
                            "error": f"{type(e).__name__}: {e}"})
            continue
        if n_rows == 0:
            # An empty sweep must not "pass" — a gate comparing nothing
            # against nothing would green on a broken suite.
            failures += 1
            print(f"suite {name!r} emitted no rows — failing "
                  "(empty sweeps don't pass)", file=sys.stderr)
            records.append({"suite": name, "name": name, "us_per_call": -1.0,
                            "error": "suite emitted no rows"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "failures": failures, "rows": records},
                      f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except (OSError, ValueError) as e:
            print(f"cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            raise SystemExit(2)
        violations = compare_to_baseline(records, baseline, args.tolerance,
                                         ran_suites=set(selected))
        for v in violations:
            print(f"PERF REGRESSION: {v}", file=sys.stderr)
        if violations:
            raise SystemExit(1)
        print(f"# perf gate: {len(baseline.get('rows', []))} baseline "
              f"row(s) within {args.tolerance:.1f}% tolerance",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
