"""Benchmark harness — one suite per paper table/figure (+ the roofline).

    PYTHONPATH=src python -m benchmarks.run [--only <suite>]

Prints ``name,us_per_call,derived`` CSV.
Suites:
    mapper    — paper Section 6.1 (mapping coverage)
    gemm      — paper Figure 3 (DeepBench GEMM, ISAM vs kernel library)
    gru       — paper Figure 4 (128-step GRU, fusion + persistent weights)
    resnet    — paper Figure 5 (ResNet-50 layers via conv->matmul mapping)
    kernels   — Pallas kernel microbenchmarks vs jnp oracles
    roofline  — dry-run roofline terms per (arch x shape x mesh)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_gemm, bench_gru, bench_kernels, bench_mapper,
                   bench_resnet, bench_roofline)
    suites = {
        "mapper": bench_mapper.run,
        "gemm": bench_gemm.run,
        "gru": bench_gru.run,
        "resnet": bench_resnet.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.2f},{derived}", flush=True)
        except Exception as e:
            failures += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
