"""Graph-compilation suite: what the ``repro.graph`` tier buys — epilogue
fusion (fewer nodes, fewer inter-kernel bytes) and artifact-cache dedupe
(compiles issued vs graph nodes) on the traced transformer block, plus the
unrolled-GRU dedupe-extreme chain.

Every ``us_per_call`` is the **deterministic modeled** end-to-end makespan
of the graph schedule on the event simulator (microseconds) — stable
across machines, so the CI perf gate can hold these rows to its tight
tolerance.  Wall-clock compile times and cache effects are reported in
``derived`` only.

CSV: name, us_per_call = modeled graph makespan (us), derived =
"nodes=<n>/compiles=<c>/dedupe=<x>/edge=<B>/hbm=<B>[/saved=<B>]".
"""
from __future__ import annotations

import os
import tempfile
import time

from repro.compile.cache import ArtifactCache
from repro.configs.registry import get_trace_config
from repro.graph.compile import compile_graph
from repro.graph.fuse import fuse_epilogues
from repro.graph.trace import trace_block, trace_gru_chain

ARCH = "olmo-1b"
SEQ = 8


def _row(name: str, cg, extra: str = "") -> tuple[str, float, str]:
    s = cg.stats
    derived = (f"nodes={s['nodes']}/compiles={s['unique_programs']}/"
               f"dedupe={s['dedupe']}/edge={cg.edge_bytes}/"
               f"hbm={cg.hbm_bytes}")
    return name, cg.makespan * 1e6, derived + extra


def run() -> list[tuple[str, float, str]]:
    rows = []
    cfg = get_trace_config(ARCH)
    unfused = trace_block(cfg, seq_len=SEQ)
    fused, decisions = fuse_epilogues(trace_block(cfg, seq_len=SEQ))

    cg_un = compile_graph(unfused, use_cache=False)
    rows.append(_row("graph_block_unfused", cg_un))

    cg_f = compile_graph(fused, use_cache=False, decisions=decisions)
    saved = sum(d.saved_bytes for d in decisions)
    rows.append(_row("graph_block_fused", cg_f, f"/saved={saved}"))

    # cache round-trip: cold populate then a warm compile that must be all
    # hits; wall times go to derived only (machine-dependent).
    with tempfile.TemporaryDirectory() as d:
        cache = ArtifactCache(os.path.join(d, "arts.json"))
        t0 = time.perf_counter()
        compile_graph(fused, cache=cache, decisions=decisions)
        cold_s = time.perf_counter() - t0
        from repro.compile.driver import clear_memo
        clear_memo()
        t0 = time.perf_counter()
        cg_w = compile_graph(fused, cache=ArtifactCache(cache.path),
                             decisions=decisions)
        warm_s = time.perf_counter() - t0
    rows.append(_row("graph_block_fused_cached", cg_w,
                     f"/hits={cg_w.stats['cache_hits']}"
                     f"/cold={cold_s:.3f}s/warm={warm_s:.3f}s"))

    cg_g = compile_graph(trace_gru_chain(), use_cache=False)
    rows.append(_row("graph_gru_chain", cg_g))
    return rows
