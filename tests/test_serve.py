"""Tests for ``repro.serve`` — online continuous-batching serving on top
of CompiledGraph: seeded workloads, the bucketed warmup lattice, KV-aware
admission, frozen-schedule replay, the ``srv.*`` verifier rules, and the
double-buffered load/compute overlap the serve makespans inherit.
"""
from __future__ import annotations

import copy
import json
import warnings

import pytest

from repro.compile.cache import ArtifactCache
from repro.compile.driver import clear_memo
from repro.configs.registry import get_trace_config
from repro.serve import (Admission, FifoOnlineScheduler, Request,
                         ServeParams, ServingPool, StaticBatchScheduler,
                         TracingScheduler, bucket_for, generate_requests,
                         kv_bytes, make_static_scheduler, percentile,
                         simulate_serving)
from repro.verify import (verify_replay, verify_serve_trace,
                          verify_task_graph)
from repro.verify.mutate import run_mutation

BUCKETS = (4, 8)
PARAMS = ServeParams(max_batch=4, kv_budget=1 << 15)
WORKLOAD = dict(seed=0, rate=400.0, prompt_lens=(2, 4, 6, 8),
                decode_lens=(1, 2, 3))


@pytest.fixture(scope="module")
def pool():
    p = ServingPool(archs=("olmo-1b",), buckets=BUCKETS, use_cache=False)
    p.warmup()
    return p


@pytest.fixture(scope="module")
def requests():
    return generate_requests(12, **WORKLOAD)


@pytest.fixture(scope="module")
def online(requests, pool):
    return simulate_serving(requests, pool, FifoOnlineScheduler(), PARAMS)


@pytest.fixture(scope="module")
def static(requests, pool):
    return simulate_serving(requests, pool, StaticBatchScheduler(), PARAMS)


@pytest.fixture(scope="module")
def frozen(requests, pool):
    sched = make_static_scheduler(FifoOnlineScheduler)()
    return simulate_serving(requests, pool, sched, PARAMS)


# -- workload -----------------------------------------------------------------

def test_workload_deterministic():
    a = generate_requests(16, seed=7, rate=250.0)
    b = generate_requests(16, seed=7, rate=250.0)
    assert [r.to_dict() for r in a] == [r.to_dict() for r in b]
    c = generate_requests(16, seed=8, rate=250.0)
    assert [r.to_dict() for r in a] != [r.to_dict() for r in c]


def test_workload_poisson_shape():
    reqs = generate_requests(32, seed=0, rate=100.0)
    arrivals = [r.arrival for r in reqs]
    assert arrivals == sorted(arrivals)
    assert all(a >= 0.0 for a in arrivals)
    assert len({r.rid for r in reqs}) == 32
    assert all(r.prompt_len > 0 and r.decode_len > 0 for r in reqs)


def test_workload_burst_groups():
    reqs = generate_requests(16, seed=0, rate=100.0, arrival="burst",
                             burst_size=4)
    starts = sorted({r.arrival for r in reqs})
    # 16 requests in bursts of 4 share exactly 4 distinct arrival times.
    assert len(starts) == 4
    for s in starts:
        assert sum(1 for r in reqs if r.arrival == s) == 4


def test_request_roundtrip():
    r = Request(rid=3, arch="olmo-1b", arrival=0.5, prompt_len=6,
                decode_len=2)
    assert Request.from_dict(r.to_dict()) == r
    assert r.tokens == 8


def test_percentile():
    vals = [4.0, 1.0, 3.0, 2.0]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 100.0) == 4.0
    assert percentile(vals, 50.0) == 2.5
    assert vals == [4.0, 1.0, 3.0, 2.0]    # input untouched


# -- bucket lattice -----------------------------------------------------------

def test_bucket_for_pads_up():
    assert bucket_for(1, (4, 8)) == 4
    assert bucket_for(4, (4, 8)) == 4
    assert bucket_for(5, (4, 8)) == 8
    with pytest.raises(ValueError):
        bucket_for(9, (4, 8))


def test_kv_bytes_model():
    cfg = get_trace_config("olmo-1b")
    # bucket * K&V * kv_heads * head_dim * f32 * layers
    assert kv_bytes(cfg, 16) == 16 * 2 * cfg.n_kv_heads * cfg.hd * 4 \
        * cfg.n_layers


def test_warmup_dedupes_across_buckets(pool):
    s = pool.stats
    assert s["entries"] == len(BUCKETS)
    # kernels shared between the two bucket graphs compile once
    assert s["unique_programs"] < s["nodes"]
    assert s["fresh_compiles"] == s["unique_programs"]
    assert s["evicted"] == 0


def test_second_arch_warms_for_free(tmp_path):
    # every get_trace_config arch scales to the same block dims, so the
    # second family's kernels are already in the cache: zero extra fresh.
    clear_memo()
    one = ServingPool(archs=("olmo-1b",), buckets=BUCKETS,
                      cache=ArtifactCache(str(tmp_path / "one.json")))
    s1 = one.warmup()
    clear_memo()
    two = ServingPool(archs=("olmo-1b", "qwen2-7b"), buckets=BUCKETS,
                      cache=ArtifactCache(str(tmp_path / "two.json")))
    s2 = two.warmup()
    assert s2["entries"] == 2 * len(BUCKETS)
    assert s2["fresh_compiles"] == s1["fresh_compiles"]
    assert s2["unique_programs"] == s1["unique_programs"]


def test_warm_restart_zero_fresh(tmp_path):
    path = str(tmp_path / "arts.json")
    clear_memo()
    cold = ServingPool(archs=("olmo-1b",), buckets=BUCKETS,
                       cache=ArtifactCache(path))
    sc = cold.warmup()
    assert sc["fresh_compiles"] > 0
    clear_memo()
    warm = ServingPool(archs=("olmo-1b",), buckets=BUCKETS,
                       cache=ArtifactCache(path))
    sw = warm.warmup()
    assert sw["fresh_compiles"] == 0
    assert sw["cache_hits"] == sc["fresh_compiles"] + sc["cache_hits"]


def test_admit_corrupt_evicts_and_warns_once(pool):
    import repro.serve.bucket as bucket_mod
    art = pool.get("olmo-1b", BUCKETS[0])
    corrupt = copy.deepcopy(art.cg)
    for t in list(corrupt.placement.locations):
        corrupt.placement.locations[t] = "l2"    # no legal placement
    spare = ServingPool(archs=("olmo-1b",), buckets=BUCKETS,
                        use_cache=False)
    bucket_mod._warned_corrupt.discard(("olmo-1b", BUCKETS[0]))
    with pytest.warns(UserWarning, match="evicting corrupt"):
        repaired = spare.admit(corrupt, "olmo-1b", BUCKETS[0])
    assert spare.stats.get("evicted") == 1
    from repro.verify import DiagnosticReport, verify_placement
    rep = DiagnosticReport()
    rep.extend(verify_placement(repaired.cg.graph,
                                repaired.cg.placement.locations,
                                repaired.cg.placement.budget))
    assert rep.ok
    with warnings.catch_warnings():          # second corruption: silent
        warnings.simplefilter("error")
        spare.admit(copy.deepcopy(corrupt), "olmo-1b", BUCKETS[0])
    assert spare.stats.get("evicted") == 2


def test_route(pool, requests):
    r = requests[0]
    art = pool.route(r)
    assert art.bucket == bucket_for(r.prompt_len, BUCKETS)
    assert art.arch == r.arch


# -- simulation ---------------------------------------------------------------

def test_sim_bit_deterministic(requests, pool, online):
    again = simulate_serving(requests, pool, FifoOnlineScheduler(), PARAMS)
    assert again.metrics == online.metrics
    assert again.completion_times() == online.completion_times()


def test_all_requests_complete(online, static):
    for res in (online, static):
        assert res.metrics["completed"] == res.metrics["n_requests"]
        assert res.metrics["starved"] == 0


def test_admission_respects_kv_and_batch(online):
    tr = online.trace()
    by_rid = {r["rid"]: r for r in tr["requests"]}
    for it in tr["iterations"]:
        assert len(it["running"]) <= PARAMS.max_batch
        used = sum(by_rid[r]["kv_bytes"] for r in it["running"])
        assert used <= PARAMS.kv_budget
        assert used == it["kv_used"]


def test_latency_positive_and_ordered(online):
    m = online.metrics
    assert 0.0 < m["p50_latency_s"] <= m["p99_latency_s"]
    assert m["goodput_tps"] > 0.0


def test_online_beats_static_at_high_load(pool):
    reqs = generate_requests(24, **{**WORKLOAD, "rate": 2000.0})
    on = simulate_serving(reqs, pool, FifoOnlineScheduler(), PARAMS)
    st = simulate_serving(reqs, pool, StaticBatchScheduler(), PARAMS)
    assert on.metrics["goodput_tps"] > st.metrics["goodput_tps"]
    assert on.metrics["makespan_s"] < st.metrics["makespan_s"]


def test_eventsim_timeline_audits_clean(online):
    assert online.tasks
    assert verify_task_graph(online.tasks) == []


def test_trace_json_roundtrip(online):
    tr = online.trace()
    assert json.loads(json.dumps(tr)) == tr
    assert tr["schema"] == 1
    assert tr["scheduler"] == "online-fifo"


# -- frozen replay ------------------------------------------------------------

def test_tracing_scheduler_records(requests, pool):
    tracer = TracingScheduler(FifoOnlineScheduler())
    simulate_serving(requests, pool, tracer, PARAMS)
    assert sorted(a.rid for a in tracer.schedules) == \
        sorted(r.rid for r in requests)
    assert all(isinstance(a, Admission) and a.wave == 0
               for a in tracer.schedules)


def test_frozen_replay_is_bit_identical(online, frozen):
    assert frozen.completion_times() == online.completion_times()
    assert frozen.metrics["p50_latency_s"] == online.metrics["p50_latency_s"]
    assert frozen.metrics["p99_latency_s"] == online.metrics["p99_latency_s"]
    assert frozen.scheduler == "static-online-fifo"


# -- the srv.* verifier -------------------------------------------------------

def test_verify_traces_clean(online, static, frozen):
    for res in (online, static, frozen):
        assert verify_serve_trace(res.trace()) == []


def test_verify_replay_clean_and_drift(online, frozen):
    assert verify_replay(frozen.trace(), online.trace()) == []
    drifted = frozen.trace()
    drifted["requests"][0] = dict(drifted["requests"][0])
    drifted["requests"][0]["completed"] += 1e-6
    diags = verify_replay(drifted, online.trace())
    assert any(d.rule == "srv.replay-drift" for d in diags)


def test_verify_catches_kv_violation(online):
    tr = online.trace()
    tr["params"] = dict(tr["params"], kv_budget=1)
    diags = verify_serve_trace(tr)
    assert any(d.rule == "srv.kv-budget" for d in diags)


def test_verify_catches_starvation(online):
    tr = online.trace()
    tr["requests"][0] = dict(tr["requests"][0], admitted=None,
                             completed=None)
    rid = tr["requests"][0]["rid"]
    tr["iterations"] = [
        dict(it, running=[r for r in it["running"] if r != rid],
             admitted=[r for r in it["admitted"] if r != rid])
        for it in tr["iterations"]]
    diags = verify_serve_trace(tr)
    assert any(d.rule == "srv.starvation" for d in diags)


@pytest.mark.parametrize("name", ["srv-over-admit", "srv-bucket-miss",
                                  "srv-replay-drift", "srv-starve"])
def test_serve_mutations_caught(name):
    res = run_mutation(name)
    assert res.caught, f"{name}: expected {res.expected}, got {res.rules}"
    assert res.expected in res.rules


# -- double-buffered overlap --------------------------------------------------

def test_double_buffer_strictly_faster(pool):
    from repro.fabric.simulate import simulate_kernel_graph
    cg = pool.get("olmo-1b", max(BUCKETS)).cg
    g = cg.graph
    costs = {n.name: cg.kernels[cg.node_kernels[n.name]].cost
             for n in g.nodes}
    db = simulate_kernel_graph(g, costs, cg.placement.locations)
    ser = simulate_kernel_graph(g, costs, cg.placement.locations,
                                double_buffer=False)
    assert db["makespan"] < ser["makespan"]
    assert db["hbm_bytes"] == ser["hbm_bytes"]
    assert verify_task_graph(db["tasks"]) == []
    # the pool artifact's recorded makespan is the double-buffered one
    assert cg.makespan == db["makespan"]
