"""ISAMIR construction + interpreter oracle tests."""
import numpy as np
import pytest

from repro.core import kernels_ir as K
from repro.core.ir import (Access, Axis, Buffer, IRError, Program,
                           ProgramBuilder, Statement, interpret, random_inputs)


def test_matmul_semantics():
    prog = K.matmul(5, 4, 3)
    rng = np.random.default_rng(0)
    ins = random_inputs(prog, rng)
    out = interpret(prog, ins)["C"]
    np.testing.assert_allclose(out, ins["C"] + ins["A"] @ ins["B"], rtol=1e-5)


def test_conv1d_semantics():
    prog = K.conv1d(2, 6, 3, 4, 5)
    rng = np.random.default_rng(1)
    ins = random_inputs(prog, rng)
    out = interpret(prog, ins)["C"]
    ref = np.array(ins["C"])
    for d in range(3):
        ref += np.einsum("ixk,ko->ixo", ins["A"][:, d:d + 6, :], ins["B"][d])
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_conv2d_strided_semantics():
    prog = K.conv2d(1, 3, 3, 2, 2, 2, 3, stride=2)
    rng = np.random.default_rng(2)
    ins = random_inputs(prog, rng)
    out = interpret(prog, ins)["C"]
    ref = np.array(ins["C"])
    for y in range(3):
        for x in range(3):
            patch = ins["A"][:, 2 * y:2 * y + 2, 2 * x:2 * x + 2, :]
            ref[:, y, x, :] += np.einsum("byxc,yxco->bo", patch, ins["W"])
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_separable_depthwise_semantics():
    prog = K.separable_depthwise_conv(1, 3, 3, 2, 2, 3, 2, 4)
    rng = np.random.default_rng(3)
    ins = random_inputs(prog, rng)
    out = interpret(prog, ins)["C"]
    A, D, P, C0 = ins["A"], ins["D"], ins["P"], ins["C"]
    ref = np.array(C0)
    for i in range(3):
        for j in range(3):
            # depthwise (q, r) intermediate, then pointwise P[2q+r, k]
            acc = np.zeros((3, 2))
            for di in range(2):
                for dj in range(2):
                    acc += A[0, i + di, j + dj][:, None] * D[di, dj]
            ref[0, i, j] += acc.reshape(-1) @ P
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_gru_cell_semantics():
    prog = K.gru_cell(3, 5, 4)
    rng = np.random.default_rng(4)
    ins = random_inputs(prog, rng)
    out = interpret(prog, ins)["Hout"]

    def sig(v):
        return 1 / (1 + np.exp(-v))

    x, h = ins["X"], ins["H"]
    r = sig(x @ ins["Wr"] + h @ ins["Ur"] + ins["br"])
    z = sig(x @ ins["Wz"] + h @ ins["Uz"] + ins["bz"])
    n = np.tanh(x @ ins["Wn"] + r * (h @ ins["Un"] + ins["bnh"]) + ins["bnx"])
    ref = (1 - z) * n + z * h
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_statement_domain_excludes_unused_axes():
    """A += over axes unused by the statement must not double-count."""
    pb = ProgramBuilder("p")
    i, j = pb.axes(i=3, j=4)
    x = pb.buffer("x", (3,))
    y = pb.buffer("y", (3,))
    pb.stmt(y[i], "+=", x[i])    # j unused: must run over i only
    prog = pb.build()
    ins = {"x": np.ones(3)}
    out = interpret(prog, ins)["y"]
    np.testing.assert_allclose(out, np.ones(3))


def test_validation_errors():
    pb = ProgramBuilder("bad")
    pb.axis("i", 4)
    pb.buffer("x", (4,))
    with pytest.raises(IRError):
        Program("p", (Axis("i", 4),), (Buffer("x", (4,)),),
                (Statement(":=", Access("x", ((1,),)), Access("nope", ((1,),))),))
    with pytest.raises(IRError):
        Statement("bogus", Access("x", ((1,),)), Access("x", ((1,),)))


def test_symbolic_axis_cannot_interpret():
    from repro.core.instructions import mxu_matmul
    with pytest.raises(IRError):
        interpret(mxu_matmul(), {})


def test_pretty_print_roundtrip_info():
    prog = K.matmul(2, 2, 2)
    s = prog.pretty()
    assert "tmp[i][j][k] := A[i][k];" in s
    assert "C[i][j] += tmp[i][j][k];" in s


def test_signature_distinguishes_programs():
    assert K.matmul(2, 2, 2).signature() != K.matmul(2, 2, 3).signature()
    assert K.matmul(2, 2, 2).signature() == K.matmul(2, 2, 2).signature()
