"""Tests for repro.runtime.fault_tolerance: straggler EWMA, checkpoint/
restart, SIGTERM preemption, elastic re-mesh restore."""
import os
import signal

import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.runtime.fault_tolerance import (RunState, StragglerDetector,
                                           TrainingRuntime)


# --------------------------------------------------------------------------- #
# StragglerDetector
# --------------------------------------------------------------------------- #


def test_straggler_first_observation_seeds_ewma():
    det = StragglerDetector()
    assert det.observe(0, 0.5) is False
    assert det.ewma == 0.5
    assert det.slow_steps == []


def test_straggler_flags_spike_above_threshold():
    det = StragglerDetector(alpha=0.2, threshold=2.0)
    det.observe(0, 1.0)
    assert det.observe(1, 1.1) is False            # within 2x EWMA
    assert det.observe(2, 5.0) is True             # 5x the baseline
    (step, dt, ewma), = det.slow_steps
    assert step == 2 and dt == 5.0
    # the EWMA recorded is the one the decision was made against
    assert dt > det.threshold * ewma


def test_straggler_ewma_update_rule():
    det = StragglerDetector(alpha=0.25, threshold=10.0)
    det.observe(0, 1.0)
    det.observe(1, 2.0)
    assert det.ewma == pytest.approx(0.75 * 1.0 + 0.25 * 2.0)


def test_straggler_adapts_to_sustained_slowdown():
    det = StragglerDetector(alpha=0.5, threshold=2.0)
    det.observe(0, 1.0)
    assert det.observe(1, 3.0) is True
    # EWMA has absorbed the slowdown; the same dt stops being "slow"
    assert det.observe(2, 3.0) is False


# --------------------------------------------------------------------------- #
# Training loop: checkpoint / crash / restart
# --------------------------------------------------------------------------- #


def _step_fn(carry, batch):
    params, opt = carry
    return (params + batch, opt + 1), {"loss": float(batch)}


def _batch_fn(step):
    return np.float64(step)


def _carry0():
    return (np.float64(0.0), np.int64(0))


def test_run_completes_and_commits_final_checkpoint(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    rt = TrainingRuntime(ckpt, save_every=3, async_save=False)
    carry = rt.run(_carry0(), _step_fn, _batch_fn, n_steps=7)
    assert rt.state.step == 7
    assert carry[0] == sum(range(7))
    # periodic saves at 3, 6 plus the final blocking save at 7
    assert ckpt.latest_step() == 7
    assert set(ckpt.committed_steps()) == {3, 6, 7}


def test_crash_restart_resumes_from_committed_step(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    rt = TrainingRuntime(ckpt, save_every=2, async_save=False)
    with pytest.raises(RuntimeError, match="injected fault at step 5"):
        rt.run(_carry0(), _step_fn, _batch_fn, n_steps=10,
               inject_fault_at=5)
    assert rt.state.crashed == 1
    assert ckpt.latest_step() == 4                 # last committed save

    # a fresh runtime (new process) restores and finishes the run
    rt2 = TrainingRuntime(ckpt, save_every=2, async_save=False)
    restored = rt2.try_restore(_carry0())
    assert restored is not None
    carry, step = restored
    assert step == 4 and rt2.state.step == 4 and rt2.state.resumed == 1
    carry = rt2.run(carry, _step_fn, _batch_fn, n_steps=10)
    # step-keyed batches: the resumed run replays exactly steps 4..9
    assert carry[0] == sum(range(10))
    assert rt2.state.step == 10


def test_try_restore_without_checkpoint_returns_none(tmp_path):
    rt = TrainingRuntime(Checkpointer(str(tmp_path)))
    assert rt.try_restore(_carry0()) is None
    assert rt.state.resumed == 0


def test_metrics_callback_sees_every_step(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    rt = TrainingRuntime(ckpt, save_every=100, async_save=False)
    seen = []
    rt.run(_carry0(), _step_fn, _batch_fn, n_steps=4,
           on_metrics=lambda step, m, dt, slow: seen.append(
               (step, m["loss"], slow)))
    assert [s for s, _, _ in seen] == [0, 1, 2, 3]
    assert all(not slow for _, _, slow in seen)


# --------------------------------------------------------------------------- #
# Preemption (SIGTERM)
# --------------------------------------------------------------------------- #


def test_sigterm_stops_loop_and_checkpoints(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    rt = TrainingRuntime(ckpt, save_every=1000, async_save=False)
    prev = signal.getsignal(signal.SIGTERM)
    rt.install_preemption_handler()
    try:
        def step_fn(carry, batch):
            carry, metrics = _step_fn(carry, batch)
            if batch == 3:                         # preempted mid-run
                os.kill(os.getpid(), signal.SIGTERM)
            return carry, metrics

        carry = rt.run(_carry0(), step_fn, _batch_fn, n_steps=100)
    finally:
        signal.signal(signal.SIGTERM, prev)
    assert rt.state.preempted is True
    assert rt.state.step == 4                      # stopped at loop top
    assert carry[0] == sum(range(4))
    # the final blocking save committed the preempted state
    assert ckpt.latest_step() == 4
    tree, step = ckpt.restore(4, _carry0())
    assert step == 4 and tree[0] == sum(range(4))


# --------------------------------------------------------------------------- #
# Elastic re-mesh restore
# --------------------------------------------------------------------------- #


def test_elastic_restore_applies_new_shardings(tmp_path):
    jax = pytest.importorskip("jax")
    ckpt = Checkpointer(str(tmp_path))
    rt = TrainingRuntime(ckpt, save_every=5, async_save=False)
    rt.run(_carry0(), _step_fn, _batch_fn, n_steps=5)

    # restore onto "whatever mesh is available" — here a single device
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    rt2 = TrainingRuntime(ckpt, save_every=5, async_save=False)
    restored = rt2.try_restore(_carry0(),
                               shardings=(sharding, sharding))
    assert restored is not None
    (params, opt), step = restored
    assert step == 5
    assert params.devices() == {dev}
    assert np.asarray(params) == sum(range(5))
    assert np.asarray(opt) == 5


def test_runstate_defaults():
    st = RunState()
    assert (st.step, st.crashed, st.resumed, st.preempted) == (0, 0, 0, False)
