"""Contract tests for repro.dist.ctx: constrain is an identity outside
``activation_sharding_ctx``, applies the matching rule inside it, and
unknown rule names fall back to no-op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.dist.ctx import activation_sharding_ctx, constrain, current_rules
from repro.dist.sharding import make_activation_rules
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_constrain_is_identity_outside_ctx():
    x = jnp.arange(8.0).reshape(2, 4)
    assert current_rules() is None
    y = constrain(x, "residual")
    assert y is x                      # literally untouched, not a copy
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_constrain_applies_matching_rule_inside_ctx(mesh):
    cfg = get_config("qwen2-7b")
    rules = make_activation_rules(mesh, cfg)
    x = jnp.ones((2, 8, 4, 2))

    applied = []
    def spy(name, shape):
        s = rules(name, shape)
        applied.append((name, None if s is None else s.spec))
        return s

    with activation_sharding_ctx(spy):
        assert current_rules() is spy
        y = constrain(x, "heads")
    assert applied == [("heads", P("data", None, "model", None))]
    assert y.shape == x.shape
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_constrain_under_jit_traces_and_preserves_values(mesh):
    cfg = get_config("qwen2-7b")
    rules = make_activation_rules(mesh, cfg)
    x = jnp.arange(24.0).reshape(2, 3, 4)

    @jax.jit
    def f(a):
        return constrain(a, "residual") * 2.0

    with mesh, activation_sharding_ctx(rules):
        out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)


def test_unknown_rule_name_is_noop(mesh):
    cfg = get_config("qwen2-7b")
    rules = make_activation_rules(mesh, cfg)
    x = jnp.ones((4, 4))
    with activation_sharding_ctx(rules):
        y = constrain(x, "no_such_rule_name")
    assert y is x


def test_rules_returning_none_is_noop():
    x = jnp.ones((4, 4))
    with activation_sharding_ctx(lambda name, shape: None):
        assert constrain(x, "residual") is x


def test_ctx_restores_on_exit_and_nests(mesh):
    cfg = get_config("qwen2-7b")
    outer = make_activation_rules(mesh, cfg)
    inner = lambda name, shape: None   # noqa: E731
    with activation_sharding_ctx(outer):
        with activation_sharding_ctx(inner):
            assert current_rules() is inner
        assert current_rules() is outer
    assert current_rules() is None


def test_ctx_restores_after_exception(mesh):
    cfg = get_config("qwen2-7b")
    rules = make_activation_rules(mesh, cfg)
    with pytest.raises(ValueError):
        with activation_sharding_ctx(rules):
            raise ValueError("boom")
    assert current_rules() is None


def test_explicit_sharding_rules_apply(mesh):
    """constrain accepts whatever sharding object the rules hand back."""
    sh = NamedSharding(mesh, P("data", None))
    x = jnp.ones((2, 4))

    @jax.jit
    def f(a):
        return constrain(a, "anything")

    with activation_sharding_ctx(lambda name, shape: sh):
        y = f(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert y.sharding.is_equivalent_to(sh, x.ndim)
