"""repro.search tests: the baseline anchors to GreedyApproach exactly,
search is deterministic under a fixed seed, tuned schedules never model
worse than greedy, the persistent cache round-trips, and winning schedules
replay bit-exact against the ISAMIR oracle through the executor."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.approach import GreedyApproach
from repro.core.isel import select_instructions
from repro.core.scheduler import schedule
from repro.core.sysgraph import paper_accelerator, tpu_v5e
from repro.search.cache import (TuningCache, TuningRecord, lookup_gemm,
                                set_default_cache)
from repro.search.evaluate import CostModelEvaluator, validate_selection
from repro.search.space import (ParamApproach, SearchSpace, config_key,
                                program_fingerprint, sysgraph_fingerprint,
                                tuning_key)
from repro.search.strategies import STRATEGIES, hill_climb

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GEMM = (256, 192, 130)      # fixed case: odd k exercises boundary tiles


def _gemm_fixture(graph=None):
    graph = graph or tpu_v5e(1)
    prog = K.matmul(*GEMM)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    return prog, sel, graph


# --------------------------------------------------------------------------- #
# space / ParamApproach
# --------------------------------------------------------------------------- #


def test_param_baseline_matches_greedy_exactly():
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    s_greedy = schedule(sel, graph, GreedyApproach())
    s_base = schedule(sel, graph, ParamApproach(space.baseline()))
    assert s_base.makespan == s_greedy.makespan
    assert [op.kind for op in s_base.ops] == [op.kind for op in s_greedy.ops]
    assert s_base.counts() == s_greedy.counts()


def test_param_approach_tolerates_unknown_config_values():
    """Records written by a newer version (unknown policy names, junk
    numerics) must degrade to the greedy defaults, not crash scheduling."""
    prog, sel, graph = _gemm_fixture()
    weird = {"unroll": "block_major", "device": "gpu_first", "source": "??",
             "vmem_frac": "lots", "tile_i": "wide"}
    s = schedule(sel, graph, ParamApproach(weird))
    s_greedy = schedule(sel, graph, GreedyApproach())
    assert s.makespan == s_greedy.makespan


def test_random_configs_schedule_and_stay_finite():
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph)
    import random
    rng = random.Random(7)
    costs = [ev(space.random_config(rng)) for _ in range(5)]
    assert all(c > 0 for c in costs)
    assert any(np.isfinite(c) for c in costs)


def test_tile_guard_rejects_blowup():
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph, max_tiles=1)
    assert ev(space.baseline()) == float("inf")


def test_fingerprints_structural():
    p1, p2 = K.matmul(64, 64, 64), K.matmul(64, 64, 64)
    p3 = K.matmul(64, 64, 128)
    assert program_fingerprint(p1) == program_fingerprint(p2)
    assert program_fingerprint(p1) != program_fingerprint(p3)
    g1, g2 = tpu_v5e(1), tpu_v5e(2)
    assert sysgraph_fingerprint(g1) == sysgraph_fingerprint(tpu_v5e(1))
    assert sysgraph_fingerprint(g1) != sysgraph_fingerprint(g2)
    assert tuning_key(p1, g1, "cost") != tuning_key(p1, g1, "measure")


# --------------------------------------------------------------------------- #
# strategies
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_deterministic_under_fixed_seed(name):
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph)
    o1 = STRATEGIES[name](space, ev, trials=10, seed=5)
    o2 = STRATEGIES[name](space, ev, trials=10, seed=5)
    assert [(config_key(t.config), t.cost) for t in o1.trials] == \
           [(config_key(t.config), t.cost) for t in o2.trials]
    assert o1.best_config == o2.best_config
    assert o1.best_cost == o2.best_cost


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_tuned_cost_never_worse_than_greedy(name):
    """Every strategy evaluates the greedy-equivalent baseline first."""
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph)
    greedy = schedule(sel, graph, GreedyApproach()).makespan
    o = STRATEGIES[name](space, ev, trials=8, seed=0)
    assert o.baseline_cost == greedy
    assert o.best_cost <= greedy
    assert o.trials[0].config == space.baseline()


def test_hill_climb_finds_improvement_on_deepbench_shape():
    graph = tpu_v5e(1)
    prog = K.matmul(1024, 128, 1024)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    space = SearchSpace.for_graph(graph)
    o = hill_climb(space, CostModelEvaluator(sel, graph), trials=12, seed=0)
    assert o.best_cost < o.baseline_cost


# --------------------------------------------------------------------------- #
# cache
# --------------------------------------------------------------------------- #


def test_cache_roundtrip_same_schedule(tmp_path):
    """write -> fresh cache instance -> lookup -> identical schedule."""
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph)
    o = hill_climb(space, ev, trials=6, seed=0)
    key = tuning_key(prog, graph, "cost")

    path = str(tmp_path / "tuning.json")
    TuningCache(path).store(TuningRecord(
        key=key, config=o.best_config, cost=o.best_cost,
        baseline_cost=o.baseline_cost, strategy="hillclimb", trials=6))

    rec = TuningCache(path).lookup(key)       # fresh instance, re-read disk
    assert rec is not None
    assert rec.config == o.best_config
    s1 = schedule(sel, graph, ParamApproach(o.best_config))
    s2 = schedule(sel, graph, ParamApproach(rec.config))
    assert s1.makespan == s2.makespan == rec.cost
    assert [op.kind for op in s1.ops] == [op.kind for op in s2.ops]


def test_round_robin_deterministic_on_reused_approach():
    """The round-robin cursor lives on the per-run scheduler state, so the
    same Approach instance yields the same schedule on repeated calls."""
    graph = paper_accelerator(2)
    prog = K.matmul(100, 80, 60)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    app = ParamApproach({"device": "round_robin"})
    s1 = schedule(sel, graph, app)
    s2 = schedule(sel, graph, app)
    assert s1.makespan == s2.makespan
    assert [op.device for op in s1.ops] == [op.device for op in s2.ops]


def test_cache_save_merges_concurrent_writers(tmp_path):
    """Records stored by another process between our load and save must
    survive (merge-on-save, last writer wins per key not per file)."""
    path = str(tmp_path / "tuning.json")
    c1 = TuningCache(path)
    c1.store(TuningRecord(key="a", config={}, cost=1.0, baseline_cost=1.0))
    c2 = TuningCache(path)          # separate "process": own snapshot
    c2.load()
    c1.store(TuningRecord(key="b", config={}, cost=2.0, baseline_cost=2.0))
    c2.store(TuningRecord(key="c", config={}, cost=3.0, baseline_cost=3.0))
    final = TuningCache(path)
    assert sorted(final.keys()) == ["a", "b", "c"]


def test_cache_tolerates_corrupt_file(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    c = TuningCache(str(path))
    assert len(c) == 0
    c.store(TuningRecord(key="k", config={}, cost=1.0, baseline_cost=1.0))
    assert TuningCache(str(path)).lookup("k") is not None


def test_lookup_gemm_prefers_measured(tmp_path):
    from repro.search.cache import gemm_tuning_key
    path = str(tmp_path / "tuning.json")
    c = TuningCache(path)
    c.store(TuningRecord(key=gemm_tuning_key(64, 64, 64, backend="cost"),
                         config={}, cost=2.0, baseline_cost=2.0,
                         backend="cost", tile=(128, 128, 128)), save=False)
    c.store(TuningRecord(key=gemm_tuning_key(64, 64, 64, backend="measure"),
                         config={}, cost=1.0, baseline_cost=2.0,
                         backend="measure", tile=(64, 64, 64)))
    set_default_cache(c)
    try:
        rec = lookup_gemm(64, 64, 64)
        assert rec is not None and rec.backend == "measure"
        assert lookup_gemm(65, 64, 64) is None
    finally:
        set_default_cache(None)


# --------------------------------------------------------------------------- #
# executor-vs-oracle validation
# --------------------------------------------------------------------------- #


def test_tuned_schedule_replays_bit_exact():
    prog, sel, graph = _gemm_fixture()
    space = SearchSpace.for_graph(graph)
    o = hill_climb(space, CostModelEvaluator(sel, graph), trials=8, seed=0)
    rep = validate_selection(prog, sel, graph, ParamApproach(o.best_config))
    assert rep.exact
    assert rep.max_abs_err == 0.0


def test_validation_multidevice_graph():
    graph = paper_accelerator(2)
    prog = K.gru_cell(4, 16, 12)
    sel = select_instructions(prog, I.tpu_isa())
    space = SearchSpace.for_graph(graph)
    o = STRATEGIES["evolve"](space, CostModelEvaluator(sel, graph),
                             trials=6, seed=2)
    rep = validate_selection(prog, sel, graph, ParamApproach(o.best_config))
    assert rep.ok       # f32-ulp summation grouping allowed for fused gates


# --------------------------------------------------------------------------- #
# CLI + benchmark harness smoke (subprocesses, as CI runs them)
# --------------------------------------------------------------------------- #


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def test_tune_cli_smoke(tmp_path):
    cache = tmp_path / "cache.json"
    report = tmp_path / "report.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.search.tune", "--suite", "gemm",
         "--limit", "1", "--trials", "5", "--backend", "cost",
         "--cache", str(cache), "--json", str(report)],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    data = json.loads(cache.read_text())
    assert len(data["records"]) == 1
    rows = json.loads(report.read_text())["rows"]
    assert rows[0]["tuned_cost_s"] <= rows[0]["greedy_cost_s"]
    assert rows[0]["exact"] is True


def test_bench_run_unknown_suite_exits_2():
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nosuch"],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=120)
    assert res.returncode == 2
    assert "available" in res.stderr
    assert "mapper" in res.stderr


def test_bench_run_json_output(tmp_path):
    out = tmp_path / "BENCH_mapper.json"
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "mapper",
         "--json", str(out)],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    data = json.loads(out.read_text())
    assert data["failures"] == 0
    assert data["rows"] and all("suite" in r and "us_per_call" in r
                                for r in data["rows"])
