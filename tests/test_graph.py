"""Tests for ``repro.graph`` — graph IR, tracing, fusion, and graph-level
compilation to a ``CompiledGraph`` artifact.

The load-bearing contract: the traced block's interpreted output, its
per-node *executed* replay, and the plain-jax reference
(``repro.models.traceable``) are **bit-exact** — fused or not — because
every traced op is exact over the ternary oracle inputs in any summation
order (see ``repro.graph.trace``).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.compile.cache import ArtifactCache
from repro.compile.driver import clear_memo
from repro.configs.registry import get_trace_config
from repro.graph import (CompiledGraph, GraphError, KernelGraph,
                         assert_exactness_bound, block_inputs, compile_graph,
                         edge_bytes, fuse_epilogues, interpret_graph,
                         plan_placement, trace_block, trace_gru_chain)
from repro.models.traceable import block_reference

SEQ = 8


@pytest.fixture(scope="module")
def cfg():
    return get_trace_config("olmo-1b")


@pytest.fixture(scope="module")
def unfused(cfg):
    return trace_block(cfg, seq_len=SEQ)


@pytest.fixture(scope="module")
def fused(cfg):
    return fuse_epilogues(trace_block(cfg, seq_len=SEQ))


@pytest.fixture(scope="module")
def oracle(cfg, unfused):
    inputs = block_inputs(unfused)
    return inputs, block_reference(inputs, cfg, SEQ)


@pytest.fixture(scope="module")
def compiled(fused):
    g, decisions = fused
    return compile_graph(g, use_cache=False, decisions=decisions)


@pytest.fixture(scope="module")
def compiled_unfused(unfused):
    return compile_graph(unfused, use_cache=False)


# --------------------------------------------------------------------------- #
# Graph IR
# --------------------------------------------------------------------------- #


def test_graph_json_round_trip(unfused):
    d = json.loads(json.dumps(unfused.to_dict()))
    rt = KernelGraph.from_dict(d)
    assert rt.fingerprint() == unfused.fingerprint()
    assert len(rt.nodes) == len(unfused.nodes)
    assert rt.nodes[0].program.statements == unfused.nodes[0].program.statements


def test_tracer_deterministic(cfg, unfused):
    again = trace_block(cfg, seq_len=SEQ)
    assert again.fingerprint() == unfused.fingerprint()


def test_validate_rejects_topological_violations(unfused):
    g = KernelGraph.from_dict(unfused.to_dict())
    g.nodes = (g.nodes[-1],) + g.nodes[:-1]
    with pytest.raises(GraphError):
        g.validate()


def test_validate_rejects_shape_mismatch(unfused):
    d = unfused.to_dict()
    d["tensors"][0]["shape"] = [3, 5]
    with pytest.raises(GraphError):
        KernelGraph.from_dict(d)


def test_trace_rejects_non_power_of_4_head_dim(cfg):
    with pytest.raises(GraphError):
        trace_block(cfg.scaled(n_heads=4, head_dim=8), seq_len=SEQ)


# --------------------------------------------------------------------------- #
# Oracle exactness
# --------------------------------------------------------------------------- #


def test_interpreted_bit_exact_vs_jax(unfused, oracle):
    inputs, ref = oracle
    out = interpret_graph(unfused, inputs)
    assert all(np.array_equal(v, ref) for v in out.values())


def test_exactness_bound_holds(unfused, oracle):
    inputs, _ = oracle
    env = interpret_graph(unfused, inputs, return_all=True)
    worst = assert_exactness_bound(env)
    assert 0 < worst < float(1 << 24)


# --------------------------------------------------------------------------- #
# Fusion
# --------------------------------------------------------------------------- #


def test_fusion_folds_every_elementwise_node(unfused, fused):
    g, decisions = fused
    assert len(g.nodes) < len(unfused.nodes)
    assert not any(n.kind == "elementwise" for n in g.nodes)
    assert len(decisions) == len(unfused.nodes) - len(g.nodes)


def test_fusion_reduces_edge_bytes(unfused, fused):
    g, decisions = fused
    assert edge_bytes(g) < edge_bytes(unfused)
    saved = sum(d.saved_bytes for d in decisions)
    assert edge_bytes(unfused) - edge_bytes(g) == saved


def test_fused_bit_exact(fused, oracle):
    g, _ = fused
    inputs, ref = oracle
    out = interpret_graph(g, inputs)
    assert all(np.array_equal(v, ref) for v in out.values())


def test_fusion_deterministic(cfg, fused):
    g, _ = fused
    again, _ = fuse_epilogues(trace_block(cfg, seq_len=SEQ))
    assert again.fingerprint() == g.fingerprint()


# --------------------------------------------------------------------------- #
# Graph compilation
# --------------------------------------------------------------------------- #


def test_dedupe_at_least_2x(compiled_unfused):
    s = compiled_unfused.stats
    assert s["unique_programs"] < s["nodes"]
    assert s["dedupe"] >= 2.0
    assert s["gemm_nodes"] >= 2 * s["unique_gemm_programs"]


def test_gru_chain_dedupes_to_one_compile():
    cg = compile_graph(trace_gru_chain(), use_cache=False)
    assert cg.stats == {**cg.stats, "nodes": 4, "unique_programs": 1}
    assert cg.stats["dedupe"] == 4.0


def test_executed_bit_exact(compiled, compiled_unfused, oracle):
    inputs, ref = oracle
    for cg in (compiled, compiled_unfused):
        out = cg.execute(inputs)
        assert all(np.array_equal(v, ref) for v in out.values())


def test_fusion_improves_makespan_and_nodes(compiled, compiled_unfused):
    assert compiled.makespan < compiled_unfused.makespan
    assert compiled.edge_bytes < compiled_unfused.edge_bytes


def test_artifact_cache_second_compile_all_hits(fused, tmp_path):
    g, _ = fused
    cache = ArtifactCache(os.fspath(tmp_path / "arts.json"))
    cold = compile_graph(g, cache=cache)
    assert cold.stats["fresh_compiles"] == cold.stats["unique_programs"]
    clear_memo()
    warm = compile_graph(g, cache=ArtifactCache(cache.path))
    assert warm.stats["fresh_compiles"] == 0
    assert warm.stats["cache_hits"] == warm.stats["unique_programs"]
    assert warm.makespan == cold.makespan


def test_compiled_graph_json_round_trip(compiled, oracle):
    inputs, ref = oracle
    d = json.loads(json.dumps(compiled.to_dict()))
    rt = CompiledGraph.from_dict(d)
    assert rt.graph_fp == compiled.graph_fp
    assert rt.makespan == compiled.makespan
    assert rt.stats == compiled.stats
    rt.ensure_kernels(use_cache=False)
    out = rt.execute(inputs)
    assert all(np.array_equal(v, ref) for v in out.values())


# --------------------------------------------------------------------------- #
# Placement
# --------------------------------------------------------------------------- #


def test_placement_all_resident_under_big_budget(unfused):
    pl = plan_placement(unfused, 1 << 26)
    assert not pl.spilled()
    assert pl.peak_vmem <= pl.budget


def test_placement_spills_under_tiny_budget(unfused):
    pl = plan_placement(unfused, 1024)
    assert pl.spilled()
    assert pl.peak_vmem <= 1024


def test_spilling_costs_makespan_and_hbm(unfused, compiled_unfused):
    spilled = compile_graph(unfused, use_cache=False, vmem_budget=1024)
    assert spilled.makespan > compiled_unfused.makespan
    assert spilled.hbm_bytes > compiled_unfused.hbm_bytes


def test_verify_placement_catches_over_budget(unfused):
    from repro.verify import verify_graph, verify_placement
    assert verify_graph(unfused) == []
    pl = plan_placement(unfused, 1 << 26)
    assert verify_placement(unfused, pl.locations, pl.budget) == []
    bad = {t: "vmem" for t in pl.locations}
    diags = verify_placement(unfused, bad, 1)
    assert any(d.rule == "gra.capacity" for d in diags)


# --------------------------------------------------------------------------- #
# Verify layer + mutation harness
# --------------------------------------------------------------------------- #


def test_graph_mutations_all_caught():
    from repro.verify.mutate import MUTATIONS, run_mutation
    graph_muts = [n for n, (_, kind, _) in MUTATIONS.items()
                  if kind == "graph"]
    assert len(graph_muts) >= 3
    for name in graph_muts:
        res = run_mutation(name)
        assert res.caught, f"{name}: expected {res.expected}, got {res.rules}"


def test_graph_verify_suite_clean(capsys):
    from repro.verify.cli import main
    assert main(["--suite", "graph"]) == 0


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #


def test_cli_json_payload(tmp_path):
    from repro.graph.__main__ import main
    report = tmp_path / "report.json"
    rc = main(["--validate", "--json", os.fspath(report)])
    assert rc == 0
    payload = json.loads(report.read_text())
    assert payload["schema"] == 1
    assert payload["failures"] == 0
    assert payload["validated"] is True
    assert payload["stats"]["dedupe"] > 1.0
    assert payload["makespan"] > 0


def test_cli_expect_cached_fails_cold(tmp_path):
    from repro.graph.__main__ import main
    clear_memo()
    rc = main(["--cache", os.fspath(tmp_path / "arts.json"),
               "--expect-cached"])
    assert rc == 1
