"""Trip-count-aware HLO analysis tests — the roofline's foundation.

The analyzer must recover loop multipliers, dot FLOPs, fusion-granularity
bytes, in-place DUS traffic and collective operand bytes from optimized HLO
text.  Synthetic-module tests pin the parser; a live grad-of-scan compile
pins the end-to-end count.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_flops import (_shape_bytes, analyse_hlo,
                                    parse_computations)

SYNTH = """
HloModule synth

%body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %d = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[64,64]) tuple(%it, %ar)
}

%cond.1 (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%it, %c), direction=LT
}

ENTRY %main.1 (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %iv = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%iv, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert _shape_bytes("pred[]") == 1


def test_parse_computations_synthetic():
    comps = parse_computations(SYNTH)
    assert set(comps) == {"body.1", "cond.1", "main.1"}
    assert any(i.op == "while" for i in comps["main.1"].instrs)


def test_trip_count_and_collectives_synthetic():
    st = analyse_hlo(SYNTH)
    assert st.while_trip_counts == [12]
    assert st.flops == 12 * 2 * 64 ** 3            # dot x trip count
    assert st.collective_counts == {"all-reduce": 12}
    assert st.collective_bytes == 12 * 64 * 64 * 4


def test_live_grad_of_scan_exact():
    def f(xs, w):
        def body(c, x):
            return c @ w + x, None
        out, _ = jax.lax.scan(body, xs[0], xs)
        return (out.astype(jnp.float32) ** 2).sum()

    lowered = jax.jit(jax.grad(f, argnums=(0, 1))).lower(
        jax.ShapeDtypeStruct((16, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32))
    st = analyse_hlo(lowered.compile().as_text())
    expected = 16 * (2 * 64 ** 3) * 3              # fwd + 2 bwd dots x 16
    assert st.flops == pytest.approx(expected, rel=0.02)
    assert sorted(st.while_trip_counts) == [16, 16]


def test_dus_counts_slice_not_buffer():
    text = """
HloModule dus

ENTRY %main.2 (buf: f32[1024,1024], upd: f32[1,1024]) -> f32[1024,1024] {
  %buf = f32[1024,1024]{1,0} parameter(0)
  %upd = f32[1,1024]{1,0} parameter(1)
  %i = s32[] constant(5)
  %z = s32[] constant(0)
  ROOT %d = f32[1024,1024]{1,0} dynamic-update-slice(%buf, %upd, %i, %z)
}
"""
    st = analyse_hlo(text)
    # ~2 x update bytes (+ scalar indices), NOT ~2 x 4MB buffer
    assert 2 * 1024 * 4 <= st.bytes_accessed < 2 * 1024 * 4 + 64
