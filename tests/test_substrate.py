"""Training-substrate tests: optimizer, data determinism, checkpointing,
fault-tolerance runtime, end-to-end smoke training with restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM, make_source
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state, schedule
from repro.runtime.fault_tolerance import StragglerDetector, TrainingRuntime


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, metrics = apply_updates(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5     # raw norm reported


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #


def test_data_deterministic_per_step():
    cfg = DataConfig(seed=3, global_batch=4, seq_len=32)
    s1 = SyntheticLM(cfg, vocab_size=101)
    s2 = SyntheticLM(cfg, vocab_size=101)
    np.testing.assert_array_equal(s1.batch(7)["tokens"], s2.batch(7)["tokens"])
    assert not np.array_equal(s1.batch(7)["tokens"], s1.batch(8)["tokens"])


def test_data_in_vocab_range():
    cfg = DataConfig(seed=0, global_batch=8, seq_len=64)
    src = SyntheticLM(cfg, vocab_size=50)
    toks = src.batch(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < 50
    assert toks.shape == (8, 64)


def test_token_file_source(tmp_path):
    path = tmp_path / "toks.bin"
    arr = np.arange(10_000, dtype=np.int32) % 97
    arr.tofile(path)
    cfg = DataConfig(seed=1, global_batch=4, seq_len=16, source="file",
                     path=str(path))
    src = make_source(cfg, get_smoke_config("olmo-1b"))
    b = src.batch(3)["tokens"]
    assert b.shape == (4, 16)
    np.testing.assert_array_equal(src.batch(3)["tokens"], b)


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #


def make_tree(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": {"c": jnp.arange(3) * 0 + int(x)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = make_tree(2.0)
    ck.save(10, tree)
    assert ck.latest_step() == 10
    restored, step = ck.restore(10, jax.eval_shape(lambda: tree))
    assert step == 10
    np.testing.assert_allclose(restored["a"], np.full((4, 4), 2.0))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, make_tree(float(s)), blocking=False)
    ck.wait()
    ck._gc()
    assert ck.committed_steps() == [3, 4]


def test_checkpoint_atomic_commit_marker(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, make_tree())
    assert os.path.exists(tmp_path / "step_5.COMMITTED")
    # uncommitted junk is invisible
    os.makedirs(tmp_path / "step_99", exist_ok=True)
    assert ck.latest_step() == 5


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic restore: apply different shardings than the writer used."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((8, 4))}
    ck.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = ck.restore(1, jax.eval_shape(lambda: tree), shardings=sh)
    assert restored["w"].sharding == sh["w"]


# --------------------------------------------------------------------------- #
# runtime: straggler detection + restart
# --------------------------------------------------------------------------- #


def test_straggler_detector():
    d = StragglerDetector(alpha=0.5, threshold=2.0)
    assert not d.observe(0, 1.0)
    assert not d.observe(1, 1.1)
    assert d.observe(2, 10.0)
    assert d.slow_steps[0][0] == 2


def test_runtime_restart_is_exact(tmp_path):
    """Crash mid-run, restore, and land on the exact same final state."""
    ckpt_a = Checkpointer(str(tmp_path / "a"))
    ckpt_b = Checkpointer(str(tmp_path / "b"))

    def step_fn(carry, batch):
        new = jax.tree.map(lambda x: x + batch["tokens"].sum(), carry)
        return new, {"loss": jnp.zeros(())}

    def batch_fn(s):
        rng = np.random.default_rng(s)
        return {"tokens": jnp.asarray(rng.integers(0, 5, size=(2, 2)))}

    init = {"w": jnp.zeros(())}

    # uninterrupted reference
    rt = TrainingRuntime(ckpt_a, save_every=3, async_save=False)
    ref = rt.run(init, step_fn, batch_fn, 10)

    # crash at step 7, restart from checkpoint
    rt1 = TrainingRuntime(ckpt_b, save_every=3, async_save=False)
    with pytest.raises(RuntimeError):
        rt1.run(init, step_fn, batch_fn, 10, inject_fault_at=7)
    rt2 = TrainingRuntime(ckpt_b, save_every=3, async_save=False)
    restored = rt2.try_restore(jax.eval_shape(lambda: init))
    assert restored is not None
    carry, step = restored
    assert step == 6
    out = rt2.run(carry, step_fn, batch_fn, 10)
    np.testing.assert_allclose(out["w"], ref["w"])


# --------------------------------------------------------------------------- #
# end-to-end smoke training via the real driver
# --------------------------------------------------------------------------- #


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "olmo-1b", "--smoke", "--steps", "25",
                   "--batch", "8", "--seq", "64",
                   "--ckpt-dir", str(tmp_path)])
    assert losses[-1] < losses[0] - 0.3
