"""Cross-backend portability: the gpu_sm target and its isolation.

Pins down the second-hardware-target contract — the modeled GPU
SystemGraph's structure, the gpu lowering config, bit-exact oracle replay
and tuned <= greedy off the tpu path, and (the load-bearing part) that
artifact/tuning/model cache keys can NEVER collide across targets.
"""
from __future__ import annotations

import importlib.util
import os

import pytest

from repro.compile import compile_conv, compile_gemm, compile_gru
from repro.compile.cache import ArtifactCache, artifact_key
from repro.core.approach import GreedyApproach
from repro.core import kernels_ir as K
from repro.core.sysgraph import (GPU_SMEM_BYTES, GPU_SMS_PER_CLUSTER,
                                 TARGET_ALIASES, TARGETS, gpu_sm,
                                 paper_accelerator, resolve_target, tpu_v5e)
from repro.search.model import model_key
from repro.search.space import sysgraph_fingerprint, tuning_key
from repro.search.tune import build_cases, tune_case
from repro.verify import verify_artifact_dict
from repro.verify.mutate import MUTATIONS

CLUSTER_SMEM = GPU_SMS_PER_CLUSTER * GPU_SMEM_BYTES


# --------------------------------------------------------------------------- #
# The modeled GPU SystemGraph
# --------------------------------------------------------------------------- #


def test_gpu_sm_structure():
    g = gpu_sm(8)
    assert g.family == "gpu"
    assert g.memories["host"].role == "host"
    assert g.memories["hbm0"].role == "global"
    smems = [m for m in g.memories.values() if m.role == "staging"]
    assert len(smems) == 8
    assert all(m.capacity == CLUSTER_SMEM for m in smems)
    assert len(g.computes) == 8
    for c in g.computes.values():
        assert c.matmul_tile == (256, 256, 32)


def test_gpu_sm_staging_budget_reads_shared_memory():
    # The scheduler's tile budget comes from the graph's staging tier, so
    # the gpu budget is cluster shared memory, not TPU VMEM.
    assert gpu_sm(8).staging_budget() == CLUSTER_SMEM // 3
    assert tpu_v5e(1).staging_budget() == (128 << 20) // 3
    assert gpu_sm(8).min_matmul_tile() == (256, 256, 32)
    assert tpu_v5e(1).min_matmul_tile() == (128, 128, 128)


def test_gpu_sm_nvlink_fabric_edges():
    # n > 1 gets cluster-to-cluster ring links; n == 1 has none.
    multi = gpu_sm(4)
    links = [e for e in multi.edges
             if e.src.startswith("smem") and e.dst.startswith("smem")]
    assert links, "expected NVLink-class smem<->smem edges for n_sms > 1"
    single = gpu_sm(1)
    assert not [e for e in single.edges
                if e.src.startswith("smem") and e.dst.startswith("smem")]


def test_target_registry_and_aliases():
    assert set(TARGETS) == {"tpu_v5e", "gpu_sm", "paper"}
    assert resolve_target("gpu").name == resolve_target("gpu_sm").name
    assert resolve_target("v5e").name == resolve_target("tpu_v5e").name
    assert TARGET_ALIASES["tpu"] == "tpu_v5e"
    with pytest.raises(KeyError):
        resolve_target("tpu_v9000")


# --------------------------------------------------------------------------- #
# Cross-target cache isolation (fingerprints and keys)
# --------------------------------------------------------------------------- #


def test_sysgraph_fingerprints_distinct_and_stable():
    fps = {sysgraph_fingerprint(g)
           for g in (tpu_v5e(1), gpu_sm(8), paper_accelerator(2))}
    assert len(fps) == 3
    assert sysgraph_fingerprint(gpu_sm(8)) == sysgraph_fingerprint(gpu_sm(8))
    assert (sysgraph_fingerprint(gpu_sm(8))
            != sysgraph_fingerprint(gpu_sm(4)))


def test_cache_keys_never_collide_across_targets():
    prog = K.matmul(256, 128, 192)
    tpu, gpu = tpu_v5e(1), gpu_sm(8)
    assert (artifact_key(prog, tpu, GreedyApproach())
            != artifact_key(prog, gpu, GreedyApproach()))
    assert tuning_key(prog, tpu) != tuning_key(prog, gpu)
    assert model_key("gemm", tpu) != model_key("gemm", gpu)


def test_tpu_warmed_artifact_cache_misses_under_gpu(tmp_path):
    cache = ArtifactCache(str(tmp_path / "arts.json"))
    art = compile_gemm(256, 128, 192, graph=tpu_v5e(1), cache=cache)
    assert art.key in set(cache.keys())
    gpu_key = artifact_key(K.matmul(256, 128, 192), gpu_sm(8),
                           GreedyApproach())
    assert gpu_key not in set(cache.keys())
    assert cache.lookup(gpu_key) is None


# --------------------------------------------------------------------------- #
# GPU compiles: lowering config, oracle replay, tuned <= greedy
# --------------------------------------------------------------------------- #


def test_gpu_gemm_lowering_config():
    art = compile_gemm(512, 256, 192, graph=gpu_sm(2), use_cache=False)
    low = art.to_dict()["lowering"]
    assert low["kind"] == "pallas_gpu_gemm"
    bm, bn, bk = low["block"]
    assert low["smem_bytes"] == 4 * (bm * bk + bk * bn + bm * bn)
    assert 0 < low["smem_bytes"] <= CLUSTER_SMEM
    assert all(x >= 1 for x in low["grid"])


def test_tpu_gemm_lowering_unchanged():
    art = compile_gemm(512, 256, 192, graph=tpu_v5e(1), use_cache=False)
    assert art.to_dict()["lowering"]["kind"] == "pallas_gemm"


def test_gpu_compiles_every_smoke_kernel():
    g = gpu_sm(2)
    arts = [compile_gemm(256, 128, 192, graph=g, use_cache=False),
            compile_gru(4, 32, graph=g, use_cache=False),
            compile_conv(graph=g, use_cache=False, batch=2, h=6, w=6,
                         kh=3, kw=3, cin=8, cout=8)]
    for art in arts:
        assert art.cost > 0
        assert not verify_artifact_dict(art.to_dict())


def test_gpu_tune_bit_exact_and_tuned_le_greedy():
    case = build_cases("gemm", limit=1)[0]
    rep = tune_case(case, gpu_sm(8), "hillclimb", 6, 0, "cost",
                    validate=True)
    assert rep.ok
    assert rep.tuned_cost <= rep.greedy_cost
    assert rep.validation is not None and rep.validation.exact


# --------------------------------------------------------------------------- #
# Verifier: the art.lowering-target rule and the gpu mutation classes
# --------------------------------------------------------------------------- #


def test_lowering_target_rule_fires_on_crossed_configs():
    base = {"key": "k", "cost": 1.0, "instrs": [],
            "graph_name": "tpu_v5e_x1",
            "lowering": {"kind": "pallas_gpu_gemm", "block": [8, 8, 8],
                         "grid": [1, 1, 1], "smem_bytes": 768}}
    assert any(d.rule == "art.lowering-target"
               for d in verify_artifact_dict(base))
    crossed = dict(base, graph_name="gpu_sm_x8",
                   lowering={"kind": "pallas_gemm", "block": [8, 8, 8],
                             "grid": [1, 1, 1]})
    assert any(d.rule == "art.lowering-target"
               for d in verify_artifact_dict(crossed))
    missing_smem = dict(base, graph_name="gpu_sm_x8",
                        lowering={"kind": "pallas_gpu_gemm",
                                  "block": [8, 8, 8], "grid": [1, 1, 1]})
    assert any(d.rule == "art.lowering-target"
               for d in verify_artifact_dict(missing_smem))


def test_gpu_mutation_classes_registered():
    # The parametrized harness in test_verify.py runs them; here we pin the
    # registry so the classes cannot silently vanish.
    assert MUTATIONS["gpu-smem-capacity"][0] == "sch.capacity"
    assert MUTATIONS["gpu-wrong-lowering"][0] == "art.lowering-target"


# --------------------------------------------------------------------------- #
# The perf gate keys per target
# --------------------------------------------------------------------------- #


def _load_bench_run():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_baseline_comparison_keys_rows_by_target():
    run = _load_bench_run()
    baseline = {"rows": [
        {"suite": "portability", "name": "port_gemm", "us_per_call": 10.0,
         "target": "tpu_v5e"},
        {"suite": "portability", "name": "port_gemm", "us_per_call": 50.0,
         "target": "gpu_sm"},
    ]}
    # Same name, per-target numbers: each row gates against its own target.
    records = [
        {"suite": "portability", "name": "port_gemm", "us_per_call": 10.0,
         "target": "tpu_v5e"},
        {"suite": "portability", "name": "port_gemm", "us_per_call": 50.0,
         "target": "gpu_sm"},
    ]
    assert run.compare_to_baseline(records, baseline, 5.0) == []
    # A gpu row must never satisfy (or be gated by) the tpu baseline: drop
    # the tpu record and the tpu baseline row reports missing even though a
    # same-named gpu row exists.
    violations = run.compare_to_baseline(records[1:], baseline, 5.0)
    assert len(violations) == 1
    assert "port_gemm@tpu_v5e" in violations[0]
    assert "missing" in violations[0]
    # And a slow gpu row is caught under its own target label.
    slow = [dict(records[0]),
            dict(records[1], us_per_call=80.0)]
    violations = run.compare_to_baseline(slow, baseline, 5.0)
    assert len(violations) == 1
    assert "port_gemm@gpu_sm" in violations[0]
