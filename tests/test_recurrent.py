"""Recurrent scheduling tests (paper Section 3.6): priming / recursive /
finish streams, steady-state copy elimination, numeric equivalence."""
import numpy as np
import pytest

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.ir import interpret
from repro.core.isel import select_instructions
from repro.core.recurrent import execute_recurrent, schedule_recurrent
from repro.core.sysgraph import paper_accelerator, tpu_v5e

ISA = I.tpu_isa()
GRU_WEIGHTS = ["Wr", "Ur", "Wz", "Uz", "Wn", "Un", "br", "bz", "bnx", "bnh"]


def make_gru(B=4, H=16, E=12):
    prog = K.gru_cell(B, H, E)
    sel = select_instructions(prog, ISA)
    assert sel.complete
    return prog, sel


def ref_gru(prog, weights, h0, xs):
    h = np.asarray(h0, dtype=np.float64)
    for x in xs:
        h = interpret(prog, {**weights, "H": h, **x})["Hout"].astype(np.float64)
    return h


@pytest.mark.parametrize("graph_fn,steps", [
    (lambda: paper_accelerator(2), 6),
    (lambda: tpu_v5e(1), 4),
    (lambda: tpu_v5e(2), 5),
])
def test_recurrent_gru_matches_oracle(graph_fn, steps):
    prog, sel = make_gru()
    rng = np.random.default_rng(5)
    rs = schedule_recurrent(sel, graph_fn(), carry={"Hout": "H"},
                            streamed=("X",))
    w = {n: rng.uniform(-0.5, 0.5, size=prog.buffer(n).shape)
         for n in GRU_WEIGHTS}
    h0 = rng.uniform(-0.5, 0.5, size=prog.buffer("H").shape)
    xs = [{"X": rng.uniform(-0.5, 0.5, size=prog.buffer("X").shape)}
          for _ in range(steps)]
    got = execute_recurrent(rs, sel, xs, {**w, "H": h0})["Hout"]
    ref = ref_gru(prog, w, h0, xs)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_recursive_stream_elides_weight_copies():
    """The paper's persistent-weights win: the steady-state stream must not
    re-fetch weights that stayed resident after priming."""
    prog, sel = make_gru()
    rs = schedule_recurrent(sel, paper_accelerator(2), carry={"Hout": "H"},
                            streamed=("X",))
    def weight_copies(s):
        return sum(1 for op in s.ops if op.kind == "copy"
                   and op.region.buffer in GRU_WEIGHTS)
    assert weight_copies(rs.prime) > 0
    assert weight_copies(rs.recursive) == 0
    assert rs.recursive.makespan < rs.prime.makespan


def test_total_time_formula():
    prog, sel = make_gru(2, 8, 8)
    rs = schedule_recurrent(sel, tpu_v5e(1), carry={"Hout": "H"},
                            streamed=("X",))
    t10 = rs.total_time(10)
    assert t10 == pytest.approx(rs.prime.makespan
                                + 8 * rs.recursive.makespan
                                + rs.finish.makespan)


def test_single_step_runs_prime_and_finish():
    prog, sel = make_gru(2, 8, 8)
    rs = schedule_recurrent(sel, tpu_v5e(1), carry={"Hout": "H"},
                            streamed=("X",))
    rng = np.random.default_rng(0)
    w = {n: rng.uniform(-0.5, 0.5, size=prog.buffer(n).shape)
         for n in GRU_WEIGHTS}
    h0 = rng.uniform(-0.5, 0.5, size=prog.buffer("H").shape)
    xs = [{"X": rng.uniform(-0.5, 0.5, size=prog.buffer("X").shape)}
          for _ in range(2)]
    got = execute_recurrent(rs, sel, xs, {**w, "H": h0})["Hout"]
    np.testing.assert_allclose(got, ref_gru(prog, w, h0, xs),
                               rtol=1e-4, atol=1e-5)
