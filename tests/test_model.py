"""Learned-cost-model tests: feature extraction is deterministic across
program families, seeded ridge training reproduces bit-identical predictions,
model artifacts survive a JSON round-trip, surrogate-guided search keeps the
baseline anchor (never worse than greedy) and degrades gracefully to the
cost backend when no/insufficient training data exists, and the benchmark
perf gate catches regressions, missing rows, and vacuous (empty) suites."""
import importlib.util
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.compile.features import (feature_dict, feature_names,
                                    feature_vector, program_family,
                                    role_extents)
from repro.core import kernels_ir as K
from repro.core.approach import GreedyApproach
from repro.core.scheduler import schedule
from repro.core.sysgraph import paper_accelerator, tpu_v5e
from repro.search.cache import TuningCache, TuningRecord, set_default_cache
from repro.search.evaluate import CostModelEvaluator, LearnedEvaluator
from repro.search.model import MIN_TRAIN_SAMPLES, ModelStore, fresh_labels, harvest_cache, model_key, predict_gemm_block, set_default_store, train_family, train_suites
from repro.search.space import SearchSpace, tuning_key
from repro.search.strategies import hill_climb, surrogate_search
from repro.search.tune import build_cases, tune_case

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _case(name_prefix="gemm_256x192x130"):
    for c in build_cases("gemm") + build_cases("conv"):
        if c.name.startswith(name_prefix):
            return c
    raise AssertionError(name_prefix)


def _small_gemm_case():
    from repro.compile import gemm_selection
    from repro.search.tune import TuneCase
    prog, sel = gemm_selection(256, 192, 130)
    return TuneCase("gemm_256x192x130", prog, sel, prog, prog, sel,
                    gemm_shape=(256, 192, 130))


# --------------------------------------------------------------------------- #
# feature extraction
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("prog", [
    K.matmul(256, 192, 130),
    K.gru_cell(8, 64, 64),
    K.conv2d(2, 8, 8, 3, 3, 8, 16),
])
def test_features_finite_and_deterministic(prog):
    graph = tpu_v5e(1)
    cfg = {"tile_i": 256, "tile_k": None, "unroll": "red_major",
           "vmem_frac": 0.5}
    d1 = feature_dict(cfg, prog, graph)
    d2 = feature_dict(cfg, prog, graph)
    assert d1 == d2
    assert all(np.isfinite(v) for v in d1.values())
    names = feature_names(prog, graph)
    assert names == tuple(d1)           # stable ordering = model schema
    v = feature_vector(cfg, prog, graph, names)
    assert v.shape == (len(names),)


def test_feature_names_identical_across_programs_and_graphs():
    """One schema for every family/machine — family models share code."""
    n1 = feature_names(K.matmul(64, 64, 64), tpu_v5e(1))
    n2 = feature_names(K.gru_cell(4, 16, 16), paper_accelerator(2))
    assert n1 == n2


def test_program_family_strips_shapes():
    assert program_family(K.matmul(64, 64, 64)) == "matmul"
    assert program_family(K.matmul(128, 256, 512)) == "matmul"
    assert program_family("gru_cell_16x256") == "gru_cell"
    assert program_family("conv2d") == "conv2d"


def test_role_extents_from_conv_selection():
    """Conv extractions map MXU roles onto fused axes; the role extents must
    come from the mapping, not from axis-name guessing."""
    case = _case("conv3x3")
    roles = role_extents(case.selection)
    assert set(roles) == {"i", "j", "k"}
    assert all(v > 0 for v in roles.values())
    # tile-cap features must bind against those extents
    d_free = feature_dict({"tile_j": 4096}, case.program, tpu_v5e(1),
                          roles=roles)
    d_bind = feature_dict({"tile_j": 128}, case.program, tpu_v5e(1),
                          roles={**roles, "j": 4096})
    assert d_free["tile_j_binds"] == 0.0
    assert d_bind["tile_j_binds"] == 1.0
    assert d_bind["tile_j_excess"] > 0.0


def test_config_features_tolerate_junk_configs():
    d = feature_dict({"tile_i": "wide", "unroll": "nope", "vmem_frac": "x"},
                     K.matmul(64, 64, 64), tpu_v5e(1))
    base = feature_dict({}, K.matmul(64, 64, 64), tpu_v5e(1))
    assert d == base                    # degrades exactly like ParamApproach


# --------------------------------------------------------------------------- #
# training: determinism, round-trip, insufficient data
# --------------------------------------------------------------------------- #


def _labeled_samples(n=32, seed=0):
    case = _small_gemm_case()
    return case, fresh_labels(case, tpu_v5e(1), n=n, seed=seed)


def test_fresh_labels_deterministic():
    _, s1 = _labeled_samples(24, seed=3)
    _, s2 = _labeled_samples(24, seed=3)
    assert [(sorted(s.config.items()), s.cost) for s in s1] == \
           [(sorted(s.config.items()), s.cost) for s in s2]


def test_train_predict_deterministic():
    case, samples = _labeled_samples(32)
    graph = tpu_v5e(1)
    key = model_key("matmul", graph)
    m1, met1 = train_family(key, "matmul", samples, graph, seed=7)
    m2, met2 = train_family(key, "matmul", samples, graph, seed=7)
    assert m1 is not None
    assert np.array_equal(m1.weights, m2.weights)
    assert met1 == met2
    cfg = {"tile_i": 256}
    assert m1.predict(cfg, case.program, graph) == \
        m2.predict(cfg, case.program, graph)


def test_model_json_roundtrip(tmp_path):
    case, samples = _labeled_samples(32)
    graph = tpu_v5e(1)
    model, _ = train_family(model_key("matmul", graph), "matmul", samples,
                            graph)
    path = str(tmp_path / "models.json")
    ModelStore(path).store(model)

    loaded = ModelStore(path).lookup(model.key)   # fresh instance, re-read
    assert loaded is not None
    assert loaded.names == model.names
    space = SearchSpace.for_graph(graph)
    import random
    rng = random.Random(0)
    for _ in range(10):
        cfg = space.random_config(rng)
        assert loaded.predict(cfg, case.program, graph) == pytest.approx(
            model.predict(cfg, case.program, graph), rel=0, abs=0)


def test_train_refuses_insufficient_samples():
    case, samples = _labeled_samples(8)
    graph = tpu_v5e(1)
    model, metrics = train_family(
        model_key("matmul", graph), "matmul",
        samples[:MIN_TRAIN_SAMPLES - 1], graph)
    assert model is None
    assert metrics["trained"] is False
    assert "required" in metrics["reason"]


def test_store_skips_schema_drifted_models(tmp_path):
    case, samples = _labeled_samples(32)
    graph = tpu_v5e(1)
    model, _ = train_family(model_key("matmul", graph), "matmul", samples,
                            graph)
    path = str(tmp_path / "models.json")
    store = ModelStore(path)
    store.store(model)
    raw = json.loads(open(path).read())
    raw["models"][0]["feature_schema"] = 999
    open(path, "w").write(json.dumps(raw))
    assert ModelStore(path).lookup(model.key) is None   # drift => no model


def test_harvest_cache_yields_winner_and_baseline(tmp_path):
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    o = hill_climb(space, ev, trials=8, seed=0)
    cache = TuningCache(str(tmp_path / "t.json"))
    cache.store(TuningRecord(
        key=tuning_key(case.program, graph, "cost"), config=o.best_config,
        cost=o.best_cost, baseline_cost=o.baseline_cost))
    samples = harvest_cache(cache, [case], graph)
    assert len(samples) == 2
    assert all(s.source == "cache" for s in samples)
    assert {s.cost for s in samples} == {o.best_cost, o.baseline_cost}


# --------------------------------------------------------------------------- #
# surrogate search: anchoring + fallback
# --------------------------------------------------------------------------- #


def _trained_evaluator(case, graph, tmp_path):
    samples = fresh_labels(case, graph, n=40, seed=0)
    model, _ = train_family(
        model_key(program_family(case.program), graph),
        program_family(case.program), samples, graph)
    store = ModelStore(str(tmp_path / "m.json"))
    store.store(model)
    return LearnedEvaluator.for_selection(case.selection, graph, store=store)


def test_surrogate_never_worse_than_greedy(tmp_path):
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    greedy = schedule(case.selection, graph, GreedyApproach()).makespan
    le = _trained_evaluator(case, graph, tmp_path)
    o = surrogate_search(space, ev, trials=10, seed=0,
                         predict=le.predictor)
    assert o.trials[0].config == space.baseline()   # baseline-first
    assert o.baseline_cost == greedy
    assert o.best_cost <= greedy
    assert o.strategy == "surrogate"


def test_surrogate_deterministic_under_fixed_seed(tmp_path):
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    le = _trained_evaluator(case, graph, tmp_path)
    o1 = surrogate_search(space, ev, trials=12, seed=5, predict=le.predictor)
    o2 = surrogate_search(space, ev, trials=12, seed=5, predict=le.predictor)
    assert [(sorted(t.config.items()), t.cost) for t in o1.trials] == \
           [(sorted(t.config.items()), t.cost) for t in o2.trials]


def test_surrogate_matches_hillclimb_at_half_budget(tmp_path):
    """The acceptance property at test scale: trained + anchored surrogate
    reaches hillclimb's best with half the real evaluations."""
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    hc = hill_climb(space, ev, trials=16, seed=0)

    cache = TuningCache(str(tmp_path / "t.json"))
    cache.store(TuningRecord(
        key=tuning_key(case.program, graph, "cost"), config=hc.best_config,
        cost=hc.best_cost, baseline_cost=hc.baseline_cost))
    samples = harvest_cache(cache, [case], graph)
    samples += fresh_labels(case, graph, n=40, seed=0,
                            anchors=[hc.best_config])
    model, _ = train_family(model_key("matmul", graph), "matmul", samples,
                            graph)
    sg = surrogate_search(space, ev, trials=8, seed=0,
                          predict=model.predictor(case.program, graph),
                          seeds=list(model.meta["anchors"]) or
                          [hc.best_config])
    assert sg.best_cost <= hc.best_cost
    assert sg.evaluations <= hc.evaluations // 2


def test_surrogate_without_model_falls_back_to_hillclimb():
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    o = surrogate_search(space, ev, trials=10, seed=0, predict=None)
    hc = hill_climb(space, ev, trials=10, seed=0)
    assert o.strategy == "surrogate:fallback-hillclimb"
    assert o.best_cost == hc.best_cost
    assert [t.cost for t in o.trials] == [t.cost for t in hc.trials]


def test_learned_evaluator_none_without_store_or_model(tmp_path):
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    assert LearnedEvaluator.for_selection(case.selection, graph,
                                          store=None) is None
    empty = ModelStore(str(tmp_path / "empty.json"))
    assert LearnedEvaluator.for_selection(case.selection, graph,
                                          store=empty) is None


def test_tune_case_learned_backend_degrades_to_cost(tmp_path):
    """--backend learned with no trained model must behave exactly like the
    cost backend (and still satisfy tuned <= greedy)."""
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    rep = tune_case(case, graph, "hillclimb", 6, 0, "learned",
                    validate=False,
                    model_store=ModelStore(str(tmp_path / "none.json")))
    assert rep.backend == "cost"
    assert rep.tuned_cost <= rep.greedy_cost


def test_train_suites_trains_and_stores(tmp_path):
    graph = tpu_v5e(1)
    cache = TuningCache(str(tmp_path / "t.json"))     # empty: fresh-only
    store = ModelStore(str(tmp_path / "m.json"))
    rows = train_suites("conv", graph, cache, store, samples_per_case=20,
                        seed=0)
    trained = [r for r in rows if r["trained"]]
    assert trained
    assert all("train_mae_log" in r for r in trained)
    assert len(store) == len(trained)


# --------------------------------------------------------------------------- #
# learned tuned_block path
# --------------------------------------------------------------------------- #


def test_predict_gemm_block_requires_store(tmp_path):
    assert predict_gemm_block(64, 64, 64, store=None) is None


def test_tuned_block_uses_model_on_cache_miss(tmp_path):
    case = _small_gemm_case()
    graph = tpu_v5e(1)
    samples = fresh_labels(case, graph, n=40, seed=0)
    model, _ = train_family(model_key("matmul", graph), "matmul", samples,
                            graph)
    store = ModelStore(str(tmp_path / "m.json"))
    store.store(model)

    from repro.kernels.gemm import tuned_block
    set_default_cache(TuningCache(str(tmp_path / "empty_cache.json")))
    try:
        without = tuned_block(512, 384, 640)
        set_default_store(store)
        with_model = tuned_block(512, 384, 640)
    finally:
        set_default_store(None)
        set_default_cache(None)
    assert without == (128, 128, 128)           # static default
    m, n, k = 512, 384, 640
    assert all(1 <= t for t in with_model)
    assert with_model[0] <= m and with_model[1] <= n and with_model[2] <= k
    blk = predict_gemm_block(m, n, k, store=store)
    assert with_model == blk                     # same decision path


# --------------------------------------------------------------------------- #
# benchmark perf gate (compare mode + empty-suite behavior)
# --------------------------------------------------------------------------- #


def _bench_run_module():
    spec = importlib.util.spec_from_file_location(
        "bench_run_for_test", os.path.join(ROOT, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_compare_to_baseline_detects_regressions_and_missing():
    mod = _bench_run_module()
    baseline = {"rows": [
        {"suite": "s", "name": "a", "us_per_call": 100.0},
        {"suite": "s", "name": "gone", "us_per_call": 10.0},
        {"suite": "s", "name": "err", "us_per_call": -1.0},
    ]}
    records = [
        {"suite": "s", "name": "a", "us_per_call": 109.0},
        {"suite": "s", "name": "err", "us_per_call": -1.0,
         "error": "still broken"},
        {"suite": "s", "name": "new", "us_per_call": 1.0},
    ]
    v = mod.compare_to_baseline(records, baseline, tolerance_pct=5.0)
    assert len(v) == 2
    assert any("gone" in x and "missing" in x for x in v)
    assert any("a" in x and "exceeds" in x for x in v)
    # within tolerance: no violations
    ok = mod.compare_to_baseline(
        [{"suite": "s", "name": "a", "us_per_call": 104.0},
         {"suite": "s", "name": "gone", "us_per_call": 10.0},
         {"suite": "s", "name": "err", "us_per_call": -1.0}],
        baseline, tolerance_pct=5.0)
    assert ok == []


def test_compare_flags_newly_erroring_row():
    mod = _bench_run_module()
    baseline = {"rows": [{"suite": "s", "name": "a", "us_per_call": 5.0}]}
    v = mod.compare_to_baseline(
        [{"suite": "s", "name": "a", "us_per_call": -1.0, "error": "boom"}],
        baseline, tolerance_pct=5.0)
    assert len(v) == 1 and "now errors" in v[0]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def test_bench_run_empty_suite_fails(tmp_path, monkeypatch):
    """A suite emitting zero rows must exit non-zero (the gate can't green
    on vacuous output)."""
    stub = tmp_path / "benchmarks"
    stub.mkdir()
    (stub / "bench_mapper.py").write_text("def run():\n    return []\n")
    run_py = open(os.path.join(ROOT, "benchmarks", "run.py")).read()
    (stub / "run.py").write_text(run_py)
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "mapper"],
        cwd=str(tmp_path), env=_env(), capture_output=True, text=True,
        timeout=120)
    assert res.returncode == 1
    assert "emitted no rows" in res.stderr


def test_committed_ci_baseline_is_valid():
    """The committed perf baseline must parse and carry gateable rows from
    the deterministic modeled-cost suites."""
    path = os.path.join(ROOT, "benchmarks", "baselines", "BENCH_ci.json")
    data = json.load(open(path))
    assert data["failures"] == 0
    suites = {r["suite"] for r in data["rows"]}
    assert suites == {"tuned", "fabric", "graph", "serve", "search",
                      "portability"}
    assert all(r["us_per_call"] > 0 for r in data["rows"])
    # Multi-target rows must carry their target label — the gate keys on
    # (suite, name, target) so backends never gate against each other.
    port = [r for r in data["rows"] if r["suite"] == "portability"]
    assert port and {r.get("target") for r in port} == {"tpu_v5e", "gpu_sm"}


# --------------------------------------------------------------------------- #
# CLI smoke (subprocess, as CI runs it)
# --------------------------------------------------------------------------- #


def test_model_cli_train_eval_roundtrip(tmp_path):
    cache = tmp_path / "cache.json"
    store = tmp_path / "models.json"
    report = tmp_path / "train.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.search.tune", "--suite", "gemm",
         "--limit", "1", "--trials", "6", "--cache", str(cache),
         "--no-validate"],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    res = subprocess.run(
        [sys.executable, "-m", "repro.search.model", "train", "--suite",
         "gemm", "--cache", str(cache), "--store", str(store),
         "--samples", "20", "--json", str(report)],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    rows = json.loads(report.read_text())["rows"]
    assert any(r["trained"] for r in rows)
    assert json.loads(store.read_text())["models"]
    res = subprocess.run(
        [sys.executable, "-m", "repro.search.tune", "--suite", "gemm",
         "--limit", "1", "--trials", "4", "--backend", "learned",
         "--model", str(store), "--cache", str(tmp_path / "c2.json"),
         "--no-validate", "--json", str(tmp_path / "r2.json")],
        cwd=ROOT, env=_env(), capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    row = json.loads((tmp_path / "r2.json").read_text())["rows"][0]
    assert row["strategy"] == "surrogate"
    assert row["tuned_cost_s"] <= row["greedy_cost_s"]
