"""Deterministic mapper tests — the paper's Section 6.1 mapping claims."""

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.mapper import map_program


def test_matmul_identity_mapping():
    h = K.matmul(64, 32, 16)
    r = map_program(h, I.mxu_matmul())
    assert r.ok
    m = r.best(h)
    assert dict(m.axis_map) == {"i": "i", "j": "j", "k": "k"}
    assert m.outer_axes == ()
    assert m.calls(h) == 1


def test_conv1d_maps_to_matmul_with_choices():
    """Paper Listing 5 -> Listing 6, including the multiple k-axis choices."""
    h = K.conv1d(2, 6, 3, 8, 4)
    r = map_program(h, I.mxu_matmul())
    assert r.ok
    kmaps = {dict(m.axis_map)["k"] for m in r.mappings}
    assert "ki" in kmaps           # the canonical contraction
    assert len(kmaps) >= 2         # "there were multiple choices for the k axis"
    best = r.best(h)
    assert dict(best.axis_map)["j"] == "ko"


def test_conv2d_maps_to_matmul():
    h = K.conv2d(1, 4, 4, 3, 3, 4, 8)
    r = map_program(h, I.mxu_matmul())
    assert r.ok
    assert dict(r.best(h).axis_map)["k"] == "ci"


def test_depthwise_maps_to_dot_not_matmul():
    """Depthwise conv mixes no channels: a matmul window must not exist, but
    the dot-product instruction covers it."""
    h = K.depthwise_conv2d(1, 4, 4, 3, 3, 8)
    assert not map_program(h, I.mxu_matmul()).ok
    assert map_program(h, I.vpu_dot()).ok


def test_separable_depthwise_fails_directly_with_feedback():
    h = K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8)
    r = map_program(h, I.mxu_matmul())
    assert not r.ok
    kinds = {f.kind for f in r.failures}
    assert kinds & {"not_extractable", "op_mismatch"}


def test_gru_yields_six_gemms():
    h = K.gru_cell(4, 8, 6)
    r = map_program(h, I.mxu_matmul(), max_results=64)
    windows = {m.stmt_map for m in r.mappings}
    assert len(windows) == 6


def test_gru_fused_windows():
    h = K.gru_cell(4, 8, 6)
    r = map_program(h, I.fused_matmul_bias("sigmoid"), max_results=64)
    windows = {m.stmt_map for m in r.mappings}
    assert len(windows) == 2      # the r and z gate chains


def test_attention_scores_map():
    h = K.attention_scores(2, 3, 4, 5, 8)
    r = map_program(h, I.mxu_matmul())
    assert r.ok
    m = r.best(h)
    assert set(m.outer_axes) == {"b", "h"}
    assert m.calls(h) == 6


def test_fixed_size_needle_extent_check():
    h = K.matmul(64, 64, 64)
    r = map_program(h, I.mxu_matmul128())
    assert not r.ok
    assert any(f.kind == "extent_mismatch" for f in r.failures)
    h2 = K.matmul(128, 128, 128)
    assert map_program(h2, I.mxu_matmul128()).ok


def test_temp_escape_rejected():
    """A needle temp may not bind a haystack buffer used outside the window."""
    from repro.core.ir import ProgramBuilder
    pb = ProgramBuilder("escape")
    i, j, k = pb.axes(i=4, j=4, k=4)
    A = pb.buffer("A", (4, 4))
    B = pb.buffer("B", (4, 4))
    C = pb.buffer("C", (4, 4))
    D = pb.buffer("D", (4, 4, 4))   # NOT a temp: escapes as an output
    pb.stmt(D[i, j, k], ":=", A[i, k])
    pb.stmt(D[i, j, k], "*=", B[k, j])
    pb.stmt(C[i, j], "+=", D[i, j, k])
    pb.output("C", "D")
    h = pb.build()
    r = map_program(h, I.mxu_matmul())
    assert not r.ok
    assert any(f.kind == "temp_escapes" for f in r.failures)


def test_mapping_calls_counts_window_domain_only():
    h = K.conv1d(2, 6, 3, 8, 4)
    best = map_program(h, I.mxu_matmul()).best(h)
    # best contraction: k->ki, outer (i or x choice, d): calls = extents product
    calls = best.calls(h)
    assert calls in (6, 12, 18, 48)
    assert calls == 6  # i->x (width), j->ko, k->ki leaves outer {i, d} = 2*3
