"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model, shape_applicable

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, T=8):
    batch = {"tokens": jax.random.randint(RNG, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend_tokens, cfg.d_model)).astype(
            cfg.activation_dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend_tokens, cfg.d_model)).astype(
            cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, T = 2, 8
    batch = make_batch(cfg, B, T)
    logits = model.logits(params, batch)
    extra = cfg.frontend_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, T + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, T = 2, 8
    batch = make_batch(cfg, B, T)
    full = model.logits(params, batch)
    prefix = cfg.frontend_tokens if cfg.family == "vlm" else 0
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :T - 1]
    cache, _ = model.prefill(params, pre, max_len=prefix + T)
    dec, cache2 = model.decode_step(params, cache, batch["tokens"][:, T - 1],
                                    jnp.int32(prefix + T - 1))
    assert dec.shape == (B, cfg.vocab_size)
    ref = full[:, -1].astype(jnp.float32)
    got = dec.astype(jnp.float32)
    # recurrent archs use a different (chunkwise) training formulation: allow
    # bf16-level divergence; attention archs must be exact.
    tol = 0.08 if cfg.family in ("ssm", "hybrid") else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_integrity(arch):
    cfg = get_config(arch)
    spec = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    if arch in ("phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b"):
        assert cfg.n_experts == 16 and cfg.top_k == 2
    if arch == "mixtral-8x7b":
        assert cfg.n_experts == 8 and cfg.top_k == 2
        assert cfg.sliding_window > 0
    if arch == "jamba-1.5-large-398b":
        assert cfg.attn_period == 8       # 1:7 attention:mamba
    if arch == "xlstm-1.3b":
        assert cfg.slstm_period == 8      # 7:1 mLSTM:sLSTM
    if arch == "whisper-medium":
        assert cfg.encoder_layers == 24


def test_long_500k_skip_list():
    skips = [a for a in ARCHS if not shape_applicable(a, "long_500k")]
    assert set(skips) == {"olmo-1b", "qwen2-7b", "qwen1.5-32b",
                          "qwen2.5-32b", "llava-next-34b", "whisper-medium"}


def test_param_counts_in_band():
    """Rough sanity: named parameter counts land near the advertised sizes."""
    bands = {
        "olmo-1b": (0.8e9, 1.6e9),
        "qwen2-7b": (6e9, 9e9),
        "qwen1.5-32b": (26e9, 40e9),
        "qwen2.5-32b": (26e9, 40e9),
        "mixtral-8x7b": (40e9, 52e9),
        "phi3.5-moe-42b-a6.6b": (36e9, 48e9),
        "llava-next-34b": (28e9, 42e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "xlstm-1.3b": (1.0e9, 2.3e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    assert cfg.param_count(active_only=True) < cfg.param_count()
