"""Minimal stand-in for ``hypothesis`` used only when the real library is
not installed (see conftest.py).  It implements exactly the surface this
repo's property tests use — ``given``, ``settings`` and the strategies
``integers``, ``lists``, ``sampled_from``, ``randoms``, ``composite`` — as
deterministic random search seeded per test, with no shrinking and no
example database.  Install the real ``hypothesis`` (declared in
pyproject.toml) for full property testing; new tests must not rely on
anything beyond this subset when targeting the fallback.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def _lists(elements, min_size=0, max_size=None, unique=False):
    def draw(rng):
        hi = max_size if max_size is not None else min_size + 8
        n = rng.randint(min_size, hi)
        out = []
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            v = elements.example(rng)
            attempts += 1
            if unique and v in out:
                continue
            out.append(v)
        return out
    return _Strategy(draw)


def _randoms():
    return _Strategy(lambda rng: random.Random(rng.getrandbits(64)))


def _composite(fn):
    @functools.wraps(fn)
    def build(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return _Strategy(draw_value)
    return build


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.randoms = _randoms
strategies.composite = _composite


class settings:  # noqa: N801 — mirrors hypothesis' public name
    def __init__(self, max_examples=20, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*strats, **kw_strats):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None) \
                or getattr(fn, "_fallback_settings", None)
            n = cfg.max_examples if cfg else 20
            # deterministic per-test seed: reproducible failures
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example(rng) for s in strats]
                drawn_kw = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        # hide the drawn parameters from pytest's fixture resolution, as
        # real hypothesis does: the wrapper itself takes no arguments
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorator
