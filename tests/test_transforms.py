"""IR transformation + non-deterministic search tests, incl. hypothesis
property tests that transforms preserve semantics against the NumPy oracle."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.ir import interpret, random_inputs
from repro.core.transforms import (FactorReduction, InsertUnitDim, SplitAxis,
                                   find_reduction_chains, search_mappings)


def test_find_reduction_chains():
    h = K.separable_depthwise_conv(1, 3, 3, 2, 2, 3, 2, 4)
    chains = find_reduction_chains(h, min_muls=2)
    assert len(chains) == 1
    assert len(chains[0].muls) == 2


def test_factor_reduction_semantics():
    h = K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8)
    ch = find_reduction_chains(h, min_muls=2)[0]
    t = FactorReduction(ch, factor_mul=1)
    h2 = t.apply(h)
    rng = np.random.default_rng(0)
    ins = random_inputs(h, rng)
    ref = interpret(h, ins)["C"]
    got = interpret(h2, t.adapt_inputs(ins))["C"]
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_search_unblocks_separable_depthwise():
    """Paper Section 2.3's flagship case: factorization exposes the matmul."""
    h = K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8)
    results = search_mappings(h, I.mxu_matmul(), max_depth=2)
    assert results
    assert any(len(r.steps) == 1 and "factor" in r.steps[0].name
               for r in results)


def test_split_axis_semantics():
    h = K.matmul(8, 6, 4)
    t = SplitAxis("i", 4)
    h2 = t.apply(h)
    assert {a.name for a in h2.axes} == {"i_o", "i_i", "j", "k"}
    rng = np.random.default_rng(1)
    ins = random_inputs(h, rng)
    np.testing.assert_allclose(interpret(h2, ins)["C"],
                               interpret(h, ins)["C"], rtol=1e-6)


def test_split_axis_enables_fixed_needle():
    from repro.core.mapper import map_program
    h = K.matmul(256, 128, 128)
    assert not map_program(h, I.mxu_matmul128()).ok
    h2 = SplitAxis("i", 128).apply(h)
    r = map_program(h2, I.mxu_matmul128())
    assert r.ok
    assert "i_o" in r.best(h2).outer_axes


def test_insert_unit_dim_semantics():
    h = K.matmul(4, 3, 5)
    t = InsertUnitDim("A")
    h2 = t.apply(h)
    assert h2.buffer("A").shape == (4, 5, 1)
    rng = np.random.default_rng(2)
    ins = random_inputs(h, rng)
    got = interpret(h2, t.adapt_inputs(ins))
    np.testing.assert_allclose(t.adapt_outputs(got)["C"],
                               interpret(h, ins)["C"], rtol=1e-6)


# ---------------------------------------------------------------------------
# Property tests: every applicable transform preserves program semantics.
# ---------------------------------------------------------------------------

@st.composite
def chain_programs(draw):
    """Random 'C += A * B1 [* B2] ...' reduction programs with random axis
    assignments — the domain FactorReduction must be sound over."""
    from repro.core.ir import ProgramBuilder
    n_axes = draw(st.integers(3, 5))
    sizes = [draw(st.integers(2, 4)) for _ in range(n_axes)]
    pb = ProgramBuilder("rand")
    for i, s in enumerate(sizes):
        pb.axis(f"a{i}", s)
    n_muls = draw(st.integers(2, 3))

    def rand_subset(min_len=1):
        idx = draw(st.lists(st.integers(0, n_axes - 1), min_size=min_len,
                            max_size=n_axes, unique=True))
        return sorted(idx)

    buf_axes = [rand_subset() for _ in range(n_muls + 2)]  # A, B*, C
    all_used = sorted(set().union(*buf_axes[:-1]))
    # C gets a subset of used axes so there is a reduction
    c_axes = [a for a in buf_axes[-1] if a in all_used] or [all_used[0]]
    names = []
    for bi, idxs in enumerate(buf_axes[:-1]):
        nm = f"B{bi}"
        pb.buffer(nm, tuple(sizes[i] for i in idxs))
        names.append((nm, idxs))
    pb.buffer("C", tuple(sizes[i] for i in c_axes))
    t_idxs = all_used
    pb.temp("t", tuple(sizes[i] for i in t_idxs))

    def acc(nm, idxs):
        from repro.core.ir import AccessExpr, AxisExpr
        return AccessExpr(nm, tuple(AxisExpr({f"a{i}": 1}, 0) for i in idxs))

    pb.stmt(acc("t", t_idxs), ":=", acc("B0", names[0][1]))
    for nm, idxs in names[1:]:
        pb.stmt(acc("t", t_idxs), "*=", acc(nm, idxs))
    pb.stmt(acc("C", c_axes), "+=", acc("t", t_idxs))
    pb.output("C")
    return pb.build()


@settings(max_examples=30, deadline=None)
@given(chain_programs(), st.integers(0, 2), st.randoms())
def test_factor_reduction_property(prog, factor_idx, rnd):
    from repro.core.ir import IRError
    chains = find_reduction_chains(prog, min_muls=2)
    if not chains:
        return
    ch = chains[0]
    f = factor_idx % len(ch.muls)
    try:
        prog2 = FactorReduction(ch, f).apply(prog)
    except IRError:
        return  # R1 empty: legitimately inapplicable
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    ins = random_inputs(prog, rng)
    np.testing.assert_allclose(interpret(prog2, ins)["C"],
                               interpret(prog, ins)["C"], rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 6), st.integers(2, 6),
       st.sampled_from(["i", "j", "k"]), st.integers(2, 3))
def test_split_axis_property(m, n, k, axis, factor):
    prog = K.matmul(m * factor, n * factor, k * factor)
    prog2 = SplitAxis(axis, factor).apply(prog)
    rng = np.random.default_rng(0)
    ins = random_inputs(prog, rng)
    np.testing.assert_allclose(interpret(prog2, ins)["C"],
                               interpret(prog, ins)["C"], rtol=1e-5)
