"""Multi-core system-graph invariants + the 2-core scheduler path.

The multi-chip wiring (ICI ring with per-direction issuers) used to be
ad-hoc inside ``tpu_v5e`` and only ever exercised with n_cores=1; these
tests pin the fabric-backed contract: proper ring (wraparound included),
pull-style per-direction issuers, multi-hop routing, and a numerically
correct schedule on >1 core.
"""
import numpy as np

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.executor import execute
from repro.core.ir import interpret, random_inputs
from repro.core.isel import select_instructions
from repro.core.scheduler import schedule
from repro.core.sysgraph import SystemGraph, tpu_v5e


def _ici_edges(g: SystemGraph):
    return [e for e in g.edges
            if e.src.startswith("hbm") and e.dst.startswith("hbm")]


def test_tpu_v5e4_is_a_ring_with_wraparound():
    g = tpu_v5e(4)
    pairs = {(e.src, e.dst) for e in _ici_edges(g)}
    expected = set()
    for c in range(4):
        a, b = f"hbm{c}", f"hbm{(c + 1) % 4}"
        expected |= {(a, b), (b, a)}
    assert pairs == expected            # wraparound hbm3<->hbm0 included


def test_ici_issuer_is_receiving_core_per_direction():
    g = tpu_v5e(4)
    for e in _ici_edges(g):
        assert e.issuer == f"core{e.dst[3:]}", (e.src, e.dst, e.issuer)
    # the old bug: hbm0->hbm1 and hbm1->hbm0 were both issued by core1
    fwd = g.edge("hbm0", "hbm1")
    rev = g.edge("hbm1", "hbm0")
    assert fwd.issuer == "core1" and rev.issuer == "core0"


def test_pcie_writeback_issued_by_chip_core():
    g = tpu_v5e(2)
    assert g.edge("host", "hbm1").issuer == "host"
    assert g.edge("hbm1", "host").issuer == "core1"


def test_add_edge_rev_issuer():
    g = SystemGraph("t")
    g.add_memory("a", 1 << 20, level=1)
    g.add_memory("b", 1 << 20, level=1)
    g.add_edge("a", "b", 1e9, issuer="pa", rev_issuer="pb")
    assert g.edge("a", "b").issuer == "pa"
    assert g.edge("b", "a").issuer == "pb"
    g2 = SystemGraph("t2")
    g2.add_memory("a", 1 << 20, level=1)
    g2.add_memory("b", 1 << 20, level=1)
    g2.add_edge("a", "b", 1e9, issuer="pa")      # legacy default
    assert g2.edge("b", "a").issuer == "pa"


def test_shortest_path_across_two_ici_hops():
    g = tpu_v5e(4)
    path = g.shortest_path("hbm0", "hbm2", nbytes=1 << 20)
    assert [(e.src, e.dst) for e in path] in (
        [("hbm0", "hbm1"), ("hbm1", "hbm2")],
        [("hbm0", "hbm3"), ("hbm3", "hbm2")],
    )
    # 5 cores: hbm0 -> hbm2 still 2 hops, hbm0 -> hbm3 takes the wraparound
    g5 = tpu_v5e(5)
    assert len(g5.shortest_path("hbm0", "hbm2", 1 << 20)) == 2
    assert len(g5.shortest_path("hbm0", "hbm3", 1 << 20)) == 2


def test_two_core_schedule_matches_oracle():
    prog = K.matmul(192, 96, 64)
    sel = select_instructions(prog, I.tpu_isa())
    assert sel.complete
    sched = schedule(sel, tpu_v5e(2))
    rng = np.random.default_rng(7)
    ins = random_inputs(prog, rng)
    ref = interpret(prog, ins)
    got = execute(sched, sel, ins)
    for name in ref:
        np.testing.assert_array_equal(got[name], ref[name])
    assert sched.makespan > 0
