"""repro.fabric tests: topologies, partitioning + bit-exact re-materialization,
collective lowering, the event-driven simulator, and the joint distributed
search integration."""
import pytest

from repro.fabric.collectives import (ALGORITHMS, all_gather_time,
                                      all_reduce_time, lower_all_gather,
                                      lower_all_reduce, lower_reduce_scatter,
                                      reduce_scatter_time)
from repro.fabric.partition import partition_gemm, partition_gru, replay_bitexact, split_extent
from repro.fabric.simulate import (EventSim, FabricEvaluator, replicate_output,
                                   simulate_partition, single_chip_makespan)
from repro.fabric.topology import (Topology, host_tree, make_topology, ring,
                                   torus)
from repro.search.space import ParamApproach, SearchSpace
from repro.search.strategies import STRATEGIES

CHIP = Topology.chip_graph()


# --------------------------------------------------------------------------- #
# Topology
# --------------------------------------------------------------------------- #


def test_ring_bonds_ici_ports():
    t = ring(4)
    assert len(t.links) == 8                      # 4 pairs, both directions
    assert {l.bandwidth for l in t.links} == {100e9}   # 2 ports x 50 GB/s
    assert ring(2).links[0].bandwidth == 200e9         # all 4 ports bonded
    assert len(ring(1).links) == 0


def test_torus_links_and_snake_ring_order():
    t = torus(2, 2)
    assert {l.bandwidth for l in t.links} == {100e9}   # folded wraps bond
    assert t.ring_order == (0, 1, 3, 2)
    big = torus(4, 4)
    assert {l.bandwidth for l in big.links} == {50e9}  # one port per link
    assert len(big.links) == 2 * 2 * 16                # 2 dims x 16 chips
    # snake order is a cycle over fabric-adjacent chips
    order = big.ring_order
    for a, b in zip(order, order[1:]):
        assert len(big.path(a, b)) == 1


def test_host_tree_routes_through_host():
    t = host_tree(4)
    path = t.path(0, 2)
    assert [(l.src, l.dst) for l in path] == [("chip0", "host"),
                                              ("host", "chip2")]


def test_build_graph_matches_tpu_v5e_wiring():
    from repro.core.sysgraph import tpu_v5e
    g = ring(3).build_graph()
    ref = tpu_v5e(3)
    assert set(g.memories) == set(ref.memories)
    assert {(e.src, e.dst, e.issuer) for e in g.edges} == \
           {(e.src, e.dst, e.issuer) for e in ref.edges}


def test_make_topology_dispatch():
    assert make_topology("ring", 4).name == "ring4"
    assert make_topology("torus", 8).name == "torus2x4"
    assert make_topology("host", 2).name == "host2"
    with pytest.raises(ValueError):
        make_topology("mesh", 4)


# --------------------------------------------------------------------------- #
# Partitioning
# --------------------------------------------------------------------------- #


def test_split_extent_uneven():
    assert split_extent(8, 4) == [(0, 2), (2, 2), (4, 2), (6, 2)]
    assert split_extent(10, 4) == [(0, 3), (3, 3), (6, 2), (8, 2)]
    # balanced split: every shard non-empty even when ceil-blocks would
    # over-cover (9 into 6 used to produce a (10, -1) shard)
    assert split_extent(9, 6) == [(0, 2), (2, 2), (4, 2), (6, 1), (7, 1),
                                  (8, 1)]
    assert all(ln > 0 for _, ln in split_extent(9, 6))
    with pytest.raises(ValueError):
        split_extent(3, 4)


def test_partition_gemm_axes_imply_collectives():
    m = partition_gemm(64, 48, 32, "m", 4)
    assert m.collectives == [] and m.out_mode == "concat"
    assert [s.program.buffer("A").shape for s in m.shards] == [(16, 32)] * 4

    n = partition_gemm(64, 48, 32, "n", 4)
    assert [c.kind for c in n.collectives] == ["all_gather"]
    assert n.collectives[0].buffer == "A" and n.collectives[0].when == "pre"
    assert n.shards[0].program.buffer("B").shape == (32, 12)

    k = partition_gemm(64, 48, 32, "k", 4)
    assert [c.kind for c in k.collectives] == ["reduce_scatter"]
    assert k.collectives[0].buffer == "C" and k.collectives[0].when == "post"
    assert k.out_mode == "chain_sum"
    assert k.shards[0].program.buffer("A").shape == (64, 8)

    with pytest.raises(ValueError):
        partition_gemm(64, 48, 32, "batch", 4)


def test_partition_gru_is_data_parallel():
    pp = partition_gru(8, 16, n_chips=2)
    assert pp.collectives == []
    assert pp.shards[0].program.buffer("X").shape == (4, 16)
    assert pp.shards[0].slices["Wr"] == (slice(None), slice(None))


@pytest.mark.parametrize("axis", ["m", "n", "k"])
def test_gemm_replay_bitexact(axis):
    pp = partition_gemm(96, 64, 80, axis, 4)
    assert replay_bitexact(pp, CHIP).exact


@pytest.mark.parametrize("axis", ["m", "n", "k"])
def test_gemm_replay_bitexact_uneven(axis):
    pp = partition_gemm(100, 52, 37, axis, 3)
    assert replay_bitexact(pp, CHIP).exact


def test_gru_replay_bitexact():
    assert replay_bitexact(partition_gru(8, 16, n_chips=2), CHIP).exact
    assert replay_bitexact(partition_gru(9, 24, n_chips=3), CHIP).exact


def test_replay_bitexact_with_tuned_tiles():
    cfg = {"tile_i": 128, "tile_j": 128, "tile_k": 128, "unroll": "red_major"}
    pp = partition_gemm(96, 64, 80, "k", 2)
    assert replay_bitexact(pp, CHIP, ParamApproach(cfg)).exact


# --------------------------------------------------------------------------- #
# Collective lowering
# --------------------------------------------------------------------------- #


def _deliveries(steps, p, own):
    """Replay step streams per direction and return chip -> chunks seen."""
    have = {i: set(s) for i, s in own.items()}
    for st in sorted(steps, key=lambda s: (s.direction, s.step)):
        assert st.chunk in have[st.src], (st, have[st.src])
        have[st.dst].add(st.chunk)
    return have


@pytest.mark.parametrize("p", [2, 3, 4, 5, 8])
@pytest.mark.parametrize("alg", ALGORITHMS)
def test_all_gather_delivers_every_chunk(p, alg):
    steps = lower_all_gather(p, [1000] * p, alg)
    have = _deliveries(steps, p, {i: {i} for i in range(p)})
    assert all(have[i] == set(range(p)) for i in range(p))


@pytest.mark.parametrize("alg", ALGORITHMS)
def test_reduce_scatter_chains_visit_every_chip(alg):
    p = 4
    steps = lower_reduce_scatter(p, [1000] * p, alg)
    for d in {st.direction for st in steps}:
        for c in range(p):
            hops = [st for st in steps
                    if st.direction == d and st.chunk == c]
            visited = {hops[0].src} | {st.dst for st in hops}
            assert visited == set(range(p))     # every partial folded once
            assert all(st.reduce for st in hops)


def test_all_reduce_is_rs_plus_ag():
    p = 4
    ar = lower_all_reduce(p, [1000] * p, "ring")
    rs = [st for st in ar if st.reduce]
    ag = [st for st in ar if not st.reduce]
    assert len(rs) == p * (p - 1) and len(ag) == p * (p - 1)
    # the gather rotation starts at each chunk's reduce-scatter owner
    for st in ag:
        if st.step == p - 1:
            assert st.chunk == (st.src + 1) % p


def test_closed_form_costs():
    p, nb, bw = 4, 4 << 20, 100e9
    assert all_gather_time(p, nb, bw, algorithm="bidir") < \
           all_gather_time(p, nb, bw, algorithm="ring")
    assert reduce_scatter_time(p, nb, bw, algorithm="bidir") < \
           reduce_scatter_time(p, nb, bw, algorithm="ring")
    assert all_reduce_time(p, nb, bw) == pytest.approx(
        reduce_scatter_time(p, nb, bw) + all_gather_time(p, nb, bw))
    assert all_gather_time(1, nb, bw) == 0.0


# --------------------------------------------------------------------------- #
# EventSim
# --------------------------------------------------------------------------- #


def test_eventsim_deps_and_fifo_resources():
    sim = EventSim()
    sim.add("a", resource="r", duration=2.0)
    sim.add("b", resource="r", duration=3.0)            # FIFO behind a
    sim.add("c", resource="q", duration=1.0, deps=["a"])
    sim.add("d", duration=0.0, deps=["b", "c"])         # barrier marker
    t = sim.run()
    assert t["a"] == (0.0, 2.0)
    assert t["b"] == (2.0, 5.0)
    assert t["c"] == (2.0, 3.0)
    assert t["d"] == (5.0, 5.0)


def test_eventsim_rejects_unknown_deps_and_duplicates():
    sim = EventSim()
    sim.add("a")
    with pytest.raises(ValueError):
        sim.add("a")
    with pytest.raises(ValueError):
        sim.add("b", deps=["nope"])


# --------------------------------------------------------------------------- #
# The simulator
# --------------------------------------------------------------------------- #


def test_m_sharding_is_communication_free_and_faster():
    pp = partition_gemm(1024, 512, 512, "m", 2)
    res = simulate_partition(pp, ring(2), chip_graph=CHIP)
    one = single_chip_makespan(pp, CHIP)
    assert res.comm_end == 0.0 and res.n_collective_steps == 0
    assert res.makespan < one


def test_k_sharding_reduces_and_overlaps():
    pp = partition_gemm(1024, 512, 512, "k", 2)
    res = simulate_partition(pp, ring(2), chip_graph=CHIP)
    assert res.n_collective_steps > 0
    assert res.comm_end > 0.0
    # communication overlaps compute: the makespan is far below the sum of
    # compute and a fully serialized collective
    serial = max(res.chip_spans) + reduce_scatter_time(
        2, 1024 * 512 * 4, ring(2).min_link_bandwidth())
    assert res.makespan <= serial + 1e-12


def test_n_sharding_gates_compute_on_operand_gather():
    pp = partition_gemm(1024, 512, 512, "n", 2)
    res = simulate_partition(pp, ring(2), chip_graph=CHIP)
    assert res.n_collective_steps > 0
    # the pre all-gather cannot make the chips *faster* than compute alone
    m_only = simulate_partition(partition_gemm(1024, 512, 512, "m", 2),
                                ring(2), chip_graph=CHIP)
    assert res.makespan > m_only.makespan


def test_acceptance_shape_beats_one_chip_on_two_axes():
    """The ISSUE acceptance criterion, as a regression test."""
    one = single_chip_makespan(partition_gemm(5124, 700, 2048, "m", 1), CHIP)
    wins = 0
    for axis in ("m", "n", "k"):
        pp = partition_gemm(5124, 700, 2048, axis, 4)
        best = min(simulate_partition(pp, ring(4), None, alg, CHIP).makespan
                   for alg in ALGORITHMS)
        wins += best < one
    assert wins >= 2


def test_replicated_output_costs_more():
    pp = partition_gemm(1024, 512, 512, "m", 2)
    shard_out = simulate_partition(pp, ring(2), chip_graph=CHIP)
    repl = replicate_output(pp)
    assert [c.kind for c in repl.collectives] == ["all_gather"]
    repl_out = simulate_partition(repl, ring(2), chip_graph=CHIP)
    assert repl_out.makespan > shard_out.makespan

    ppk = replicate_output(partition_gemm(1024, 512, 512, "k", 2))
    assert [c.kind for c in ppk.collectives] == ["all_reduce"]


def test_gru_batch_sharding_scales():
    pp = partition_gru(32, 256, n_chips=4)
    res = simulate_partition(pp, ring(4), chip_graph=CHIP)
    one = single_chip_makespan(pp, CHIP)
    assert res.makespan < one


def test_simulate_rejects_chip_count_mismatch():
    pp = partition_gemm(64, 64, 64, "k", 2)
    with pytest.raises(ValueError):
        simulate_partition(pp, ring(4), chip_graph=CHIP)


def test_host_tree_collectives_are_slower_than_ici():
    pp = partition_gemm(1024, 512, 512, "k", 2)
    ici = simulate_partition(pp, ring(2), chip_graph=CHIP)
    pcie = simulate_partition(pp, host_tree(2), chip_graph=CHIP)
    assert pcie.makespan > ici.makespan


# --------------------------------------------------------------------------- #
# Search integration
# --------------------------------------------------------------------------- #


def test_fabric_space_axes_and_baseline():
    space = SearchSpace.for_fabric("gemm")
    names = [a.name for a in space.axes]
    assert "part_axis" in names and "collective" in names
    base = space.baseline()
    assert base["part_axis"] == "m" and base["collective"] == "ring"
    # the plain space is unchanged
    assert "part_axis" not in [a.name for a in SearchSpace().axes]


def test_fabric_evaluator_baseline_matches_simulator():
    topo = ring(2)
    ev = FabricEvaluator("gemm", (512, 256, 256), topo)
    space = SearchSpace.for_fabric("gemm")
    base_cost = ev(space.baseline())
    direct = simulate_partition(partition_gemm(512, 256, 256, "m", 2),
                                topo, None, "ring", ev.chip_graph)
    assert base_cost == pytest.approx(direct.makespan)
    assert ev({**space.baseline(), "part_axis": "nope"}) == float("inf")


def test_joint_fabric_search_anchored_to_baseline():
    topo = ring(2)
    ev = FabricEvaluator("gemm", (512, 256, 256), topo)
    space = SearchSpace.for_fabric("gemm")
    out = STRATEGIES["hillclimb"](space, ev, trials=8, seed=0)
    assert out.best_cost <= out.baseline_cost
    assert out.best_config["part_axis"] in ("m", "n", "k")
    assert out.best_config["collective"] in ALGORITHMS


def test_tune_fabric_case_smoke(tmp_path):
    from repro.search.tune import fabric_record_for, tune_fabric_case
    topo = ring(2)
    rep = tune_fabric_case(512, 256, 256, topo, "random", trials=4, seed=0)
    assert rep.ok
    assert rep.validation is not None and rep.validation.exact
    rec = fabric_record_for(rep, topo, "random")
    assert rec.backend == "fabric" and rec.meta["chips"] == 2
