"""repro.compile tests: the pipeline is bit-identical to the historical
ad-hoc call chains, tiles are derived from mapping axis roles (not guessed
axis names), the artifact cache hits/misses on exactly the fingerprint
dimensions, and cached artifacts replay schedules that stay bit-exact
against the ISAMIR oracle."""
import json
import warnings

import pytest

from repro.compile import (ArtifactCache, CompileError, artifact_key,
                           compile_conv, compile_fabric, compile_gemm,
                           compile_gru, compile_program, compile_selection,
                           gemm_selection, set_default_artifact_cache)
from repro.compile.cache import approach_fingerprint
from repro.compile.driver import clear_memo
from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.approach import GreedyApproach
from repro.core.isel import select_instructions
from repro.core.scheduler import schedule
from repro.core.sysgraph import paper_accelerator, tpu_v5e
from repro.search.evaluate import validate_schedule
from repro.search.space import ParamApproach


@pytest.fixture(autouse=True)
def _isolate_caches():
    """No test leaks a process-default artifact cache or a stale memo."""
    clear_memo()
    set_default_artifact_cache(None)
    yield
    clear_memo()
    set_default_artifact_cache(None)


# --------------------------------------------------------------------------- #
# Pipeline equivalence with the historical call chains
# --------------------------------------------------------------------------- #


def test_compile_gemm_matches_legacy_chain():
    """Driver output == select_instructions + schedule, op for op."""
    m, n, k = 512, 192, 384
    prog = K.matmul(m, n, k)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    legacy = schedule(sel, tpu_v5e(1), GreedyApproach())

    art = compile_gemm(m, n, k, use_cache=False)
    assert art.cost == legacy.makespan
    assert art.schedule.counts() == legacy.counts()
    assert [op.kind for op in art.schedule.ops] == \
        [op.kind for op in legacy.ops]


def test_gemm_tile_derived_from_axis_roles():
    art = compile_gemm(1024, 1024, 1024, use_cache=False)
    tile = art.gemm_tile()
    assert tile[0] == 128
    assert tile[1] % 128 == 0
    assert tile[2] >= 128
    assert art.lowering["kind"] == "pallas_gemm"
    assert tuple(art.lowering["block"]) == tile


def test_conv_extraction_tile_not_128_default():
    """The conv->matmul extraction renames haystack axes; role-derived tiles
    must reflect the real fused extents, not an i/j/k guess defaulting to
    128 (the historical _tile_from_schedule bug)."""
    art = compile_conv(use_cache=False, batch=2, h=6, w=6, kh=1, kw=1,
                       cin=8, cout=8)
    plan = art.instr_plan("mxu.matmul")
    hay_axes = {h for _, h in plan.axis_map}
    assert not {"i", "j", "k"} <= hay_axes      # axes really are renamed
    assert art.gemm_tile() == (72, 8, 8)        # fused extents, clamped


def test_unmappable_tile_request_raises():
    art = compile_gru(4, 16, use_cache=False)
    with pytest.raises(CompileError):
        art.instr_plan("mxu.matmul").tile_for("q")   # no such role
    with pytest.raises(CompileError):
        art.instr_plan("nonexistent.needle")


def test_compile_program_rejects_uncoverable():
    prog = K.matmul(64, 64, 64)
    with pytest.raises(CompileError):
        compile_program(prog, isa=[I.vpu_unary("exp")], use_cache=False)


def test_compile_selection_param_approach_matches_evaluator():
    from repro.search.evaluate import CostModelEvaluator
    from repro.search.space import SearchSpace
    prog, sel = gemm_selection(256, 192, 130)
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(sel, graph)
    cfg = space.baseline()
    art = compile_selection(sel, graph, ParamApproach(cfg))
    assert art.cost == ev(cfg)
    assert art.cost == schedule(sel, graph, GreedyApproach()).makespan


# --------------------------------------------------------------------------- #
# Artifact cache correctness (hit/miss dimensions + replay)
# --------------------------------------------------------------------------- #


def test_same_program_sysgraph_hits_cache(tmp_path):
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    a1 = compile_gemm(256, 128, 192, cache=cache)
    assert not a1.from_cache
    clear_memo()                       # force the persistent layer
    a2 = compile_gemm(256, 128, 192, cache=cache)
    assert a2.from_cache
    assert a2.key == a1.key
    assert a2.cost == a1.cost
    assert a2.gemm_tile() == a1.gemm_tile()
    assert a2.lowering == a1.lowering


def test_changed_sysgraph_misses(tmp_path):
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    a1 = compile_gemm(256, 128, 192, cache=cache)
    clear_memo()
    a2 = compile_gemm(256, 128, 192, graph=paper_accelerator(2), cache=cache)
    assert not a2.from_cache
    assert a2.key != a1.key


def test_changed_backend_and_approach_miss():
    prog = K.matmul(64, 64, 64)
    g = tpu_v5e(1)
    greedy = GreedyApproach()
    base = artifact_key(prog, g, greedy, "cost")
    assert artifact_key(prog, g, greedy, "measure") != base
    tuned = ParamApproach({"tile_i": 256})
    assert artifact_key(prog, g, tuned, "cost") != base


def test_changed_isa_or_transform_policy_misses():
    """Same program compiled under a different needle set (or transform
    policy) must not be served the other compile's artifact."""
    prog = K.gru_cell(4, 16, 16)
    full = compile_program(prog, isa=I.tpu_isa())
    unfused = compile_program(prog, isa=I.tpu_isa(include_fused=False))
    assert full.key != unfused.key
    full_needles = {p.needle for p in full.instrs}
    assert any(n.startswith("fused.") for n in full_needles)
    assert not any(p.needle.startswith("fused.") for p in unfused.instrs)
    g = tpu_v5e(1)
    mm = K.matmul(64, 64, 64)
    assert artifact_key(mm, g, GreedyApproach(), "cost",
                        [I.mxu_matmul()], True) != \
        artifact_key(mm, g, GreedyApproach(), "cost",
                     [I.mxu_matmul()], False)


def test_changed_jax_version_misses(tmp_path, monkeypatch):
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    compile_gemm(256, 128, 192, cache=cache)
    clear_memo()
    import repro.search.space as space_mod
    monkeypatch.setattr(space_mod, "jax_version", lambda: "99.0.0-test")
    a2 = compile_gemm(256, 128, 192, cache=cache)
    assert not a2.from_cache
    assert "jax=99.0.0-test" in a2.key


def test_opaque_approach_never_cached(tmp_path):
    class Wrapped(GreedyApproach):
        pass
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    assert approach_fingerprint(Wrapped()).startswith("opaque:")
    compile_gemm(64, 64, 64, approach=Wrapped(), cache=cache)
    assert len(cache) == 0


def test_cached_artifact_replays_bit_exact(tmp_path):
    """The satellite acceptance check: a cache-hydrated CompiledKernel
    rebuilds a schedule whose executor replay is bit-exact vs the oracle."""
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    m, n, k = 96, 80, 130                       # odd k: boundary tiles
    compile_gemm(m, n, k, cache=cache)
    clear_memo()
    art = compile_gemm(m, n, k, cache=cache)
    assert art.from_cache and art.schedule is None
    sched = art.ensure_schedule()
    prog = K.matmul(m, n, k)
    report = validate_schedule(prog, art.selection, sched)
    assert report.exact
    # and the replayed schedule reproduces the cached artifact's decisions
    assert sched.makespan == art.cost


def test_cached_gru_artifact_replays_bit_exact(tmp_path):
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    compile_gru(4, 16, cache=cache)
    clear_memo()
    art = compile_gru(4, 16, cache=cache)
    assert art.from_cache
    sched = art.ensure_schedule()
    report = validate_schedule(art.program, art.selection, sched)
    assert report.ok
    assert sched.makespan == art.cost


def test_cache_roundtrip_through_json(tmp_path):
    path = str(tmp_path / "compiled.json")
    a1 = compile_gemm(128, 64, 64, cache=ArtifactCache(path))
    raw = json.loads(open(path).read())
    assert raw["schema"] == 1 and len(raw["artifacts"]) == 1
    clear_memo()
    a2 = ArtifactCache(path).lookup(a1.key)
    assert a2 is not None
    assert a2.gemm_tile() == a1.gemm_tile()
    assert [p.needle for p in a2.instrs] == [p.needle for p in a1.instrs]


def test_corrupt_artifact_cache_warns_once(tmp_path):
    path = tmp_path / "compiled.json"
    path.write_text("{definitely not json")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        c = ArtifactCache(str(path))
        assert len(c) == 0
        ArtifactCache(str(path)).load()         # second reader: no new warn
    assert len([w for w in caught if "corrupt" in str(w.message)]) == 1
    # a corrupt cache degrades to empty, then heals on the next save
    art = compile_gemm(64, 64, 64, cache=c)
    clear_memo()
    assert ArtifactCache(str(path)).lookup(art.key) is not None


def test_plan_gemm_narrowed_cache_errors(tmp_path, monkeypatch):
    """plan_gemm survives the documented cache error types and still plans;
    an unrelated error propagates (no bare except Exception anymore)."""
    from repro.kernels import ops

    def boom(*a, **kw):
        raise OSError("disk on fire")
    import repro.search.cache as scache
    monkeypatch.setattr(scache, "lookup_gemm", boom)
    tile, cost = ops.plan_gemm(64, 64, 64)
    assert tile == (64, 64, 64) and cost > 0

    def bug(*a, **kw):
        raise RuntimeError("logic bug")
    monkeypatch.setattr(scache, "lookup_gemm", bug)
    with pytest.raises(RuntimeError):
        ops.plan_gemm(64, 64, 64)


# --------------------------------------------------------------------------- #
# Entry-point consistency + fabric compiles
# --------------------------------------------------------------------------- #


def test_plan_gemm_and_plan_gru_route_through_driver():
    from repro.kernels import ops
    tile, secs = ops.plan_gemm(1024, 1024, 1024, use_cache=False)
    art = compile_gemm(1024, 1024, 1024, use_cache=False)
    assert tile == art.gemm_tile() and secs == art.cost
    (bb, bh), gsecs = ops.plan_gru(16, 64)
    assert (bb, bh) == (16, 64) and gsecs > 0


def test_compile_fabric_matches_simulator(tmp_path):
    from repro.fabric.partition import partition
    from repro.fabric.simulate import simulate_partition
    from repro.fabric.topology import make_topology
    topo = make_topology("ring", 2)
    shape = (256, 128, 192)
    art = compile_fabric("gemm", shape, topo, axis="k", use_cache=False)
    res = simulate_partition(partition("gemm", shape, "k", 2), topo,
                             None, "ring")
    assert art.cost == res.makespan
    assert art.fabric["axis"] == "k"
    assert art.fabric["collectives"] == [
        {"kind": "reduce_scatter", "buffer": "C", "when": "post", "axis": 0}]
    # fabric artifacts round-trip through the cache too
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    a1 = compile_fabric("gemm", shape, topo, axis="k", cache=cache)
    clear_memo()
    a2 = compile_fabric("gemm", shape, topo, axis="k", cache=cache)
    assert a2.from_cache and a2.cost == a1.cost
    assert a2.fabric == a1.fabric


def test_cached_fabric_artifact_replays_per_chip_schedule(tmp_path):
    """A cache-hydrated fabric artifact rebuilds chip 0's per-chip schedule
    (what a fresh compile attaches), not the unsharded program on the
    fabric graph."""
    from repro.fabric.topology import make_topology
    topo = make_topology("ring", 2)
    cache = ArtifactCache(str(tmp_path / "compiled.json"))
    fresh = compile_fabric("gemm", (256, 128, 192), topo, axis="k",
                           cache=cache)
    clear_memo()
    cached = compile_fabric("gemm", (256, 128, 192), topo, axis="k",
                            cache=cache)
    assert cached.from_cache
    sched = cached.ensure_schedule()
    assert sched.makespan == fresh.schedule.makespan
    assert sched.counts() == fresh.schedule.counts()


def test_dtype_table_single_source():
    from repro.core.dtypes import DTYPE_BYTES, dtype_bytes
    from repro.core import scheduler
    from repro.launch import hlo_analysis, hlo_flops
    assert scheduler.DTYPE_BYTES is DTYPE_BYTES
    assert hlo_flops._DTYPE_BYTES is DTYPE_BYTES
    assert hlo_analysis._DTYPE_BYTES is DTYPE_BYTES
    assert dtype_bytes("f32") == 4 and dtype_bytes("bf16") == 2
    assert dtype_bytes("no-such-dtype") == 4
