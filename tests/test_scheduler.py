"""Scheduler + executor tests: the scheduled instruction stream must compute
exactly what the ISAMIR oracle computes, across system graphs, approaches and
kernels — including cross-device coherence and cache invalidation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.approach import CostModelApproach, GreedyApproach, RandomApproach
from repro.core.executor import execute
from repro.core.ir import interpret, random_inputs
from repro.core.isel import select_instructions
from repro.core.scheduler import ScheduleError, Scheduler, schedule
from repro.core.sysgraph import SystemGraph, paper_accelerator, tpu_v5e

ISA = I.tpu_isa()


def run_case(prog, graph, approach=None, rng_seed=0):
    sel = select_instructions(prog, ISA)
    assert sel.complete, sel.uncovered
    sched = schedule(sel, graph, approach)
    rng = np.random.default_rng(rng_seed)
    ins = random_inputs(prog, rng)
    ref = interpret(prog, ins)
    ins2 = ins
    for t in sel.steps:
        ins2 = t.adapt_inputs(ins2)
    got = execute(sched, sel, ins2)
    outs = {k: got[k] for k in ref}
    for t in reversed(sel.steps):
        outs = t.adapt_outputs(outs)
    for k in ref:
        np.testing.assert_allclose(outs[k], ref[k], rtol=1e-4, atol=1e-5)
    return sched


@pytest.mark.parametrize("graph_fn", [lambda: tpu_v5e(1), lambda: tpu_v5e(2),
                                      lambda: paper_accelerator(2)])
def test_matmul_all_graphs(graph_fn):
    run_case(K.matmul(130, 90, 70), graph_fn())


@pytest.mark.parametrize("prog_fn", [
    lambda: K.gru_cell(4, 16, 12),
    lambda: K.conv1d(2, 6, 3, 8, 4),
    lambda: K.conv2d(2, 5, 5, 3, 3, 4, 8),
    lambda: K.depthwise_conv2d(1, 4, 4, 3, 3, 8),
    lambda: K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8),
    lambda: K.mlp_gate(8, 16, 32),
    lambda: K.attention_scores(2, 2, 8, 8, 16),
])
def test_kernels_multidevice(prog_fn):
    run_case(prog_fn(), paper_accelerator(2))


def test_approaches_agree_numerically():
    prog = K.matmul(100, 80, 60)
    for app in [GreedyApproach(), RandomApproach(seed=1),
                CostModelApproach(samples=3)]:
        run_case(prog, paper_accelerator(2), app)


def test_cost_model_approach_not_worse_than_greedy():
    prog = K.matmul(200, 160, 120)
    sel = select_instructions(prog, ISA)
    g = paper_accelerator(2)
    greedy = schedule(sel, g, GreedyApproach())
    best = schedule(sel, g, CostModelApproach(samples=6))
    assert best.makespan <= greedy.makespan * 1.0001


def test_scheduler_respects_capacity_with_eviction():
    """A register file far smaller than the working set forces eviction +
    dirty write-back; numerics must survive."""
    g = SystemGraph("tiny")
    g.add_memory("host", 1 << 30, level=0)
    g.add_memory("hbm0", 1 << 26, level=1)
    g.add_memory("rf0", 80 << 10, level=2)   # 80 KiB: a few 64x64 f32 tiles
    g.add_edge("host", "hbm0", 32e9, 2e-6)
    g.add_edge("hbm0", "rf0", 400e9, 2e-7, issuer="pu0")
    g.add_compute("pu0", "rf0", {"mxu.", "vpu.", "fused."}, 25e12,
                  matmul_tile=(64, 64, 64))
    sched = run_case(K.matmul(256, 192, 128), g)
    assert any(op.kind == "writeback" for op in sched.ops) or \
           sched.counts().get("copy", 0) > 10


def test_capacity_error_when_tile_cannot_fit():
    g = SystemGraph("toosmall")
    g.add_memory("host", 1 << 30, level=0)
    g.add_memory("rf0", 1 << 10, level=2)    # 1 KiB: nothing fits
    g.add_edge("host", "rf0", 1e9, 1e-6, issuer="pu0")
    g.add_compute("pu0", "rf0", {"mxu.", "vpu.", "fused."}, 1e12,
                  matmul_tile=(64, 64, 64))
    sel = select_instructions(K.matmul(64, 64, 64), ISA)
    with pytest.raises(ScheduleError):
        schedule(sel, g)


def test_cache_invalidation_cross_device():
    """GRU on two clusters: gates written on one register file must be
    re-fetched (not stale) when consumed on the other — this is the virtual
    cache-invalidation path."""
    sched = run_case(K.gru_cell(4, 16, 12), paper_accelerator(2), rng_seed=3)
    devices = {op.device for op in sched.ops if op.kind == "compute"}
    assert len(devices) > 1  # work actually spread across units


def test_makespan_and_busy_accounting():
    sched = run_case(K.matmul(256, 256, 256), tpu_v5e(1))
    assert sched.makespan > 0
    busy = sum(sched.device_busy.values())
    assert busy > 0
    for op in sched.ops:
        assert op.end >= op.start >= 0


def test_unmapped_temp_not_materialized():
    """Chain temps consumed inside an instruction never get homes/copies."""
    prog = K.matmul(64, 64, 64)
    sel = select_instructions(prog, ISA)
    s = Scheduler(sel, tpu_v5e(1))
    assert "tmp" not in s.homes


@settings(max_examples=10, deadline=None)
@given(st.integers(20, 200), st.integers(20, 200), st.integers(20, 200),
       st.integers(1, 3))
def test_matmul_schedule_property(m, n, k, cores):
    """Any GEMM size on any core count executes to the oracle's result."""
    run_case(K.matmul(m, n, k), tpu_v5e(cores), rng_seed=m * n + k)


def test_bytes_moved_respects_buffer_dtype():
    """bf16 buffers move half the bytes of an f32 twin with an identical op
    stream (regions are element ranges; dtype scaling happens at the byte
    accounting, the cost model, and the capacity checks)."""
    def build(dtype):
        prog = K.matmul(256, 128, 192)
        if dtype != "f32":
            for b in prog.buffers:
                object.__setattr__(b, "dtype", dtype)
        sel = select_instructions(prog, ISA)
        return schedule(sel, tpu_v5e(1))

    f32, bf16 = build("f32"), build("bf16")
    assert [(op.kind, op.src, op.dst) for op in f32.ops] == \
           [(op.kind, op.src, op.dst) for op in bf16.ops]
    assert f32.bytes_moved() == 2 * bf16.bytes_moved()
    assert bf16.makespan < f32.makespan          # cost model sees the traffic
    f64 = build("f64")
    assert f64.bytes_moved() == 2 * f32.bytes_moved()


def test_region_nbytes_uses_program_dtype():
    prog = K.matmul(32, 32, 32)
    for b in prog.buffers:
        if b.name == "A":
            object.__setattr__(b, "dtype", "bf16")
    sel = select_instructions(prog, ISA)
    sched = schedule(sel, tpu_v5e(1))
    from repro.core.scheduler import Region
    a = Region("A", ((0, 8), (0, 8)))
    c = Region("C", ((0, 8), (0, 8)))
    assert sched.region_nbytes(a) == 8 * 8 * 2   # bf16
    assert sched.region_nbytes(c) == 8 * 8 * 4   # f32
