"""Sharding-rule unit tests over an abstract 16x16 production mesh — no
devices required (PartitionSpec logic only)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (batch_spec, cache_spec, dp_axes, param_spec,
                                 shard_dim)

# AbstractMesh and its (axis_sizes, axis_names) signature are recent jax
# API; repro.dist.compat bridges 0.4.3x (installed via conftest.py), but on
# a jax that predates AbstractMesh entirely these spec tests cannot build
# their device-free meshes — skip with a clear message instead of crashing
# the whole collection.
try:
    from jax.sharding import AbstractMesh
    MESH = AbstractMesh((16, 16), ("data", "model"))
    MESH3 = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
except (ImportError, TypeError) as e:
    pytest.skip(
        f"jax {jax.__version__} has no usable jax.sharding.AbstractMesh "
        f"({e}); abstract-mesh sharding spec tests need jax>=0.4.35",
        allow_module_level=True)


def test_dp_axes():
    assert dp_axes(MESH) == ("data",)
    assert dp_axes(MESH3) == ("pod", "data")


def test_shard_dim_divisibility():
    assert shard_dim(MESH, 4096, "model") == "model"
    assert shard_dim(MESH, 28, "model") is None
    assert shard_dim(MESH, 28, "model", ("data",)) is None
    assert shard_dim(MESH3, 256, ("pod", "data")) == ("pod", "data")


def test_attention_param_rules():
    cfg = get_config("qwen2-7b")
    # column-parallel qkv: FSDP on input dim, TP on output dim
    assert param_spec("layers/attn/wq", (28, 3584, 3584), MESH, cfg) \
        == P(None, "data", "model")
    # row-parallel output proj
    assert param_spec("layers/attn/wo", (28, 3584, 3584), MESH, cfg) \
        == P(None, "model", "data")
    assert param_spec("layers/norm1", (28, 3584), MESH, cfg) == P()


def test_embed_lm_head_rules():
    cfg = get_config("qwen2-7b")
    assert param_spec("embed", (152064, 3584), MESH, cfg) \
        == P("model", "data")
    assert param_spec("lm_head", (3584, 152064), MESH, cfg) \
        == P("data", "model")


def test_moe_expert_parallelism():
    cfg = get_config("phi3.5-moe-42b-a6.6b")     # 16 experts: EP over model
    spec = param_spec("layers/ffn/w_gate", (32, 16, 4096, 6400), MESH, cfg)
    assert spec == P(None, "model", "data", None)


def test_moe_tp_fallback_when_experts_dont_divide():
    cfg = get_config("mixtral-8x7b")             # 8 experts: TP fallback
    spec = param_spec("layers/ffn/w_gate", (32, 8, 4096, 14336), MESH, cfg)
    assert spec == P(None, None, "data", "model")


def test_slstm_recurrent_weight_replicated():
    cfg = get_config("xlstm-1.3b")
    assert param_spec("blocks/slstm/p/r_z", (6, 2048, 2048), MESH, cfg) \
        in (P(None, None, None), P())
    # the hoisted projections stay TP
    assert param_spec("blocks/slstm/p/w_z", (6, 2048, 2048), MESH, cfg) \
        == P(None, "data", "model")


def test_batch_specs():
    assert batch_spec("tokens", (256, 4096), MESH) == P("data", None)
    assert batch_spec("tokens", (128,), MESH) == P("data")
    # long-context batch=1: sequence sharding fallback
    assert batch_spec("tokens", (1, 524288), MESH) == P(None, "data")


def test_kv_cache_specs():
    cfg = get_config("qwen2.5-32b")   # kv=8: heads don't divide 16
    spec = cache_spec("kv/k", (64, 128, 32768, 8, 128), MESH, cfg)
    assert spec[3] is None and spec[4] == "model"   # head_dim sharded
    cfg2 = get_config("qwen1.5-32b")  # kv=40 -> not divisible either
    spec2 = cache_spec("kv/k", (64, 128, 32768, 40, 128), MESH, cfg2)
    assert spec2[4] == "model"


def test_mamba_state_specs():
    cfg = get_config("jamba-1.5-large-398b")
    spec = cache_spec("dense/h", (9, 4, 128, 16384, 16), MESH, cfg)
    assert spec[-2] == "model"        # d_inner sharded

def test_activation_rules_fallback_to_sequence():
    from repro.dist.sharding import make_activation_rules
    cfg = get_config("qwen2-7b")      # 28 heads % 16 != 0
    rules = make_activation_rules(MESH, cfg)
    s = rules("heads", (32, 32768, 28, 128))
    assert s.spec == P("data", "model", None, None)
    cfg2 = get_config("mixtral-8x7b")  # 32 heads: divisible
    rules2 = make_activation_rules(MESH, cfg2)
    s2 = rules2("heads", (256, 4096, 32, 128))
    assert s2.spec == P("data", None, "model", None)
