"""Tests for repro.verify: golden-clean compiles across the tune suites,
one mutation test per corruption class, Diagnostic round-trips, the
VerifyPass gate, and cached-payload rejection."""
import json

import pytest

from repro.compile.artifact import CompileError
from repro.compile.driver import compile_gemm, compile_selection
from repro.search.tune import FABRIC_GEMM_SIZES, build_cases, make_graph
from repro.verify import (ERROR, RULES, WARNING, Diagnostic,
                          DiagnosticReport, diag, verify_artifact,
                          verify_compile, verify_fabric)
from repro.verify.mutate import MUTATIONS, baseline_report, run_mutation

GRAPH = make_graph("tpu")


# --------------------------------------------------------------------------- #
# Golden: every tune-suite compile verifies clean (zero false positives)
# --------------------------------------------------------------------------- #

CASES = build_cases("all")


@pytest.mark.parametrize("case", CASES, ids=[c.name for c in CASES])
def test_tune_suite_compile_verifies_clean(case):
    art = compile_selection(case.selection, GRAPH, program=case.program)
    report = verify_compile(selection=case.selection, schedule=art.schedule,
                            approach=art.approach)
    assert report.ok, report.render()
    assert report.diagnostics == [], report.render()


@pytest.mark.parametrize("axis", ["m", "n", "k"])
def test_fabric_partition_verifies_clean(axis):
    from repro.fabric.partition import partition
    from repro.fabric.topology import make_topology
    topo = make_topology("ring", 4)
    pp = partition("gemm", FABRIC_GEMM_SIZES[1], axis, topo.n_chips)
    diags = verify_fabric(pp, topo)
    assert [d for d in diags if d.severity == ERROR] == [], \
        "\n".join(str(d) for d in diags)


def test_artifact_verifies_clean_end_to_end():
    art = compile_gemm(256, 128, 192, use_cache=False)
    report = verify_artifact(art)
    assert report.ok and report.diagnostics == [], report.render()


# --------------------------------------------------------------------------- #
# Mutation harness: every corruption class is caught with its rule id
# --------------------------------------------------------------------------- #


def test_mutation_baseline_is_clean():
    report = baseline_report()
    assert report.ok and report.diagnostics == [], report.render()


@pytest.mark.parametrize("name", sorted(MUTATIONS))
def test_mutation_is_caught(name):
    res = run_mutation(name)
    assert res.caught, str(res)
    # one corruption ~ one primary finding: the expected rule fires, and the
    # report stays small (no cascade of unrelated diagnostics)
    assert res.expected in res.rules
    assert len(set(res.rules)) <= 3, str(res)


def test_mutation_registry_covers_every_layer():
    layers = {MUTATIONS[n][0].split(".", 1)[0] for n in MUTATIONS}
    assert layers == {"prg", "sel", "sch", "fab", "gra", "srv",
                      "art"}
    assert len(MUTATIONS) >= 10


# --------------------------------------------------------------------------- #
# Diagnostics: structure + JSON round-trip
# --------------------------------------------------------------------------- #


def test_diag_rejects_unregistered_rule():
    with pytest.raises(KeyError):
        diag("prg.not-a-rule", "nope")


def test_diagnostic_layer_derived_from_rule():
    d = diag("sch.capacity", "too big", uid=7, subject="vmem")
    assert d.layer == "sch" and d.severity == ERROR


def test_diagnostic_json_round_trip():
    d = diag("sel.axis-role", "axis j bound twice", severity=WARNING,
             subject="mxu.matmul", uid=3)
    d2 = Diagnostic.from_dict(json.loads(json.dumps(d.to_dict())))
    assert d2 == d


def test_report_json_round_trip_and_severity_split():
    rep = DiagnosticReport(meta={"case": "gemm"})
    rep.extend([diag("prg.bounds", "oob"),
                diag("sch.vmem-budget", "tight", severity=WARNING)])
    assert not rep.ok and len(rep.errors) == 1 and len(rep.warnings) == 1
    rep2 = DiagnosticReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert rep2.diagnostics == rep.diagnostics
    assert rep2.meta == {"case": "gemm"}
    assert "prg.bounds" in rep.render()


def test_rules_table_is_namespaced():
    for rule in RULES:
        assert rule.split(".", 1)[0] in ("prg", "sel", "sch", "fab", "gra",
                                         "srv", "art")


# --------------------------------------------------------------------------- #
# VerifyPass: strict by default, ctx.verify=False escapes
# --------------------------------------------------------------------------- #


def _corrupt_schedule(art):
    wb = [op for op in art.schedule.ops if op.kind == "writeback"][-1]
    art.schedule.ops = [op for op in art.schedule.ops if op.uid != wb.uid]
    art.schedule.final_residency.pop(
        (wb.region.buffer, wb.region.bounds), None)


def test_verify_pass_rejects_corrupt_schedule():
    from repro.compile.pipeline import CompileContext, VerifyPass
    art = compile_gemm(128, 64, 96, use_cache=False)
    _corrupt_schedule(art)
    ctx = CompileContext(program=art.program, graph=art.graph,
                         approach=art.approach)
    ctx.selection, ctx.schedule = art.selection, art.schedule
    with pytest.raises(CompileError, match="sch.output-not-home"):
        VerifyPass().run(ctx)
    ctx.verify = False                         # the --no-verify escape hatch
    VerifyPass().run(ctx)


def test_compile_selection_verify_flag():
    case = CASES[0]
    art = compile_selection(case.selection, GRAPH, program=case.program,
                            verify=True)
    assert art.cost > 0


# --------------------------------------------------------------------------- #
# Cache: corrupt payloads are rejected before hydration
# --------------------------------------------------------------------------- #


def test_cache_lookup_rejects_corrupt_payload(tmp_path, recwarn):
    from repro.compile.cache import ArtifactCache
    art = compile_gemm(64, 32, 48, use_cache=False)
    path = str(tmp_path / "compiled.json")
    cache = ArtifactCache(path)
    cache.store(art)

    fresh = ArtifactCache(path)
    assert fresh.lookup(art.key) is not None    # intact payload hydrates

    with open(path) as f:
        payload = json.load(f)
    payload["artifacts"][0]["cost"] = -1.0      # corrupt on disk
    with open(path, "w") as f:
        json.dump(payload, f)
    poisoned = ArtifactCache(path)
    assert poisoned.lookup(art.key) is None
    assert any("failed payload verification" in str(w.message)
               for w in recwarn.list)
