"""Instruction-selection tests (paper Section 2.4)."""

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions


def test_fused_beats_unfused_on_gru_gates():
    """The r/z gate chains must select the fused matmul+bias+sigmoid needle
    (1 call) over three separate instructions."""
    sel = select_instructions(K.gru_cell(4, 8, 6), I.tpu_isa())
    names = [si.needle.name for si in sel.instrs]
    assert names.count("fused.matmul_bias_sigmoid") == 2
    assert names.count("fused.matmul_bias") == 1   # the n-gate H-side

def test_no_fused_isa_still_complete():
    sel = select_instructions(K.gru_cell(4, 8, 6),
                              I.tpu_isa(include_fused=False))
    assert sel.complete
    assert all(not si.needle.name.startswith("fused.")
               for si in sel.instrs)


def test_transform_path_chosen_when_cheaper():
    """Separable-depthwise: the factorized 2-matmul cover must win over the
    complete-but-huge elementwise cover."""
    sel = select_instructions(
        K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8), I.tpu_isa())
    assert sel.complete
    assert sel.steps and "factor" in sel.steps[0].name
    assert [si.needle.name for si in sel.instrs] == ["mxu.matmul",
                                                     "mxu.matmul"]


def test_selection_orders_by_program_position():
    sel = select_instructions(K.mlp_gate(8, 16, 32), I.tpu_isa())
    firsts = [si.first_stmt for si in sel.instrs]
    assert firsts == sorted(firsts)


def test_statement_cover_is_partition():
    sel = select_instructions(K.gru_cell(2, 4, 4), I.tpu_isa())
    covered = []
    for si in sel.instrs:
        covered.extend(si.mapping.stmt_map)
    assert sorted(covered) == list(range(len(sel.program.statements)))


def test_allow_transforms_false_reports_uncovered():
    from repro.core.instructions import mxu_matmul
    sel = select_instructions(
        K.separable_depthwise_conv(1, 4, 4, 3, 3, 4, 2, 8),
        [mxu_matmul()], allow_transforms=False)
    assert not sel.complete
    assert sel.uncovered
