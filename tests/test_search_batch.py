"""Batched-evaluation tier tests: the vectorized guard and schedule keys
are bit-identical to the scalar path, every strategy produces the identical
winner and trial trace through ``evaluate_many``, incremental re-scheduling
reproduces from-scratch schedules op for op (and the verify layer catches
the two ways a delta resume can go wrong), and ``tune --workers`` merges a
bit-identical cache in deterministic case order."""
import json
import random

import pytest

from repro.compile.driver import (DeltaScheduler, conv_selection,
                                  gemm_selection, gru_selection)
from repro.core.scheduler import (schedule, schedule_incremental,
                                  schedule_with_segments)
from repro.core.sysgraph import paper_accelerator, tpu_v5e
from repro.search.batch import BatchPlan
from repro.search.evaluate import CostModelEvaluator
from repro.search.space import ParamApproach, SearchSpace, config_key
from repro.search.strategies import STRATEGIES
from repro.verify.schedule import verify_reschedule, verify_schedule

GEMM = (256, 192, 130)      # odd k exercises boundary tiles


def _sample_configs(space, n, seed=0):
    configs = list(space.enumerate_configs())
    idx = random.Random(seed).sample(range(len(configs)), n)
    return [configs[i] for i in idx]


# --------------------------------------------------------------------------- #
# Guard + schedule-key parity
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("sel_graph", [
    lambda: (gemm_selection(*GEMM)[1], tpu_v5e(1)),
    lambda: (gru_selection(4, 256, 64)[1], tpu_v5e(1)),
    lambda: (gemm_selection(512, 128, 512)[1], paper_accelerator(2)),
])
def test_batch_guard_matches_scalar(sel_graph):
    sel, graph = sel_graph()
    ev = CostModelEvaluator(sel, graph)
    space = SearchSpace.for_graph(graph)
    configs = _sample_configs(space, 64)
    feasible, keys = ev.plan.analyze(configs, ev.max_tiles)
    for cfg, ok in zip(configs, feasible):
        want = ev.estimated_tiles(ParamApproach(cfg)) <= ev.max_tiles
        assert bool(ok) == want, cfg
    assert len(keys) == len(configs)


def test_equal_keys_mean_equal_cost():
    _, sel = gemm_selection(*GEMM)
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    configs = _sample_configs(space, 48)
    plan = BatchPlan(sel, graph)
    feasible, keys = plan.analyze(configs, 4096)
    by_key = {}
    for cfg, ok, key in zip(configs, feasible, keys):
        if not ok:
            continue
        cost = CostModelEvaluator(sel, graph, incremental=False)(cfg)
        by_key.setdefault(key, set()).add(cost)
    assert by_key, "no feasible config in sample"
    for key, costs in by_key.items():
        assert len(costs) == 1, f"key {key} scored {costs}"


def test_evaluate_many_bit_identical_to_scalar():
    _, sel = gemm_selection(*GEMM)
    graph = tpu_v5e(1)
    space = SearchSpace.for_graph(graph)
    configs = _sample_configs(space, 64)
    batch = CostModelEvaluator(sel, graph)
    scores = batch.evaluate_many(configs)
    scalar = CostModelEvaluator(sel, graph)
    assert scores == [scalar(c) for c in configs]
    assert batch.stats.evals == len(configs)
    assert batch.stats.memo_hits > 0       # 64 samples alias to far fewer keys


def test_unschedulable_selection_scores_inf():
    # On a graph where some instruction has no device, every config is inf
    # through both paths (compile would fail).
    _, sel = gru_selection(4, 64)
    graph = tpu_v5e(1)
    ev = CostModelEvaluator(sel, graph)
    ev.plan.unschedulable = True
    assert ev.evaluate_many([SearchSpace.for_graph(graph).baseline()]) \
        == [float("inf")]


# --------------------------------------------------------------------------- #
# Strategy equivalence: batched == sequential, every strategy
# --------------------------------------------------------------------------- #


CASES = {
    "gemm": lambda: (gemm_selection(*GEMM)[1], tpu_v5e(1)),
    "conv": lambda: (conv_selection(batch=2, h=8, w=8, kh=3, kw=3,
                                    cin=8, cout=8)[1], tpu_v5e(1)),
    "gemm_paper": lambda: (gemm_selection(256, 128, 256)[1],
                           paper_accelerator(2)),
}


def _trace(outcome):
    return [(config_key(t.config), t.cost) for t in outcome.trials]


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("case", sorted(CASES))
def test_strategy_trace_identical_batched_vs_scalar(strategy, case):
    sel, graph = CASES[case]()
    space = SearchSpace.for_graph(graph)
    kw = {}
    if strategy == "surrogate":
        # deterministic fake predictor: enough to drive the ranking phases
        kw = {"predict":
              lambda c: float(abs(hash(config_key(c))) % 997) / 997.0,
              "seeds": [space.baseline()]}
    batched = CostModelEvaluator(sel, graph)
    seq_ev = CostModelEvaluator(sel, graph)
    out_b = STRATEGIES[strategy](space, batched, trials=16, seed=7, **kw)
    out_s = STRATEGIES[strategy](space, lambda c: seq_ev(c),
                                 trials=16, seed=7, **kw)
    assert _trace(out_b) == _trace(out_s)
    assert config_key(out_b.best_config) == config_key(out_s.best_config)
    assert out_b.best_cost == out_s.best_cost


# --------------------------------------------------------------------------- #
# Incremental re-scheduling
# --------------------------------------------------------------------------- #


def _hetero_gru():
    """input dim != hidden dim: instruction 0's reduction (k=64) is below
    the hardware tile, hence cap-invariant — tile_k changes share its
    prefix and first_changed is 1."""
    _, sel = gru_selection(4, 256, 64)
    return sel, tpu_v5e(1)


def _ops_equal(a, b) -> bool:
    if len(a.ops) != len(b.ops):
        return False
    for x, y in zip(a.ops, b.ops):
        if (x.kind, x.device, x.src, x.dst, x.region, x.start, x.end) \
                != (y.kind, y.device, y.src, y.dst, y.region, y.start, y.end):
            return False
        tx, ty = x.tile, y.tile
        if (tx is None) != (ty is None):
            return False
        if tx is not None and (tx.instr_idx, tx.needle_name, tx.offsets,
                               tx.sizes, tx.device) \
                != (ty.instr_idx, ty.needle_name, ty.offsets, ty.sizes,
                    ty.device):
            return False
    return True


def test_incremental_reschedule_bit_exact():
    sel, graph = _hetero_gru()
    base = SearchSpace.for_graph(graph).baseline()
    parent, segments = schedule_with_segments(sel, graph, ParamApproach(base))
    assert _ops_equal(parent, schedule(sel, graph, ParamApproach(base)))
    for tk in (128, 256):
        child_ap = ParamApproach(dict(base, tile_k=tk))
        inc, _ = schedule_incremental(sel, graph, child_ap, parent,
                                      segments, 1)
        full = schedule(sel, graph, child_ap)
        assert inc.makespan == full.makespan
        assert _ops_equal(inc, full)
        assert inc.final_residency == full.final_residency
        assert verify_reschedule(inc, sel, child_ap, graph) == []


def test_incremental_fallback_without_usable_segment():
    # first_changed 0 (or a missing segment) must degrade to a full
    # from-scratch schedule, never a wrong one.
    sel, graph = _hetero_gru()
    base = SearchSpace.for_graph(graph).baseline()
    ap = ParamApproach(base)
    parent, segments = schedule_with_segments(sel, graph, ap)
    sched, _ = schedule_incremental(sel, graph, ap, parent, {}, 5)
    assert _ops_equal(sched, parent)
    sched0, _ = schedule_incremental(sel, graph, ap, parent, segments, 0)
    assert _ops_equal(sched0, parent)


def test_delta_scheduler_fires_and_matches():
    sel, graph = _hetero_gru()
    space = SearchSpace.for_graph(graph)
    base = space.baseline()
    sweep = [dict(base, tile_k=tk, vmem_frac=vf)
             for tk in (None, 128, 256, 512) for vf in (1.0, 0.5)]
    ev = CostModelEvaluator(sel, graph)
    scores = ev.evaluate_many(sweep)
    assert ev.stats.delta > 0, "incremental path never fired"
    check = CostModelEvaluator(sel, graph, incremental=False)
    assert scores == check.evaluate_many(sweep)


def test_delta_scheduler_respects_policy_suffix():
    # An anchor with a different unroll/device/source policy must never be
    # resumed from — keys carry the policy suffix and DeltaScheduler only
    # matches same-policy anchors.
    sel, graph = _hetero_gru()
    base = SearchSpace.for_graph(graph).baseline()
    plan = BatchPlan(sel, graph)
    delta = DeltaScheduler(sel, graph)
    cfg_a = dict(base)
    cfg_b = dict(base, tile_k=128, unroll="red_major")
    _, (key_a, key_b) = plan.analyze([cfg_a, cfg_b], 4096)
    delta.schedule_for(ParamApproach(cfg_a), key_a)
    sched = delta.schedule_for(ParamApproach(cfg_b), key_b)
    assert delta.stats == {"fresh": 2, "delta": 0}
    full = schedule(sel, graph, ParamApproach(cfg_b))
    assert _ops_equal(sched, full)


# --------------------------------------------------------------------------- #
# Verify layer: the two incremental corruption classes
# --------------------------------------------------------------------------- #


def test_stale_stream_is_replay_silent_but_caught():
    from repro.verify.mutate import _incremental_bundle
    b = _incremental_bundle()
    bad, _ = schedule_incremental(b.selection, b.sysgraph, b.approach,
                                  b.parent_schedule, b.segments,
                                  b.first_changed + 1)
    # self-consistent splice: the replay rules all stay silent...
    assert verify_schedule(bad, b.approach) == []
    # ...but the tile recomputation flags the stale instruction
    diags = verify_reschedule(bad, b.selection, b.approach, b.sysgraph)
    assert [d.rule for d in diags] == ["sch.tile-mismatch"]


def test_incremental_mutations_caught():
    from repro.verify.mutate import run_mutation
    for name in ("inc-stale-stream", "inc-wrong-instr"):
        res = run_mutation(name)
        assert res.caught, str(res)


def test_incremental_bundle_baseline_clean():
    from repro.verify.mutate import _incremental_bundle, _verify_bundle
    assert _verify_bundle(_incremental_bundle()) == []


# --------------------------------------------------------------------------- #
# Parallel tuning: deterministic shared-cache merge
# --------------------------------------------------------------------------- #


def test_tune_workers_deterministic(tmp_path):
    from repro.search.tune import main
    seq_cache = tmp_path / "seq.json"
    par_cache = tmp_path / "par.json"
    seq_json = tmp_path / "seq_rep.json"
    par_json = tmp_path / "par_rep.json"
    common = ["--suite", "gemm", "--limit", "2", "--trials", "8",
              "--no-validate"]
    assert main(common + ["--cache", str(seq_cache),
                          "--json", str(seq_json)]) == 0
    assert main(common + ["--cache", str(par_cache),
                          "--json", str(par_json), "--workers", "2"]) == 0
    assert json.loads(seq_cache.read_text()) \
        == json.loads(par_cache.read_text())

    def rows(path):
        return [{k: v for k, v in r.items()
                 if k not in ("elapsed_s", "counters")}
                for r in json.loads(path.read_text())["rows"]]
    assert rows(seq_json) == rows(par_json)


def test_tune_json_reports_throughput_counters(tmp_path):
    from repro.search.tune import main
    out = tmp_path / "rep.json"
    assert main(["--suite", "gemm", "--limit", "1", "--trials", "8",
                 "--no-validate", "--cache", str(tmp_path / "c.json"),
                 "--json", str(out)]) == 0
    row = json.loads(out.read_text())["rows"][0]
    counters = row["counters"]
    for field in ("evals", "guard_rejects", "memo_hits", "fresh", "delta",
                  "schedule_s", "predict_s", "configs_per_sec"):
        assert field in counters, field
    assert counters["evals"] > 0
    assert counters["configs_per_sec"] > 0


def test_file_lock_serializes_concurrent_saves(tmp_path):
    # Two stores saving "concurrently" (interleaved in one process) must
    # both survive: the lock serializes the merge-on-save read-modify-write.
    from repro.search.cache import TuningCache, TuningRecord
    path = str(tmp_path / "cache.json")
    a, b = TuningCache(path), TuningCache(path)
    a.store(TuningRecord(key="ka", config={}, cost=1.0, baseline_cost=1.0),
            save=False)
    b.store(TuningRecord(key="kb", config={}, cost=2.0, baseline_cost=2.0),
            save=False)
    a.save()
    b.save()
    assert set(TuningCache(path).load()) == {"ka", "kb"}
