"""Pallas kernel tests: shape/dtype sweeps in interpret mode against the
pure-jnp oracles, plus the ISAM->BlockSpec bridge."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gemm import gemm, gemm_bias_act
from repro.kernels.gru import PARAM_NAMES, gru_cell, gru_seq


def rand(rng, shape, dtype):
    x = rng.uniform(-1, 1, size=shape)
    return jnp.asarray(x, dtype=dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128),            # exact MXU tile
    (256, 128, 384),            # multi-tile, divisible
    (64, 48, 96),               # sub-tile
    (130, 70, 190),             # ragged: exercises padding
    (1, 128, 512),              # skinny (decode-like)
    (512, 1, 64),               # skinny the other way
])
def test_gemm_matches_ref(m, n, k, dtype):
    rng = np.random.default_rng(m * 7 + n * 3 + k)
    a, b = rand(rng, (m, k), dtype), rand(rng, (k, n), dtype)
    got = gemm(a, b, interpret=True)
    want = ref.gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block", [(32, 32, 32), (64, 128, 32), (128, 64, 256)])
def test_gemm_block_sweep(block):
    rng = np.random.default_rng(0)
    a, b = rand(rng, (160, 96), jnp.float32), rand(rng, (96, 224), jnp.float32)
    got = gemm(a, b, block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fn", ["", "sigmoid", "tanh", "relu"])
def test_gemm_bias_act(fn):
    rng = np.random.default_rng(1)
    a, b = rand(rng, (96, 64), jnp.float32), rand(rng, (64, 80), jnp.float32)
    bias = rand(rng, (80,), jnp.float32)
    got = gemm_bias_act(a, b, bias, fn=fn, interpret=True)
    want = ref.gemm_bias_act_ref(a, b, bias, fn=fn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def make_gru_params(rng, E, H, dtype=jnp.float32):
    p = {}
    for name in PARAM_NAMES:
        if name.startswith("W"):
            p[name] = rand(rng, (E, H), dtype)
        elif name.startswith("U"):
            p[name] = rand(rng, (H, H), dtype)
        else:
            p[name] = rand(rng, (H,), dtype)
    return p


@pytest.mark.parametrize("B,E,H", [(4, 16, 32), (8, 64, 64), (3, 10, 50)])
def test_gru_cell_matches_ref(B, E, H):
    rng = np.random.default_rng(B + E + H)
    p = make_gru_params(rng, E, H)
    x, h = rand(rng, (B, E), jnp.float32), rand(rng, (B, H), jnp.float32)
    got = gru_cell(x, h, p, block=(4, 32), interpret=True)
    want = ref.gru_cell_ref(x, h, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gru_seq_matches_ref():
    rng = np.random.default_rng(9)
    T, B, E, H = 5, 4, 12, 24
    p = make_gru_params(rng, E, H)
    xs = rand(rng, (T, B, E), jnp.float32)
    h0 = rand(rng, (B, H), jnp.float32)
    got = gru_seq(xs, h0, p, block=(4, 24), interpret=True)
    want = ref.gru_seq_ref(xs, h0, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_isam_plans_gemm_tile():
    """The ISAM schedule must produce an MXU-aligned tile for a big GEMM and
    a clipped tile for a small one."""
    tile, secs = ops.plan_gemm(1024, 1024, 1024)
    assert tile[0] == 128
    assert tile[1] % 128 == 0      # j grows into the VMEM budget, MXU-aligned
    assert tile[2] >= 128          # k streams as deep as VMEM allows
    assert secs > 0
    tile_small, _ = ops.plan_gemm(32, 32, 32)
    assert tile_small == (32, 32, 32)


def test_scheduled_gemm_executes():
    rng = np.random.default_rng(2)
    a = rand(rng, (192, 64), jnp.float32)
    b = rand(rng, (64, 160), jnp.float32)
    got = ops.scheduled_gemm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)
