"""Autotuning walkthrough: search the mapping/schedule space, persist the
winner, and watch the kernels pick it up (paper Section 4).

    PYTHONPATH=src python examples/autotune_gemm.py

1. Build a GEMM haystack and select the MXU matmul instruction.
2. Search the ParamApproach config space (tile shapes, reduction streaming,
   VMEM budget, unroll order, device/copy policies) against the static
   scheduler's cost model — the greedy-equivalent baseline is trial 0, so
   the result can only match or beat the paper's heuristics.
3. Validate: the winning schedule replays bit-exact against the ISAMIR
   oracle through the executor.
4. Persist the winner in the tuning cache and read it back the way
   ``kernels/gemm.py`` does at run time.
5. Run the tuned block shape through the Pallas GEMM.

The same flow over the paper's full evaluation set is the CLI:

    PYTHONPATH=src python -m repro.search.tune --suite gemm --trials 32
"""
import os
import tempfile

import numpy as np

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.isel import select_instructions
from repro.core.sysgraph import tpu_v5e
from repro.search.cache import (TuningCache, TuningRecord, lookup_gemm,
                                set_default_cache)
from repro.search.evaluate import (CostModelEvaluator, gemm_tile_for,
                                   validate_selection)
from repro.search.space import ParamApproach, SearchSpace, tuning_key
from repro.search.strategies import hill_climb

M, N, KDIM = 1024, 128, 1024

# 1. map + select ------------------------------------------------------------
prog = K.matmul(M, N, KDIM)
sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
graph = tpu_v5e(1)

# 2. search ------------------------------------------------------------------
space = SearchSpace.for_graph(graph)
evaluate = CostModelEvaluator(sel, graph)
outcome = hill_climb(space, evaluate, trials=24, seed=0)
print(f"== search: {outcome.evaluations} trials ==")
print(f"greedy baseline : {outcome.baseline_cost * 1e6:8.2f} us (modeled)")
print(f"tuned           : {outcome.best_cost * 1e6:8.2f} us "
      f"({outcome.speedup:.2f}x)")
changed = {k: v for k, v in outcome.best_config.items()
           if v != space.baseline()[k]}
print(f"winning moves   : {changed or 'none (greedy is optimal here)'}")

# 3. oracle validation --------------------------------------------------------
report = validate_selection(prog, sel, graph,
                            ParamApproach(outcome.best_config))
assert report.exact, report
print("tuned schedule replays bit-exact against the ISAMIR oracle")

# 4. persist + read back -------------------------------------------------------
cache_path = os.path.join(tempfile.mkdtemp(prefix="repro_tune_"),
                          "tuning.json")
cache = TuningCache(cache_path)
cache.store(TuningRecord(
    key=tuning_key(prog, graph, "cost"), config=outcome.best_config,
    cost=outcome.best_cost, baseline_cost=outcome.baseline_cost,
    strategy="hillclimb", trials=outcome.evaluations,
    tile=gemm_tile_for(outcome.best_config, graph, M, N, KDIM)))
set_default_cache(cache)          # what `--tuned` launches do
rec = lookup_gemm(M, N, KDIM)
print(f"cache {cache_path}: tile={rec.tile} "
      f"speedup={rec.speedup:.2f}x")

# 5. tuned Pallas kernel --------------------------------------------------------
import jax.numpy as jnp

from repro.kernels.gemm import gemm, tuned_block
from repro.kernels.ref import gemm_ref

block = tuned_block(M, N, KDIM)
assert block == rec.tile
rng = np.random.default_rng(0)
a = jnp.asarray(rng.uniform(-1, 1, (M, KDIM)), jnp.float32)
b = jnp.asarray(rng.uniform(-1, 1, (KDIM, N)), jnp.float32)
out = gemm(a, b, block=block, interpret=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(gemm_ref(a, b)),
                           rtol=1e-4, atol=1e-4)
print(f"Pallas GEMM with tuned BlockSpec {block}: OK")
