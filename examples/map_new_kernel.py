"""Bring-your-own-kernel: write a NEW operation in ISAMIR and let the
compiler map + schedule it with zero per-kernel engineering — the paper's
core pitch ("novel kernels without kernel-library additions").

    PYTHONPATH=src python examples/map_new_kernel.py

The kernel here is a *gated cross-channel mixer* (invented for this demo):

    Y[b, t, o] = sigmoid(sum_c X[b, t, c] * G[c, o]) * (sum_c X[b, t, c] * U[c, o])

ISAM factors it into two matmuls + fused elementwise automatically.
"""
import numpy as np

from repro.core import instructions as I
from repro.core.executor import execute
from repro.core.ir import ProgramBuilder, interpret, random_inputs
from repro.core.isel import select_instructions
from repro.core.scheduler import schedule
from repro.core.sysgraph import tpu_v5e

B, T, C, O = 4, 32, 96, 64

pb = ProgramBuilder("gated_mixer")
b, t, o, c = pb.axes(b=B, t=T, o=O, c=C)
X = pb.buffer("X", (B, T, C))
G = pb.buffer("G", (C, O))
U = pb.buffer("U", (C, O))
Gate = pb.buffer("Gate", (B, T, O), temp=True)
Up = pb.buffer("Up", (B, T, O), temp=True)
Y = pb.buffer("Y", (B, T, O))
t1 = pb.temp("t1", (B, T, O, C))
t2 = pb.temp("t2", (B, T, O, C))
pb.stmt(t1[b, t, o, c], ":=", X[b, t, c])
pb.stmt(t1[b, t, o, c], "*=", G[c, o])
pb.stmt(Gate[b, t, o], "+=", t1[b, t, o, c])
pb.apply(Gate[b, t, o], "sigmoid", Gate[b, t, o])
pb.stmt(t2[b, t, o, c], ":=", X[b, t, c])
pb.stmt(t2[b, t, o, c], "*=", U[c, o])
pb.stmt(Up[b, t, o], "+=", t2[b, t, o, c])
pb.stmt(Y[b, t, o], ":=", Gate[b, t, o])
pb.stmt(Y[b, t, o], "*=", Up[b, t, o])
pb.output("Y")
prog = pb.build()
print(prog.pretty())

sel = select_instructions(prog, I.tpu_isa())
assert sel.complete
print("\nmapped to:", [si.needle.name for si in sel.instrs])

sched = schedule(sel, tpu_v5e(1))
print(f"schedule: {sched.counts()}, modeled {sched.makespan*1e6:.1f} us")

rng = np.random.default_rng(3)
ins = random_inputs(prog, rng)
got = execute(sched, sel, ins)["Y"]
want = interpret(prog, ins)["Y"]
np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

sig = 1 / (1 + np.exp(-(ins["X"] @ ins["G"])))
ref = sig * (ins["X"] @ ins["U"])
np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)
print("new kernel mapped, scheduled and executed correctly — no "
      "hand-written lowering rule involved")
