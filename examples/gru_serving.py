"""GRU RNN serving via the recurrent scheduler (paper Section 3.6 + Fig. 4).

    PYTHONPATH=src python examples/gru_serving.py

Builds the GRU cell in ISAMIR, schedules priming/recursive/finish streams on
the paper's case-study accelerator, executes a 32-step sequence, and compares
modeled cycles against composed kernel-library calls.
"""
import numpy as np

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.ir import interpret
from repro.core.isel import select_instructions
from repro.core.recurrent import execute_recurrent, schedule_recurrent
from repro.core.sysgraph import paper_accelerator

B, H, E, T = 16, 256, 128, 32
prog = K.gru_cell(B, H, E)
sel = select_instructions(prog, I.tpu_isa())
print("selected instructions:",
      [si.needle.name for si in sel.instrs][:8], "...")

graph = paper_accelerator(n_clusters=2)
rs = schedule_recurrent(sel, graph, carry={"Hout": "H"}, streamed=("X",))
print("copies per stream:", rs.copy_counts())
print(f"modeled: prime={rs.prime.makespan*1e6:.1f}us "
      f"recursive={rs.recursive.makespan*1e6:.1f}us "
      f"finish={rs.finish.makespan*1e6:.1f}us "
      f"-> total({T} steps)={rs.total_time(T)*1e6:.1f}us")

rng = np.random.default_rng(1)
weights = {n: rng.uniform(-0.4, 0.4, size=prog.buffer(n).shape)
           for n in ("Wr", "Ur", "Wz", "Uz", "Wn", "Un",
                     "br", "bz", "bnx", "bnh")}
h0 = rng.uniform(-0.5, 0.5, size=(B, H))
xs = [{"X": rng.uniform(-0.5, 0.5, size=(B, E))} for _ in range(T)]

got = execute_recurrent(rs, sel, xs, {**weights, "H": h0})["Hout"]
h = h0
for t in range(T):
    h = interpret(prog, {**weights, "H": h, **xs[t]})["Hout"].astype(float)
np.testing.assert_allclose(got, h, rtol=1e-4, atol=1e-5)
print(f"{T}-step GRU execution matches the oracle; weights stayed resident "
      f"({rs.copy_counts()['recursive']} copies/step in steady state vs "
      f"{rs.copy_counts()['prime']} cold)")
