"""Fabric walkthrough: shard a DeepBench GEMM over a 4-chip ICI ring,
simulate the distributed schedule, and validate it bit-exact.

    PYTHONPATH=src python examples/fabric_gemm.py

1. Build the 4-chip ring fabric and its multi-chip system graph.
2. Partition the GEMM along each axis (m / n / k) — each choice implies a
   different collective (none / operand all-gather / reduce-scatter).
3. Simulate: per-chip static schedules + collective COPY streams replayed
   on one event timeline with compute/communication overlap; compare every
   axis's modeled makespan against the 1-chip schedule.
4. Re-materialize the sharded outputs through the executor and check them
   bit-exact against the single-chip ISAMIR oracle (proxy-sized).
5. Tune (partition axis, collective algorithm, per-chip tiles) jointly.

The same flow as a CLI:

    PYTHONPATH=src python -m repro.fabric.simulate \\
        --shape 5124x700x2048 --chips 4 --topology ring
"""
from repro.fabric.collectives import ALGORITHMS
from repro.fabric.partition import partition_gemm, replay_bitexact
from repro.fabric.simulate import (FabricEvaluator, simulate_partition,
                                   single_chip_makespan)
from repro.fabric.topology import Topology, ring
from repro.search.space import SearchSpace
from repro.search.strategies import hill_climb

M, N, KDIM = 5124, 700, 2048
CHIPS = 4

# 1. the fabric ---------------------------------------------------------------
topo = ring(CHIPS)
graph = topo.build_graph()
print(f"== fabric {topo.name}: {len(topo.links)} ICI links at "
      f"{topo.min_link_bandwidth() / 1e9:.0f} GB/s, "
      f"{len(graph.computes)} cores ==")

# 2 + 3. partition and simulate every axis ------------------------------------
chip_graph = Topology.chip_graph()
one = single_chip_makespan(partition_gemm(M, N, KDIM, "m", 1), chip_graph)
print(f"1-chip modeled makespan : {one * 1e6:8.2f} us")
best = None
for axis in ("m", "n", "k"):
    pp = partition_gemm(M, N, KDIM, axis, CHIPS)
    res = min((simulate_partition(pp, topo, None, alg, chip_graph)
               for alg in ALGORITHMS), key=lambda r: r.makespan)
    collectives = [f"{c.kind}({c.buffer})" for c in pp.collectives] or ["none"]
    print(f"axis={axis}: {res.makespan * 1e6:8.2f} us "
          f"({one / res.makespan:4.2f}x vs 1 chip)  "
          f"collectives={','.join(collectives)} alg={res.algorithm}")
    if best is None or res.makespan < best[1].makespan:
        best = (pp, res)

# 4. bit-exact re-materialization (proxy-sized: the NumPy oracle cannot
#    hold the full-shape temporaries) ----------------------------------------
proxy = partition_gemm(192, 192, 192, best[0].axis, CHIPS)
report = replay_bitexact(proxy, chip_graph)
assert report.exact, report
print(f"axis={best[0].axis} sharded replay is bit-exact vs the 1-chip oracle")

# 5. joint distributed tuning --------------------------------------------------
space = SearchSpace.for_fabric("gemm")
outcome = hill_climb(space, FabricEvaluator("gemm", (M, N, KDIM), topo),
                     trials=12, seed=0)
moves = {k: v for k, v in outcome.best_config.items()
         if v != space.baseline()[k]}
print(f"joint tune: baseline {outcome.baseline_cost * 1e6:.2f} us -> "
      f"{outcome.best_cost * 1e6:.2f} us "
      f"({outcome.speedup:.2f}x); moves: {moves or 'baseline is optimal'}")
