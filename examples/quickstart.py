"""Quickstart: the ISA Mapper pipeline end to end on one kernel.

    PYTHONPATH=src python examples/quickstart.py

1. Express a 1-D convolution in ISAMIR (the paper's Listing 5).
2. Deterministically map it onto the MXU matmul instruction (Listing 6).
3. Statically schedule it on a TPU v5e system graph (tiles, copies,
   cache-tracked memory movement).
4. Execute the recorded instruction stream and check it against the oracle.
5. Run the same GEMM through the ISAM-planned Pallas kernel.
"""
import numpy as np

from repro.core import instructions as I
from repro.core import kernels_ir as K
from repro.core.executor import execute
from repro.core.ir import interpret, random_inputs
from repro.core.isel import select_instructions
from repro.core.mapper import map_program
from repro.core.scheduler import schedule
from repro.core.sysgraph import tpu_v5e

# 1. the haystack program ----------------------------------------------------
conv = K.conv1d(batch=4, width=32, kw=3, cin=64, cout=64)
print("== ISAMIR (paper Listing 5) ==")
print(conv.pretty())

# 2. deterministic mapping ----------------------------------------------------
result = map_program(conv, I.mxu_matmul())
print(f"\n== {len(result.mappings)} mappings found ==")
best = result.best(conv)
print(f"best: axis_map={dict(best.axis_map)} outer={best.outer_axes} "
      f"calls={best.calls(conv)}")

# 3. instruction selection + static schedule ----------------------------------
sel = select_instructions(conv, I.tpu_isa())
graph = tpu_v5e(n_cores=1)
sched = schedule(sel, graph)
print(f"\n== schedule: {sched.counts()} ops, modeled "
      f"{sched.makespan * 1e6:.1f} us, {sched.bytes_moved()} bytes moved ==")

# 4. replay execution vs the oracle --------------------------------------------
rng = np.random.default_rng(0)
ins = random_inputs(conv, rng)
got = execute(sched, sel, ins)["C"]
want = interpret(conv, ins)["C"]
np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
print("replayed instruction stream matches the ISAMIR oracle")

# 5. ISAM-planned Pallas GEMM ---------------------------------------------------
import jax.numpy as jnp
from repro.kernels.ops import plan_gemm, scheduled_gemm
from repro.kernels.ref import gemm_ref

tile, modeled = plan_gemm(512, 256, 1024)
a = jnp.asarray(rng.uniform(-1, 1, (512, 1024)), jnp.float32)
b = jnp.asarray(rng.uniform(-1, 1, (1024, 256)), jnp.float32)
out = scheduled_gemm(a, b, interpret=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(gemm_ref(a, b)),
                           rtol=1e-5, atol=1e-5)
print(f"Pallas GEMM with ISAM-chosen BlockSpec tile {tile}: OK "
      f"(modeled {modeled * 1e6:.1f} us on v5e)")
