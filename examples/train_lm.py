"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the full production stack — config, sharded train step, deterministic
data pipeline, AdamW, checkpoint/restart runtime — on a ~100M-param dense
model (a scaled olmo family member).  On CPU this takes a few minutes; on a
pod the same driver takes the full config and production mesh.
"""
import argparse

import jax

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_source
from repro.launch.mesh import make_host_mesh
from repro.launch.train import build_trainer
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import TrainingRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--width", type=int, default=768,
                    help="d_model (default 768 -> ~100M params; use 256 "
                         "for a fast single-CPU-core run)")
    ap.add_argument("--layers", type=int, default=12)
    args = ap.parse_args()

    # default: ~100M params (olmo family at width 768 / depth 12).
    # On one CPU core the full size takes ~1 h for 300 steps; pass
    # --width 256 --layers 6 for a minutes-scale demo of the same stack.
    cfg = get_config("olmo-1b").scaled(
        n_layers=args.layers, d_model=args.width,
        n_heads=args.width // 64, n_kv_heads=args.width // 64,
        d_ff=4 * args.width, vocab_size=32000, remat=False)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params ({cfg.n_layers}L x "
          f"{cfg.d_model}d)")

    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    model, init_state, step, _ = build_trainer(cfg, opt_cfg, mesh)

    dcfg = DataConfig(seed=11, global_batch=args.batch, seq_len=args.seq)
    source = make_source(dcfg, cfg)

    ckpt = Checkpointer("artifacts/ckpt_train_lm")
    rt = TrainingRuntime(ckpt, save_every=100)
    carry = init_state(jax.random.PRNGKey(7))

    losses = []

    def on_metrics(s, m, dt, slow):
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d} loss {losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)

    rt.run(carry, step, lambda s: source.batch(s), args.steps, on_metrics)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
