"""Pytest bootstrap for a clean checkout.

1. Puts ``src/`` on sys.path so ``import repro`` works without an editable
   install or PYTHONPATH (the tier-1 command still sets PYTHONPATH=src; both
   paths lead to the same package).
2. Installs the AbstractMesh signature compat so the sharding spec tests
   (written against the modern ``AbstractMesh(sizes, names)`` API) run on
   jax 0.4.3x too.
3. If ``hypothesis`` is not installed, registers the minimal fallback in
   ``tests/_hypothesis_fallback.py`` under the ``hypothesis`` name so the
   property tests still collect and run (see pyproject.toml for the real
   dependency).
"""
import importlib.util
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.dist.compat import install_abstract_mesh_compat  # noqa: E402

install_abstract_mesh_compat()

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(_ROOT, "tests", "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
