"""System description graph (paper Section 3.2).

The machine is described as a graph of *compute nodes* (which instructions
they execute, out of which memory), *memory nodes* (capacity, level), and
*data-movement edges* (bandwidth/latency, which device issues the copy).
Nodes are stateful during scheduling: memory nodes track resident buffer
copies, compute nodes accumulate their instruction streams — the graph is the
hardware abstraction layer the static scheduler dry-runs against.

Two factories are provided:

  * ``tpu_v5e(n_cores)`` — the TPU target: HBM (819 GB/s, 16 GiB) feeding
    per-core VMEM (128 MiB) feeding an MXU (matmul) + VPU (elementwise).
  * ``paper_accelerator(n_clusters)`` — the paper's case-study device
    (Section 5): clusters of paired processing units sharing register files,
    several HBM modules, everything explicitly managed.  Used by the GEMM and
    GRU benchmarks so results are comparable with the paper's Figures 3-4.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MemoryNode:
    name: str
    capacity: int                  # bytes
    level: int                     # 0 = host/system memory, larger = closer


@dataclass(frozen=True)
class ComputeNode:
    name: str
    memory: str                    # the memory node operands must reside in
    instructions: frozenset[str]   # needle-name prefixes it can execute
    flops_per_sec: float
    matmul_tile: tuple[int, int, int] = (128, 128, 128)
    vector_lanes: int = 8 * 128    # VPU elements per cycle
    clock_hz: float = 0.94e9

    def executes(self, needle_name: str) -> bool:
        return any(needle_name.startswith(p) for p in self.instructions)


@dataclass(frozen=True)
class MoveEdge:
    src: str
    dst: str
    bandwidth: float               # bytes / sec
    latency: float                 # sec per transfer issue
    issuer: str = "host"           # device that emits the copy instruction


@dataclass
class SystemGraph:
    name: str
    memories: dict[str, MemoryNode] = field(default_factory=dict)
    computes: dict[str, ComputeNode] = field(default_factory=dict)
    edges: list[MoveEdge] = field(default_factory=list)

    # -- construction -------------------------------------------------------
    def add_memory(self, name: str, capacity: int, level: int) -> None:
        self.memories[name] = MemoryNode(name, capacity, level)

    def add_compute(self, name: str, memory: str, instructions, flops: float,
                    **kw) -> None:
        self.computes[name] = ComputeNode(name, memory, frozenset(instructions),
                                          flops, **kw)

    def add_edge(self, src: str, dst: str, bandwidth: float,
                 latency: float = 1e-6, issuer: str = "host",
                 bidirectional: bool = True,
                 rev_issuer: str | None = None) -> None:
        """Add a movement edge (and, by default, its reverse).

        ``issuer`` is the device that emits the forward copy; the reverse
        copy is emitted by ``rev_issuer`` when given (a pull-style DMA is
        issued by the *receiving* side, so the two directions generally
        have different issuers) and falls back to ``issuer`` otherwise.
        """
        self.edges.append(MoveEdge(src, dst, bandwidth, latency, issuer))
        if bidirectional:
            self.edges.append(MoveEdge(dst, src, bandwidth, latency,
                                       rev_issuer or issuer))

    # -- queries --------------------------------------------------------------
    def min_matmul_tile(self) -> tuple[int, int, int]:
        """The smallest hardware matmul tile across compute nodes (lexico
        min; all real graphs have uniform tiles).  The single definition
        behind the search space's tile choices and the learned cost model's
        tile features — they must agree on what "1x the hw tile" means."""
        tiles = {c.matmul_tile for c in self.computes.values()}
        return min(tiles) if tiles else (128, 128, 128)

    def edge(self, src: str, dst: str) -> MoveEdge:
        for e in self.edges:
            if e.src == src and e.dst == dst:
                return e
        raise KeyError(f"no edge {src} -> {dst}")

    def out_edges(self, src: str) -> list[MoveEdge]:
        return [e for e in self.edges if e.src == src]

    def shortest_path(self, src: str, dst: str,
                      nbytes: int = 1 << 20) -> list[MoveEdge]:
        """Min-cost path by modeled transfer time of ``nbytes`` (paper 3.5:
        'simply finding a shortest-path tends to work relatively well')."""
        if src == dst:
            return []
        dist = {src: 0.0}
        prev: dict[str, MoveEdge] = {}
        pq = [(0.0, src)]
        while pq:
            d, u = heapq.heappop(pq)
            if u == dst:
                break
            if d > dist.get(u, float("inf")):
                continue
            for e in self.out_edges(u):
                nd = d + e.latency + nbytes / e.bandwidth
                if nd < dist.get(e.dst, float("inf")):
                    dist[e.dst] = nd
                    prev[e.dst] = e
                    heapq.heappush(pq, (nd, e.dst))
        if dst not in prev:
            raise KeyError(f"no path {src} -> {dst}")
        path, cur = [], dst
        while cur != src:
            e = prev[cur]
            path.append(e)
            cur = e.src
        return list(reversed(path))

    def compute_nodes_for(self, needle_name: str) -> list[ComputeNode]:
        return [c for c in self.computes.values() if c.executes(needle_name)]

    def memory_of(self, compute: str) -> MemoryNode:
        return self.memories[self.computes[compute].memory]


# --------------------------------------------------------------------------- #
# Hardware constants (v5e) — shared with the roofline analysis
# --------------------------------------------------------------------------- #

V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
V5E_HBM_BW = 819e9             # bytes/s
V5E_HBM_BYTES = 16 << 30
V5E_VMEM_BYTES = 128 << 20
V5E_ICI_BW = 50e9              # bytes/s per link
V5E_CLOCK = 0.94e9


def add_v5e_chip(g: SystemGraph, c: int, host_mem_node: str = "host") -> None:
    """Add one v5e chip (HBM + VMEM + core, PCIe-attached to the host) to
    ``g``.  Fabric wiring between chips is layered on top by
    ``repro.fabric.topology`` — this helper deliberately knows nothing
    about inter-chip links."""
    hbm, vmem = f"hbm{c}", f"vmem{c}"
    g.add_memory(hbm, V5E_HBM_BYTES, level=1)
    g.add_memory(vmem, V5E_VMEM_BYTES, level=2)
    # PCIe: host pushes down, the chip's core DMAs back up.
    g.add_edge(host_mem_node, hbm, bandwidth=32e9, latency=2e-6,
               issuer="host", rev_issuer=f"core{c}")
    g.add_edge(hbm, vmem, bandwidth=V5E_HBM_BW, latency=1e-7,
               issuer=f"core{c}")
    g.add_compute(
        f"core{c}", vmem,
        {"mxu.", "vpu.", "fused."},
        flops=V5E_PEAK_FLOPS,
        matmul_tile=(128, 128, 128), vector_lanes=8 * 128,
        clock_hz=V5E_CLOCK)


def tpu_v5e(n_cores: int = 1, host_mem: int = 512 << 30) -> SystemGraph:
    """One v5e chip (or several connected by an ICI ring) as a system graph.

    Multi-chip wiring is delegated to ``repro.fabric.topology.ring`` — a
    proper bidirectional ring (with the wraparound link the old ad-hoc
    wiring was missing) whose per-direction copies are issued by the
    receiving chip's core."""
    g = SystemGraph(f"tpu_v5e_x{n_cores}")
    g.add_memory("host", host_mem, level=0)
    for c in range(n_cores):
        add_v5e_chip(g, c)
    if n_cores > 1:
        from ..fabric.topology import ring
        ring(n_cores).wire_ici(g)
    return g


def paper_accelerator(n_clusters: int = 2, regfile_bytes: int = 8 << 20,
                      hbm_modules: int = 2) -> SystemGraph:
    """The paper's case-study architecture (Section 5): clusters of paired
    matrix/elementwise processing units sharing large register files, several
    HBM modules, no cache hierarchy — all memory explicitly managed."""
    g = SystemGraph(f"paper_accel_x{n_clusters}")
    g.add_memory("host", 512 << 30, level=0)
    for m in range(hbm_modules):
        g.add_memory(f"hbm{m}", 8 << 30, level=1)
        g.add_edge("host", f"hbm{m}", bandwidth=32e9, latency=2e-6)
    for c in range(n_clusters):
        rf = f"rf{c}"
        g.add_memory(rf, regfile_bytes, level=2)
        for m in range(hbm_modules):
            g.add_edge(f"hbm{m}", rf, bandwidth=400e9, latency=2e-7,
                       issuer=f"pu{c}a")
        # the paired processing units sharing one register file set
        for suffix in ("a", "b"):
            g.add_compute(
                f"pu{c}{suffix}", rf,
                {"mxu.", "vpu.", "fused."},
                flops=25e12, matmul_tile=(64, 64, 64), vector_lanes=256,
                clock_hz=1.0e9)
    return g
