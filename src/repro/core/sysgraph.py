"""System description graph (paper Section 3.2).

The machine is described as a graph of *compute nodes* (which instructions
they execute, out of which memory), *memory nodes* (capacity, level), and
*data-movement edges* (bandwidth/latency, which device issues the copy).
Nodes are stateful during scheduling: memory nodes track resident buffer
copies, compute nodes accumulate their instruction streams — the graph is the
hardware abstraction layer the static scheduler dry-runs against.

Three factories are provided:

  * ``tpu_v5e(n_cores)`` — the TPU target: HBM (819 GB/s, 16 GiB) feeding
    per-core VMEM (128 MiB) feeding an MXU (matmul) + VPU (elementwise).
  * ``gpu_sm(n_sms)`` — the GPU target: one HBM3 module feeding thread-block
    clusters of SMs, each cluster staging through its distributed shared
    memory, with NVLink-class links between clusters when ``n_sms > 1``.
  * ``paper_accelerator(n_clusters)`` — the paper's case-study device
    (Section 5): clusters of paired processing units sharing register files,
    several HBM modules, everything explicitly managed.  Used by the GEMM and
    GRU benchmarks so results are comparable with the paper's Figures 3-4.

Memories carry a *role* (``host`` / ``global`` / ``staging``) so budget and
capacity logic — the scheduler's tile budget, the verifier's working-set
rules — reads the target's structure instead of hardcoding well-known TPU
names; ``resolve_target`` maps the CLI ``--target`` names onto factories.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

#: memory level -> default role.  ``host`` is system memory, ``global`` is
#: the device-wide store (HBM), ``staging`` is the explicitly managed
#: close-to-compute tier (TPU VMEM, GPU shared memory, register files) that
#: tile working sets are budgeted against.
_LEVEL_ROLES = {0: "host", 1: "global", 2: "staging"}


@dataclass(frozen=True)
class MemoryNode:
    name: str
    capacity: int                  # bytes
    level: int                     # 0 = host/system memory, larger = closer
    role: str = ""                 # host | global | staging (default: level)

    def __post_init__(self):
        if not self.role:
            object.__setattr__(
                self, "role", _LEVEL_ROLES.get(self.level, "staging"))


@dataclass(frozen=True)
class ComputeNode:
    name: str
    memory: str                    # the memory node operands must reside in
    instructions: frozenset[str]   # needle-name prefixes it can execute
    flops_per_sec: float
    matmul_tile: tuple[int, int, int] = (128, 128, 128)
    vector_lanes: int = 8 * 128    # VPU elements per cycle
    clock_hz: float = 0.94e9

    def executes(self, needle_name: str) -> bool:
        return any(needle_name.startswith(p) for p in self.instructions)


@dataclass(frozen=True)
class MoveEdge:
    src: str
    dst: str
    bandwidth: float               # bytes / sec
    latency: float                 # sec per transfer issue
    issuer: str = "host"           # device that emits the copy instruction


@dataclass
class SystemGraph:
    name: str
    memories: dict[str, MemoryNode] = field(default_factory=dict)
    computes: dict[str, ComputeNode] = field(default_factory=dict)
    edges: list[MoveEdge] = field(default_factory=list)
    family: str = "generic"        # tpu | gpu | paper | generic

    # -- construction -------------------------------------------------------
    def add_memory(self, name: str, capacity: int, level: int,
                   role: str = "") -> None:
        self.memories[name] = MemoryNode(name, capacity, level, role)

    def add_compute(self, name: str, memory: str, instructions, flops: float,
                    **kw) -> None:
        self.computes[name] = ComputeNode(name, memory, frozenset(instructions),
                                          flops, **kw)

    def add_edge(self, src: str, dst: str, bandwidth: float,
                 latency: float = 1e-6, issuer: str = "host",
                 bidirectional: bool = True,
                 rev_issuer: str | None = None) -> None:
        """Add a movement edge (and, by default, its reverse).

        ``issuer`` is the device that emits the forward copy; the reverse
        copy is emitted by ``rev_issuer`` when given (a pull-style DMA is
        issued by the *receiving* side, so the two directions generally
        have different issuers) and falls back to ``issuer`` otherwise.
        """
        self.edges.append(MoveEdge(src, dst, bandwidth, latency, issuer))
        if bidirectional:
            self.edges.append(MoveEdge(dst, src, bandwidth, latency,
                                       rev_issuer or issuer))

    # -- queries --------------------------------------------------------------
    def min_matmul_tile(self) -> tuple[int, int, int]:
        """The smallest hardware matmul tile across compute nodes (lexico
        min; all real graphs have uniform tiles).  The single definition
        behind the search space's tile choices and the learned cost model's
        tile features — they must agree on what "1x the hw tile" means."""
        tiles = {c.matmul_tile for c in self.computes.values()}
        return min(tiles) if tiles else (128, 128, 128)

    def edge(self, src: str, dst: str) -> MoveEdge:
        for e in self.edges:
            if e.src == src and e.dst == dst:
                return e
        raise KeyError(f"no edge {src} -> {dst}")

    def out_edges(self, src: str) -> list[MoveEdge]:
        return [e for e in self.edges if e.src == src]

    def shortest_path(self, src: str, dst: str,
                      nbytes: int = 1 << 20) -> list[MoveEdge]:
        """Min-cost path by modeled transfer time of ``nbytes`` (paper 3.5:
        'simply finding a shortest-path tends to work relatively well')."""
        if src == dst:
            return []
        dist = {src: 0.0}
        prev: dict[str, MoveEdge] = {}
        pq = [(0.0, src)]
        while pq:
            d, u = heapq.heappop(pq)
            if u == dst:
                break
            if d > dist.get(u, float("inf")):
                continue
            for e in self.out_edges(u):
                nd = d + e.latency + nbytes / e.bandwidth
                if nd < dist.get(e.dst, float("inf")):
                    dist[e.dst] = nd
                    prev[e.dst] = e
                    heapq.heappush(pq, (nd, e.dst))
        if dst not in prev:
            raise KeyError(f"no path {src} -> {dst}")
        path, cur = [], dst
        while cur != src:
            e = prev[cur]
            path.append(e)
            cur = e.src
        return list(reversed(path))

    def compute_nodes_for(self, needle_name: str) -> list[ComputeNode]:
        return [c for c in self.computes.values() if c.executes(needle_name)]

    def memory_of(self, compute: str) -> MemoryNode:
        return self.memories[self.computes[compute].memory]

    def staging_budget(self, devices=None) -> int | None:
        """Per-tile working-set budget: a third of the smallest staging
        memory feeding ``devices`` (default: all compute nodes).  The /3
        leaves headroom for resident weights and in-flight copies next to
        the active tile; the single definition behind the scheduler's
        tile shapes, the evaluators' feasibility guards and the tuner's
        cache records — whatever the staging tier is called (TPU VMEM,
        GPU shared memory, register files)."""
        devs = list(self.computes.values()) if devices is None \
            else list(devices)
        caps = [self.memories[d.memory].capacity for d in devs
                if d.memory in self.memories]
        return min(caps) // 3 if caps else None


# --------------------------------------------------------------------------- #
# Hardware constants (v5e) — shared with the roofline analysis
# --------------------------------------------------------------------------- #

V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
V5E_HBM_BW = 819e9             # bytes/s
V5E_HBM_BYTES = 16 << 30
V5E_VMEM_BYTES = 128 << 20
V5E_ICI_BW = 50e9              # bytes/s per link
V5E_CLOCK = 0.94e9


def add_v5e_chip(g: SystemGraph, c: int, host_mem_node: str = "host") -> None:
    """Add one v5e chip (HBM + VMEM + core, PCIe-attached to the host) to
    ``g``.  Fabric wiring between chips is layered on top by
    ``repro.fabric.topology`` — this helper deliberately knows nothing
    about inter-chip links."""
    hbm, vmem = f"hbm{c}", f"vmem{c}"
    g.add_memory(hbm, V5E_HBM_BYTES, level=1)
    g.add_memory(vmem, V5E_VMEM_BYTES, level=2)
    # PCIe: host pushes down, the chip's core DMAs back up.
    g.add_edge(host_mem_node, hbm, bandwidth=32e9, latency=2e-6,
               issuer="host", rev_issuer=f"core{c}")
    g.add_edge(hbm, vmem, bandwidth=V5E_HBM_BW, latency=1e-7,
               issuer=f"core{c}")
    g.add_compute(
        f"core{c}", vmem,
        {"mxu.", "vpu.", "fused."},
        flops=V5E_PEAK_FLOPS,
        matmul_tile=(128, 128, 128), vector_lanes=8 * 128,
        clock_hz=V5E_CLOCK)


def tpu_v5e(n_cores: int = 1, host_mem: int = 512 << 30) -> SystemGraph:
    """One v5e chip (or several connected by an ICI ring) as a system graph.

    Multi-chip wiring is delegated to ``repro.fabric.topology.ring`` — a
    proper bidirectional ring (with the wraparound link the old ad-hoc
    wiring was missing) whose per-direction copies are issued by the
    receiving chip's core."""
    g = SystemGraph(f"tpu_v5e_x{n_cores}", family="tpu")
    g.add_memory("host", host_mem, level=0)
    for c in range(n_cores):
        add_v5e_chip(g, c)
    if n_cores > 1:
        from ..fabric.topology import ring
        ring(n_cores).wire_ici(g)
    return g


# --------------------------------------------------------------------------- #
# Hardware constants (GPU, H100-class) — shared with bench_portability
# --------------------------------------------------------------------------- #

GPU_PEAK_FLOPS = 989e12        # bf16 dense FLOP/s, whole device
GPU_HBM_BW = 3.35e12           # HBM3 bytes/s, whole device
GPU_HBM_BYTES = 80 << 30
GPU_SMEM_BYTES = 228 << 10     # usable shared memory per SM
GPU_SMS_PER_CLUSTER = 16       # thread-block cluster size (distributed smem)
GPU_NVLINK_BW = 450e9          # bytes/s per direction, NVLink-class
GPU_PCIE_BW = 64e9             # host link, PCIe gen5 x16
GPU_CLOCK = 1.8e9


def gpu_sm(n_sms: int = 8, host_mem: int = 512 << 30) -> SystemGraph:
    """A modeled GPU as a system graph: ``n_sms`` thread-block clusters.

    The schedulable compute unit is a *cluster* of ``GPU_SMS_PER_CLUSTER``
    SMs cooperating through distributed shared memory (the warp/SM tier
    below it is implicit in the cluster's aggregate FLOP rate), so tile
    working sets are budgeted against the cluster-wide staging capacity
    rather than one SM's 228 KB — the same explicitly managed three-level
    shape (host -> global HBM -> staging) the scheduler already dry-runs,
    with GPU capacities and bandwidths:

      * one HBM3 module (``hbm0``, level 1, role ``global``) shared by all
        clusters; each cluster's load path gets an equal slice of the
        aggregate HBM bandwidth,
      * per-cluster shared memory (``smem{c}``, level 2, role ``staging``),
      * NVLink-class cluster-to-cluster ring links when ``n_sms > 1`` (the
        DSM/switch fabric, which the fabric layer can extend device-to-
        device).

    Clusters execute the same needle prefixes as every other target — the
    paper's portability claim is that mapping/selection are target-agnostic
    and only scheduling/lowering consult the machine.
    """
    g = SystemGraph(f"gpu_sm_x{n_sms}", family="gpu")
    g.add_memory("host", host_mem, level=0)
    g.add_memory("hbm0", GPU_HBM_BYTES, level=1)
    g.add_edge("host", "hbm0", bandwidth=GPU_PCIE_BW, latency=2e-6,
               issuer="host", rev_issuer="sm0")
    cluster_flops = GPU_PEAK_FLOPS / 8          # ~8 clusters per device
    cluster_smem = GPU_SMS_PER_CLUSTER * GPU_SMEM_BYTES
    for c in range(n_sms):
        smem = f"smem{c}"
        g.add_memory(smem, cluster_smem, level=2)
        # TMA loads: every cluster gets an equal share of HBM bandwidth.
        g.add_edge("hbm0", smem, bandwidth=GPU_HBM_BW / n_sms, latency=5e-7,
                   issuer=f"sm{c}")
        g.add_compute(
            f"sm{c}", smem,
            {"mxu.", "vpu.", "fused."},
            flops=cluster_flops,
            # cluster-wide WGMMA tile: 16 SMs x (64, 64) warpgroup output
            # panels arranged 4x4, reduction in k=32 steps
            matmul_tile=(256, 256, 32),
            vector_lanes=GPU_SMS_PER_CLUSTER * 128,
            clock_hz=GPU_CLOCK)
    if n_sms > 1:
        # DSM / NVLink-class ring between neighbouring clusters, each
        # direction issued by the receiving side (pull-style TMA).
        for c in range(n_sms):
            nxt = (c + 1) % n_sms
            if n_sms == 2 and c == 1:
                break               # a 2-ring has one physical link
            g.add_edge(f"smem{c}", f"smem{nxt}", bandwidth=GPU_NVLINK_BW,
                       latency=3e-7, issuer=f"sm{nxt}",
                       rev_issuer=f"sm{c}")
    return g


def paper_accelerator(n_clusters: int = 2, regfile_bytes: int = 8 << 20,
                      hbm_modules: int = 2) -> SystemGraph:
    """The paper's case-study architecture (Section 5): clusters of paired
    matrix/elementwise processing units sharing large register files, several
    HBM modules, no cache hierarchy — all memory explicitly managed."""
    g = SystemGraph(f"paper_accel_x{n_clusters}", family="paper")
    g.add_memory("host", 512 << 30, level=0)
    for m in range(hbm_modules):
        g.add_memory(f"hbm{m}", 8 << 30, level=1)
        g.add_edge("host", f"hbm{m}", bandwidth=32e9, latency=2e-6)
    for c in range(n_clusters):
        rf = f"rf{c}"
        g.add_memory(rf, regfile_bytes, level=2)
        for m in range(hbm_modules):
            g.add_edge(f"hbm{m}", rf, bandwidth=400e9, latency=2e-7,
                       issuer=f"pu{c}a")
        # the paired processing units sharing one register file set
        for suffix in ("a", "b"):
            g.add_compute(
                f"pu{c}{suffix}", rf,
                {"mxu.", "vpu.", "fused."},
                flops=25e12, matmul_tile=(64, 64, 64), vector_lanes=256,
                clock_hz=1.0e9)
    return g


# --------------------------------------------------------------------------- #
# Target registry — the CLI ``--target`` vocabulary
# --------------------------------------------------------------------------- #

#: canonical target name -> zero-arg factory for the default single-device
#: graph.  CLI surfaces (``repro compile|tune|dryrun``, benchmarks, CI
#: matrices) resolve through this table so adding a third target is one
#: entry here plus its factory above.
TARGETS: dict[str, object] = {
    "tpu_v5e": lambda: tpu_v5e(1),
    "gpu_sm": lambda: gpu_sm(8),
    "paper": lambda: paper_accelerator(2),
}

#: historical / short spellings accepted by resolve_target.
TARGET_ALIASES = {"v5e": "tpu_v5e", "tpu": "tpu_v5e", "gpu": "gpu_sm"}


def resolve_target(name: str) -> SystemGraph:
    """The default SystemGraph for a ``--target`` name (aliases accepted)."""
    canon = TARGET_ALIASES.get(name, name)
    try:
        return TARGETS[canon]()
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; known: "
            f"{sorted(set(TARGETS) | set(TARGET_ALIASES))}") from None
