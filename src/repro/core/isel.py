"""Instruction selection (paper Section 2.4).

The mapper typically produces several candidate mappings per needle (anything
matmul-mappable is also dot-mappable, fused instructions overlap their
unfused parts, ...).  Following the paper, the default heuristic picks the
non-overlapping set that minimises the number of final instruction *calls* —
largest statement windows first, ties broken by fewest invocations.

The full decision is routed through the Approach interface (approach.py) so
cost models / search can replace the heuristic.
"""
from __future__ import annotations

from dataclasses import dataclass

from .ir import Program
from .mapper import InstrMapping, map_program
from .transforms import search_mappings


@dataclass(frozen=True)
class SelectedInstr:
    """One chosen instruction instance covering ``mapping.stmt_map``."""

    needle: Program
    mapping: InstrMapping

    @property
    def first_stmt(self) -> int:
        return self.mapping.stmt_map[0]

    @property
    def last_stmt(self) -> int:
        return self.mapping.stmt_map[-1]


@dataclass
class Selection:
    """Complete cover of a program by instructions (+ any uncovered stmts)."""

    program: Program          # possibly transformed haystack
    steps: tuple             # transforms applied to reach `program`
    instrs: list[SelectedInstr]
    uncovered: tuple[int, ...]

    @property
    def complete(self) -> bool:
        return not self.uncovered

    def total_calls(self) -> int:
        return sum(si.mapping.calls(self.program) for si in self.instrs)


def candidate_instructions(prog: Program, isa: list[Program],
                           max_per_needle: int = 64) -> list[SelectedInstr]:
    """The mapping stage: every way an ISA needle identifies inside ``prog``,
    deduplicated to the fewest-calls mapping per statement window.  This is
    the ``Map`` pass of the compilation pipeline (``repro.compile``);
    ``select_from_candidates`` turns its output into a cover."""
    cands: list[SelectedInstr] = []
    for needle in isa:
        res = map_program(prog, needle, max_results=max_per_needle)
        best_per_window: dict[tuple[int, ...], InstrMapping] = {}
        for m in res.mappings:
            prev = best_per_window.get(m.stmt_map)
            if prev is None or m.calls(prog) < prev.calls(prog):
                best_per_window[m.stmt_map] = m
        cands.extend(SelectedInstr(needle, m) for m in best_per_window.values())
    return cands


def select_from_candidates(prog: Program, cands: list[SelectedInstr],
                           isa: list[Program],
                           allow_transforms: bool = True,
                           approach=None) -> Selection:
    """The selection stage: cover ``prog`` from pre-computed mapping
    candidates (the ``Select`` pass of the compilation pipeline).

    If a high-value needle (one covering multi-statement windows, e.g. the
    MXU matmul) has no direct mapping and ``allow_transforms`` is set, the
    feedback-guided search (transforms.py) is consulted and the resulting
    selections are compared by (completeness, total calls, #instructions) —
    the paper's minimum-instruction heuristic extended across transform paths.
    """
    chosen, covered = _greedy_cover(prog, cands, approach)
    uncovered = tuple(i for i in range(len(prog.statements)) if i not in covered)
    best = Selection(prog, (), chosen, uncovered)
    if not allow_transforms:
        return best

    def quality(sel: Selection):
        return (len(sel.uncovered), sel.total_calls(), len(sel.instrs))

    # Needles with multi-statement windows that found nothing directly are
    # candidates for unblocking via IR transformations.
    mapped_needles = {si.needle.name for si in chosen}
    for needle in isa:
        if len(needle.statements) < 2 or needle.name in mapped_needles:
            continue
        for r in search_mappings(prog, needle, max_depth=3):
            if not r.steps:
                continue
            sel2 = select_instructions(r.program, isa, allow_transforms=False,
                                       approach=approach)
            sel2 = Selection(sel2.program, tuple(r.steps), sel2.instrs,
                             sel2.uncovered)
            if quality(sel2) < quality(best):
                best = sel2
    return best


def select_instructions(prog: Program, isa: list[Program],
                        allow_transforms: bool = True,
                        approach=None) -> Selection:
    """Map + select in one call (the historical entry point): compute the
    mapping candidates, then cover the program with them."""
    return select_from_candidates(prog, candidate_instructions(prog, isa),
                                  isa, allow_transforms=allow_transforms,
                                  approach=approach)


def _greedy_cover(prog: Program, cands: list[SelectedInstr], approach=None):
    """Paper heuristic: minimum number of final instructions — widest window
    first, then fewest calls.  An Approach can override the ranking."""
    if approach is not None:
        def key(si: SelectedInstr):
            return approach.rank_instruction(si, prog)
    else:
        def key(si: SelectedInstr):
            return (-len(si.mapping.stmt_map), si.mapping.calls(prog))
    chosen: list[SelectedInstr] = []
    covered: set[int] = set()
    for si in sorted(cands, key=key):
        s = set(si.mapping.stmt_map)
        if s & covered:
            continue
        covered |= s
        chosen.append(si)
    chosen.sort(key=lambda si: si.first_stmt)
    return chosen, covered
