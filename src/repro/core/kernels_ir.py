"""Canned deep-learning kernels in ISAMIR (the paper's haystack programs).

These mirror the paper's evaluation set (Section 6.1): matrix multiplication,
1D convolution, 2D convolution, depthwise convolution, separable-depthwise
convolution (Listing 3), and the GRU cell — plus attention score/value einsums
used by the model zoo.
"""
from __future__ import annotations

from .ir import Program, ProgramBuilder


def matmul(m: int, n: int, k: int, accumulate: bool = True) -> Program:
    pb = ProgramBuilder(f"matmul_{m}x{n}x{k}")
    i, j, kk = pb.axes(i=m, j=n, k=k)
    A = pb.buffer("A", (m, k))
    B = pb.buffer("B", (k, n))
    C = pb.buffer("C", (m, n))
    t = pb.temp("tmp", (m, n, k))
    pb.stmt(t[i, j, kk], ":=", A[i, kk])
    pb.stmt(t[i, j, kk], "*=", B[kk, j])
    pb.stmt(C[i, j], "+=", t[i, j, kk])
    pb.output("C")
    return pb.build()


def conv1d(batch: int, width: int, kw: int, cin: int, cout: int) -> Program:
    """Listing 5: C[i,x,ko] += A[i,x+d,ki] * B[d,ki,ko]."""
    pb = ProgramBuilder("conv1d")
    i, x, d, ki, ko = pb.axes(i=batch, x=width, d=kw, ki=cin, ko=cout)
    A = pb.buffer("A", (batch, width + kw - 1, cin))
    B = pb.buffer("B", (kw, cin, cout))
    C = pb.buffer("C", (batch, width, cout))
    t = pb.temp("tmp", (batch, width, kw, cin, cout))
    pb.stmt(t[i, x, d, ki, ko], ":=", A[i, x + d, ki])
    pb.stmt(t[i, x, d, ki, ko], "*=", B[d, ki, ko])
    pb.stmt(C[i, x, ko], "+=", t[i, x, d, ki, ko])
    pb.output("C")
    return pb.build()


def conv2d(batch: int, h: int, w: int, kh: int, kw: int, cin: int, cout: int,
           stride: int = 1) -> Program:
    pb = ProgramBuilder("conv2d")
    b, y, x, dy, dx, ki, ko = pb.axes(b=batch, y=h, x=w, dy=kh, dx=kw,
                                      ci=cin, co=cout)
    H, W = stride * (h - 1) + kh, stride * (w - 1) + kw
    A = pb.buffer("A", (batch, H, W, cin))
    Wt = pb.buffer("W", (kh, kw, cin, cout))
    C = pb.buffer("C", (batch, h, w, cout))
    t = pb.temp("tmp", (batch, h, w, kh, kw, cin, cout))
    pb.stmt(t[b, y, x, dy, dx, ki, ko], ":=", A[b, stride * y + dy, stride * x + dx, ki])
    pb.stmt(t[b, y, x, dy, dx, ki, ko], "*=", Wt[dy, dx, ki, ko])
    pb.stmt(C[b, y, x, ko], "+=", t[b, y, x, dy, dx, ki, ko])
    pb.output("C")
    return pb.build()


def depthwise_conv2d(batch: int, h: int, w: int, kh: int, kw: int, c: int,
                     stride: int = 1) -> Program:
    """Depthwise convolution: channels are not mixed."""
    pb = ProgramBuilder("depthwise_conv2d")
    b, y, x, dy, dx, q = pb.axes(b=batch, y=h, x=w, dy=kh, dx=kw, q=c)
    H, W = stride * (h - 1) + kh, stride * (w - 1) + kw
    A = pb.buffer("A", (batch, H, W, c))
    D = pb.buffer("D", (kh, kw, c))
    C = pb.buffer("C", (batch, h, w, c))
    t = pb.temp("tmp", (batch, h, w, kh, kw, c))
    pb.stmt(t[b, y, x, dy, dx, q], ":=", A[b, stride * y + dy, stride * x + dx, q])
    pb.stmt(t[b, y, x, dy, dx, q], "*=", D[dy, dx, q])
    pb.stmt(C[b, y, x, q], "+=", t[b, y, x, dy, dx, q])
    pb.output("C")
    return pb.build()


def separable_depthwise_conv(batch: int, h: int, w: int, kh: int, kw: int,
                             cin: int, mult: int, cout: int,
                             stride: int = 1) -> Program:
    """Paper Listing 3: C[b,i,j,k] += A[b,s*i+di,s*j+dj,q] * D[di,dj,q,r]
    * P[c*q+r, k] — a depthwise stage fused with a pointwise projection.

    Direct mapping fails (two multiplications feed one reduction); the
    factor-out-of-reduction transformation (transforms.py) splits it into a
    depthwise reduction followed by a matmul-mappable pointwise reduction.
    """
    pb = ProgramBuilder("separable_depthwise_conv")
    b, i, j, k, di, dj, q, r = pb.axes(b=batch, i=h, j=w, k=cout, di=kh,
                                       dj=kw, q=cin, r=mult)
    H, W = stride * (h - 1) + kh, stride * (w - 1) + kw
    A = pb.buffer("A", (batch, H, W, cin))
    D = pb.buffer("D", (kh, kw, cin, mult))
    P = pb.buffer("P", (cin * mult, cout))
    C = pb.buffer("C", (batch, h, w, cout))
    t = pb.temp("tmp", (batch, h, w, cout, kh, kw, cin, mult))
    pb.stmt(t[b, i, j, k, di, dj, q, r], ":=",
            A[b, stride * i + di, stride * j + dj, q])
    pb.stmt(t[b, i, j, k, di, dj, q, r], "*=", D[di, dj, q, r])
    pb.stmt(t[b, i, j, k, di, dj, q, r], "*=", P[mult * q + r, k])
    pb.stmt(C[b, i, j, k], "+=", t[b, i, j, k, di, dj, q, r])
    pb.output("C")
    return pb.build()


def gru_cell(batch: int, hidden: int, inp: int) -> Program:
    """One GRU step in three-operand ISAMIR (paper Section 6.2.2).

        r = sigmoid(x Wr + h Ur + br)
        z = sigmoid(x Wz + h Uz + bz)
        n = tanh(x Wn + r * (h Un + bn_h) + bn_x)
        h' = (1 - z) * n + z * h

    The mapper extracts the six GEMMs onto ``mxu.matmul`` (or the fused
    matmul+bias+activation needles) and the gates onto VPU instructions.
    """
    pb = ProgramBuilder("gru_cell")
    b, o, e = pb.axes(b=batch, o=hidden, e=inp)
    h2 = pb.axis("h2", hidden)  # reduction axis over previous hidden
    X = pb.buffer("X", (batch, inp))
    H = pb.buffer("H", (batch, hidden))
    Wr = pb.buffer("Wr", (inp, hidden))
    Ur = pb.buffer("Ur", (hidden, hidden))
    Wz = pb.buffer("Wz", (inp, hidden))
    Uz = pb.buffer("Uz", (hidden, hidden))
    Wn = pb.buffer("Wn", (inp, hidden))
    Un = pb.buffer("Un", (hidden, hidden))
    br = pb.buffer("br", (hidden,))
    bz = pb.buffer("bz", (hidden,))
    bnx = pb.buffer("bnx", (hidden,))
    bnh = pb.buffer("bnh", (hidden,))
    R = pb.buffer("R", (batch, hidden), temp=True)
    Z = pb.buffer("Z", (batch, hidden), temp=True)
    Nb = pb.buffer("N", (batch, hidden), temp=True)
    Hn = pb.buffer("Hn", (batch, hidden), temp=True)  # h-side of n gate
    OneMZ = pb.buffer("OneMZ", (batch, hidden), temp=True)
    ZH = pb.buffer("ZH", (batch, hidden), temp=True)
    Hout = pb.buffer("Hout", (batch, hidden))
    t1 = pb.temp("t1", (batch, hidden, inp))
    t2 = pb.temp("t2", (batch, hidden, hidden))
    t3 = pb.temp("t3", (batch, hidden, inp))
    t4 = pb.temp("t4", (batch, hidden, hidden))
    t5 = pb.temp("t5", (batch, hidden, inp))
    t6 = pb.temp("t6", (batch, hidden, hidden))

    # r gate
    pb.stmt(t1[b, o, e], ":=", X[b, e])
    pb.stmt(t1[b, o, e], "*=", Wr[e, o])
    pb.stmt(R[b, o], "+=", t1[b, o, e])
    pb.stmt(t2[b, o, h2], ":=", H[b, h2])
    pb.stmt(t2[b, o, h2], "*=", Ur[h2, o])
    pb.stmt(R[b, o], "+=", t2[b, o, h2])
    pb.stmt(R[b, o], "+=", br[o])
    pb.apply(R[b, o], "sigmoid", R[b, o])
    # z gate
    pb.stmt(t3[b, o, e], ":=", X[b, e])
    pb.stmt(t3[b, o, e], "*=", Wz[e, o])
    pb.stmt(Z[b, o], "+=", t3[b, o, e])
    pb.stmt(t4[b, o, h2], ":=", H[b, h2])
    pb.stmt(t4[b, o, h2], "*=", Uz[h2, o])
    pb.stmt(Z[b, o], "+=", t4[b, o, h2])
    pb.stmt(Z[b, o], "+=", bz[o])
    pb.apply(Z[b, o], "sigmoid", Z[b, o])
    # n gate
    pb.stmt(t6[b, o, h2], ":=", H[b, h2])
    pb.stmt(t6[b, o, h2], "*=", Un[h2, o])
    pb.stmt(Hn[b, o], "+=", t6[b, o, h2])
    pb.stmt(Hn[b, o], "+=", bnh[o])
    pb.stmt(Hn[b, o], "*=", R[b, o])
    pb.stmt(t5[b, o, e], ":=", X[b, e])
    pb.stmt(t5[b, o, e], "*=", Wn[e, o])
    pb.stmt(Nb[b, o], "+=", t5[b, o, e])
    pb.stmt(Nb[b, o], "+=", Hn[b, o])
    pb.stmt(Nb[b, o], "+=", bnx[o])
    pb.apply(Nb[b, o], "tanh", Nb[b, o])
    # h' = (1 - z) * n + z * h
    pb.apply(OneMZ[b, o], "sub_from_one", Z[b, o])
    pb.stmt(OneMZ[b, o], "*=", Nb[b, o])
    pb.stmt(ZH[b, o], ":=", Z[b, o])
    pb.stmt(ZH[b, o], "*=", H[b, o])
    pb.stmt(Hout[b, o], ":=", OneMZ[b, o])
    pb.stmt(Hout[b, o], "+=", ZH[b, o])
    pb.output("Hout")
    return pb.build()


def attention_scores(batch: int, heads: int, q_len: int, k_len: int,
                     head_dim: int) -> Program:
    """S[b,h,i,j] += Q[b,h,i,d] * K[b,h,j,d] — the QK^T einsum."""
    pb = ProgramBuilder("attention_scores")
    b, h, i, j, d = pb.axes(b=batch, h=heads, i=q_len, j=k_len, d=head_dim)
    Q = pb.buffer("Q", (batch, heads, q_len, head_dim))
    K = pb.buffer("K", (batch, heads, k_len, head_dim))
    S = pb.buffer("S", (batch, heads, q_len, k_len))
    t = pb.temp("tmp", (batch, heads, q_len, k_len, head_dim))
    pb.stmt(t[b, h, i, j, d], ":=", Q[b, h, i, d])
    pb.stmt(t[b, h, i, j, d], "*=", K[b, h, j, d])
    pb.stmt(S[b, h, i, j], "+=", t[b, h, i, j, d])
    pb.output("S")
    return pb.build()


def mlp_gate(batch: int, d_model: int, d_ff: int) -> Program:
    """SwiGLU up-projection pair: G = sigmoid(X Wg) * (X Wu) — exercises
    instruction selection across matmul + elementwise needles."""
    pb = ProgramBuilder("mlp_gate")
    b, f, e = pb.axes(b=batch, f=d_ff, e=d_model)
    X = pb.buffer("X", (batch, d_model))
    Wg = pb.buffer("Wg", (d_model, d_ff))
    Wu = pb.buffer("Wu", (d_model, d_ff))
    G = pb.buffer("G", (batch, d_ff), temp=True)
    U = pb.buffer("U", (batch, d_ff), temp=True)
    Y = pb.buffer("Y", (batch, d_ff))
    t1 = pb.temp("t1", (batch, d_ff, d_model))
    t2 = pb.temp("t2", (batch, d_ff, d_model))
    pb.stmt(t1[b, f, e], ":=", X[b, e])
    pb.stmt(t1[b, f, e], "*=", Wg[e, f])
    pb.stmt(G[b, f], "+=", t1[b, f, e])
    pb.apply(G[b, f], "sigmoid", G[b, f])
    pb.stmt(t2[b, f, e], ":=", X[b, e])
    pb.stmt(t2[b, f, e], "*=", Wu[e, f])
    pb.stmt(U[b, f], "+=", t2[b, f, e])
    pb.stmt(Y[b, f], ":=", G[b, f])
    pb.stmt(Y[b, f], "*=", U[b, f])
    pb.output("Y")
    return pb.build()
