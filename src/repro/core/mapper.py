"""Deterministic instruction mapping (paper Section 2.2).

Given a *haystack* program and a *needle* program (a hardware instruction
expressed in ISAMIR), find every way the needle can be identified inside the
haystack.  A mapping consists of:

  * a **statement map** — which haystack statements realise each needle
    statement (an increasing, extractable subsequence with matching op kinds),
  * a **buffer map** — injective needle buffer → haystack buffer,
  * a **dimension map** — per mapped buffer, injective needle dim → haystack dim,
  * an **axis map** — injective needle loop axis → haystack loop axis.

Matching is permuted-submatrix equality of the affine access matrices: for
every mapped access pair, every mapped (dim, axis) entry must agree.  Haystack
axes left unmapped become *outer* axes — the instruction is invoked once per
point of their domain (with operand views shifted accordingly); haystack dims
left unmapped must not vary with any mapped axis.

The search is a pruned recursive backtracking in the spirit of VF2
(Cordella et al., 2004): whole branches are abandoned at the first
inconsistent binding.  On failure the mapper reports structured *feedback*
(paper Section 2.3) that the non-deterministic transformation search uses to
choose which IR transformation to try next.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

from .ir import Access, Program

# --------------------------------------------------------------------------- #
# Results
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class InstrMapping:
    """One way of realising ``needle`` inside ``haystack``."""

    needle_name: str
    stmt_map: tuple[int, ...]                 # needle stmt i -> haystack stmt idx
    buffer_map: tuple[tuple[str, str], ...]   # (needle buf, haystack buf)
    dim_map: tuple[tuple[str, tuple[int, ...]], ...]  # needle buf -> hay dim per needle dim
    axis_map: tuple[tuple[str, str], ...]     # (needle axis, haystack axis)
    outer_axes: tuple[str, ...]               # haystack axes not mapped

    def buffer_of(self, needle_buf: str) -> str:
        return dict(self.buffer_map)[needle_buf]

    def hay_axis(self, needle_axis: str) -> str:
        return dict(self.axis_map)[needle_axis]

    def mapped_axes(self) -> tuple[str, ...]:
        return tuple(h for _, h in self.axis_map)

    def calls(self, haystack: Program) -> int:
        """Number of instruction invocations = |outer axis domain|."""
        n = 1
        for a in self.outer_axes:
            n *= haystack.axis(a).size
        return n


@dataclass(frozen=True)
class MapFailure:
    """Structured feedback for the transformation search (Section 2.3)."""

    kind: str          # op_mismatch | coeff_mismatch | buffer_conflict |
                       # dim_exhausted | temp_escapes | extent_mismatch |
                       # not_extractable | axis_unbound
    needle_stmt: int = -1
    haystack_stmt: int = -1
    detail: str = ""

    def __str__(self) -> str:  # pragma: no cover
        return (f"{self.kind}(needle stmt {self.needle_stmt}, "
                f"haystack stmt {self.haystack_stmt}): {self.detail}")


@dataclass
class MapResult:
    mappings: list[InstrMapping]
    failures: list[MapFailure]

    @property
    def ok(self) -> bool:
        return bool(self.mappings)

    def best(self, haystack: Program) -> InstrMapping:
        """Mapping covering the largest mapped iteration volume (fewest calls)."""
        return min(self.mappings, key=lambda m: m.calls(haystack))


# --------------------------------------------------------------------------- #
# Internal search state
# --------------------------------------------------------------------------- #


class _State:
    __slots__ = ("bmap", "brev", "dmap", "amap", "arev")

    def __init__(self):
        self.bmap: dict[str, str] = {}
        self.brev: dict[str, str] = {}
        self.dmap: dict[str, dict[int, int]] = {}
        self.amap: dict[str, str] = {}
        self.arev: dict[str, str] = {}

    def clone(self) -> "_State":
        s = _State.__new__(_State)
        s.bmap = dict(self.bmap)
        s.brev = dict(self.brev)
        s.dmap = {k: dict(v) for k, v in self.dmap.items()}
        s.amap = dict(self.amap)
        s.arev = dict(self.arev)
        return s


# --------------------------------------------------------------------------- #
# Mapper
# --------------------------------------------------------------------------- #


class Mapper:
    def __init__(self, haystack: Program, needle: Program,
                 max_results: int = 32, max_windows: int = 512):
        self.h = haystack
        self.n = needle
        self.max_results = max_results
        self.max_windows = max_windows
        self.failures: list[MapFailure] = []
        self.results: list[InstrMapping] = []

    # ---- public ----------------------------------------------------------
    def run(self) -> MapResult:
        any_window = False
        for window in self._windows():
            any_window = True
            if not self._extractable(window):
                self.failures.append(MapFailure(
                    "not_extractable", haystack_stmt=window[0],
                    detail=f"window {window} cannot be reordered to be atomic"))
                continue
            self._match_window(window)
            if len(self.results) >= self.max_results:
                break
        if not any_window:
            self._report_best_prefix()
        return MapResult(self.results, self.failures)

    # ---- statement windows -------------------------------------------------
    def _windows(self):
        """Yield increasing haystack-index tuples whose op kinds match the
        needle's statement kinds, bounded by ``max_windows``."""
        nk = [s.kind for s in self.n.statements]
        hk = [s.kind for s in self.h.statements]
        count = 0

        def rec(ni: int, start: int, acc: tuple[int, ...]):
            nonlocal count
            if count >= self.max_windows:
                return
            if ni == len(nk):
                count += 1
                yield acc
                return
            for hi in range(start, len(hk) - (len(nk) - ni) + 1):
                if hk[hi] == nk[ni]:
                    yield from rec(ni + 1, hi + 1, acc + (hi,))

        yield from rec(0, 0, ())

    def _report_best_prefix(self):
        """No op-kind window exists: report where the best prefix diverges —
        this is the feedback that drives transformation selection."""
        nk = [s.kind for s in self.n.statements]
        hk = [s.kind for s in self.h.statements]
        best_len = -1
        best_at = (0, 0)
        for start in range(len(hk)):
            ni, hi = 0, start
            while ni < len(nk) and hi < len(hk):
                if hk[hi] == nk[ni]:
                    ni += 1
                hi += 1
            if ni > best_len:
                best_len = ni
                # position where we ran out
                best_at = (ni, min(start + ni, len(hk) - 1))
        ni, hi = best_at
        found = hk[hi] if hi < len(hk) else "<end>"
        expected = nk[ni] if ni < len(nk) else "<end>"
        self.failures.append(MapFailure(
            "op_mismatch", needle_stmt=min(ni, len(nk) - 1), haystack_stmt=hi,
            detail=f"expected {expected!r} found {found!r}"))

    def _extractable(self, window: tuple[int, ...]) -> bool:
        """Legality of hoisting all window statements to the last position
        (so the window can be replaced by one atomic instruction call)."""
        wset = set(window)
        lo, hi = window[0], window[-1]
        for u in range(lo + 1, hi):
            if u in wset:
                continue
            us = self.h.statements[u]
            u_reads = set(self.h.reads(us))
            u_writes = self.h.writes(us)
            for m in window:
                if m >= u:
                    break
                ms = self.h.statements[m]
                m_writes = self.h.writes(ms)
                m_reads = set(self.h.reads(ms))
                if m_writes in u_reads:   # u needs m's (now delayed) write
                    return False
                if u_writes in m_reads:   # m would read u's later value
                    return False
                if u_writes == m_writes:  # WAW inversion
                    return False
        return True

    # ---- access unification ------------------------------------------------
    def _match_window(self, window: tuple[int, ...]):
        pairs: list[tuple[Access, Access, int, int]] = []
        for ni, hi in enumerate(window):
            ns, hs = self.n.statements[ni], self.h.statements[hi]
            pairs.append((ns.lhs, hs.lhs, ni, hi))
            pairs.append((ns.rhs, hs.rhs, ni, hi))
        self._unify(pairs, 0, _State(), window)

    def _unify(self, pairs, idx: int, st: _State, window: tuple[int, ...]):
        if len(self.results) >= self.max_results:
            return
        if idx == len(pairs):
            self._finalize(st, window)
            return
        na, ha, ni, hi = pairs[idx]

        # --- buffer binding
        if na.buffer in st.bmap:
            if st.bmap[na.buffer] != ha.buffer:
                self.failures.append(MapFailure(
                    "buffer_conflict", ni, hi,
                    f"{na.buffer} already bound to {st.bmap[na.buffer]}, "
                    f"now needs {ha.buffer}"))
                return
        elif ha.buffer in st.brev:
            self.failures.append(MapFailure(
                "buffer_conflict", ni, hi,
                f"haystack buffer {ha.buffer} already bound"))
            return

        nb, hb = self.n.buffer(na.buffer), self.h.buffer(ha.buffer)
        if nb.rank > hb.rank:
            self.failures.append(MapFailure(
                "dim_exhausted", ni, hi,
                f"needle buffer {nb.name} rank {nb.rank} > haystack "
                f"{hb.name} rank {hb.rank}"))
            return

        base = st.clone()
        base.bmap[na.buffer] = ha.buffer
        base.brev[ha.buffer] = na.buffer
        base.dmap.setdefault(na.buffer, {})

        # --- dim assignments (branch over unbound needle dims)
        for st2 in self._assign_dims(base, na, ha, ni, hi):
            # --- axis assignments implied by entries of this access pair
            for st3 in self._assign_axes(st2, na, ha, ni, hi):
                self._unify(pairs, idx + 1, st3, window)

    def _assign_dims(self, st: _State, na: Access, ha: Access, ni: int, hi: int):
        dmap = st.dmap[na.buffer]
        unbound_n = [d for d in range(na.rank) if d not in dmap]
        if not unbound_n:
            yield st
            return
        bound_h = set(dmap.values())
        unbound_h = [d for d in range(ha.rank) if d not in bound_h]
        if len(unbound_n) > len(unbound_h):
            self.failures.append(MapFailure(
                "dim_exhausted", ni, hi,
                f"{len(unbound_n)} needle dims for {len(unbound_h)} haystack dims"))
            return
        for perm in itertools.permutations(unbound_h, len(unbound_n)):
            st2 = st.clone()
            for d, D in zip(unbound_n, perm):
                st2.dmap[na.buffer][d] = D
            yield st2

    def _assign_axes(self, st: _State, na: Access, ha: Access, ni: int, hi: int):
        """Bind axes so that all (dim, axis) entries of this access pair agree.
        Branch over candidates for unbound needle axes with nonzero coeffs."""
        nmat, hmat = na.matrix, ha.matrix
        n_axes = self.n.axis_names
        h_axes = self.h.axis_names
        dmap = st.dmap[na.buffer]

        # Collect (needle axis idx, required coeff, haystack row) constraints.
        todo: list[tuple[int, int, tuple[int, ...]]] = []
        for d in range(na.rank):
            D = dmap[d]
            nrow, hrow = nmat[d], hmat[D]
            for a, coeff in enumerate(nrow):
                an = n_axes[a]
                if an in st.amap:
                    A = self.h.axis_index(st.amap[an])
                    if hrow[A] != coeff:
                        self.failures.append(MapFailure(
                            "coeff_mismatch", ni, hi,
                            f"axis {an}->{st.amap[an]}: needle coeff {coeff} "
                            f"vs haystack {hrow[A]} in {ha.buffer}[{D}]"))
                        return
                elif coeff != 0:
                    todo.append((a, coeff, hrow))
            # Bound haystack axes must not appear where the needle row is zero.
            for A, hcoeff in enumerate(hrow):
                hn = h_axes[A]
                if hn in st.arev and hcoeff != 0:
                    an2 = st.arev[hn]
                    a2 = self.n.axis_names.index(an2)
                    if nrow[a2] != hcoeff:
                        self.failures.append(MapFailure(
                            "coeff_mismatch", ni, hi,
                            f"haystack axis {hn} (bound to {an2}) has coeff "
                            f"{hcoeff} where needle has {nrow[a2]}"))
                        return

        def rec(t: int, cur: _State):
            if t == len(todo):
                yield cur
                return
            a, coeff, hrow = todo[t]
            an = n_axes[a]
            if an in cur.amap:       # bound by an earlier constraint in `todo`
                A = self.h.axis_index(cur.amap[an])
                if hrow[A] == coeff:
                    yield from rec(t + 1, cur)
                else:
                    self.failures.append(MapFailure(
                        "coeff_mismatch", ni, hi,
                        f"axis {an} bound inconsistently"))
                return
            cands = [A for A, c in enumerate(hrow)
                     if c == coeff and h_axes[A] not in cur.arev]
            if not cands:
                self.failures.append(MapFailure(
                    "coeff_mismatch", ni, hi,
                    f"no haystack axis with coeff {coeff} for needle axis {an} "
                    f"in {ha.buffer}"))
                return
            for A in cands:
                nx = cur.clone()
                nx.amap[an] = h_axes[A]
                nx.arev[h_axes[A]] = an
                yield from rec(t + 1, nx)

        yield from rec(0, st)

    # ---- final validation ---------------------------------------------------
    def _finalize(self, st: _State, window: tuple[int, ...]):
        # 1. all needle axes bound
        for a in self.n.axes:
            if a.name not in st.amap:
                self.failures.append(MapFailure(
                    "axis_unbound", detail=f"needle axis {a.name} never bound"))
                return

        # 2. extent compatibility (fixed-size needles)
        for a in self.n.axes:
            if a.size:
                hsz = self.h.axis(st.amap[a.name]).size
                if hsz != a.size:
                    self.failures.append(MapFailure(
                        "extent_mismatch",
                        detail=f"needle axis {a.name} needs extent {a.size}, "
                               f"haystack {st.amap[a.name]} has {hsz}"))
                    return

        mapped_h_axes = set(st.arev)

        # 3. global coefficient re-check + unmapped-dim independence
        for ni, hi in enumerate(window):
            for na, ha in ((self.n.statements[ni].lhs, self.h.statements[hi].lhs),
                           (self.n.statements[ni].rhs, self.h.statements[hi].rhs)):
                dmap = st.dmap[na.buffer]
                rev_dims = set(dmap.values())
                for d in range(na.rank):
                    D = dmap[d]
                    for a, an in enumerate(self.n.axis_names):
                        A = self.h.axis_index(st.amap[an])
                        if na.matrix[d][a] != ha.matrix[D][A]:
                            self.failures.append(MapFailure(
                                "coeff_mismatch", ni, hi, "final recheck failed"))
                            return
                for D in range(ha.rank):
                    if D in rev_dims:
                        continue
                    for A, c in enumerate(ha.matrix[D]):
                        if c != 0 and self.h.axis_names[A] in mapped_h_axes:
                            self.failures.append(MapFailure(
                                "coeff_mismatch", ni, hi,
                                f"unmapped dim {ha.buffer}[{D}] varies with "
                                f"mapped axis {self.h.axis_names[A]}"))
                            return

        # 4. temp escape: needle temps must map to haystack buffers fully
        #    consumed inside the window (they will not be materialised).
        wset = set(window)
        for nb in self.n.buffers:
            if not nb.temp or nb.name not in st.bmap:
                continue
            hb = st.bmap[nb.name]
            if hb in self.h.outputs:
                self.failures.append(MapFailure(
                    "temp_escapes", detail=f"{hb} is a program output but maps "
                                           f"to needle temp {nb.name}"))
                return
            for si, s in enumerate(self.h.statements):
                if si in wset:
                    continue
                if hb in self.h.reads(s) or self.h.writes(s) == hb:
                    self.failures.append(MapFailure(
                        "temp_escapes", haystack_stmt=si,
                        detail=f"{hb} used outside window at stmt {si}"))
                    return

        # Outer axes: axes in the *window statements'* domains left unmapped —
        # the instruction is invoked once per point of their joint domain.
        window_axes: set[str] = set()
        for hi in window:
            s = self.h.statements[hi]
            for acc in (s.lhs, s.rhs):
                window_axes |= acc.axes_used(self.h.axis_names)
        outer = tuple(a.name for a in self.h.axes
                      if a.name in window_axes and a.name not in mapped_h_axes)
        self.results.append(InstrMapping(
            needle_name=self.n.name,
            stmt_map=window,
            buffer_map=tuple(sorted(st.bmap.items())),
            dim_map=tuple(sorted(
                (b, tuple(m[d] for d in range(len(m)))) for b, m in st.dmap.items())),
            axis_map=tuple(sorted(st.amap.items())),
            outer_axes=outer,
        ))


def map_program(haystack: Program, needle: Program,
                max_results: int = 32) -> MapResult:
    """Entry point: find all mappings of ``needle`` inside ``haystack``."""
    return Mapper(haystack, needle, max_results=max_results).run()
