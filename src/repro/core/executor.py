"""Schedule executor — "replay" execution of the recorded instruction stream.

The static scheduler emits COPY / COMPUTE / WRITEBACK ops; this module
replays them with real data, byte-for-byte honouring the memory system the
schedule claims (region copies live per memory node; computes only touch
operands resident in their compute node's memory).  Any scheduling bug —
wrong invalidation, missing copy, bad region math — surfaces as a numeric
mismatch against the pure ISAMIR oracle (ir.interpret).

Needle semantics are executed by *interpreting the needle program itself* on
the tile's operand views, so the executor contains no per-instruction code.
"""
from __future__ import annotations

import numpy as np

from .ir import Axis, Buffer, Program, interpret
from .isel import SelectedInstr, Selection
from .scheduler import Region, Schedule, ScheduledOp


class ExecutionError(RuntimeError):
    pass


class Machine:
    """Materialized memory state: every memory node holds exact region copies
    (the home node holds whole buffers)."""

    def __init__(self, schedule: Schedule, inputs: dict[str, np.ndarray]):
        self.sched = schedule
        self.prog = schedule.program
        # home storage: full arrays
        self.home_data: dict[str, np.ndarray] = {}
        for b in self.prog.buffers:
            if b.name not in schedule.homes:
                continue
            if b.name in inputs:
                arr = np.asarray(inputs[b.name], dtype=np.float64)
                if arr.shape != b.shape:
                    raise ExecutionError(
                        f"input {b.name}: shape {arr.shape} != {b.shape}")
                self.home_data[b.name] = arr.copy()
            else:
                self.home_data[b.name] = np.zeros(b.shape, dtype=np.float64)
        # region copies: (memory node, buffer, bounds) -> array
        self.region_data: dict[tuple, np.ndarray] = {}

    # -- data access -----------------------------------------------------------
    def _slices(self, region: Region) -> tuple[slice, ...]:
        return tuple(slice(s, s + n) for s, n in region.bounds)

    def read(self, node: str, region: Region) -> np.ndarray:
        key = (node, region.buffer, region.bounds)
        if key in self.region_data:
            return self.region_data[key]
        if node == self.sched.homes.get(region.buffer):
            return self.home_data[region.buffer][self._slices(region)]
        raise ExecutionError(f"{region} not resident in {node}")

    def write(self, node: str, region: Region, value: np.ndarray):
        if node == self.sched.homes.get(region.buffer):
            self.home_data[region.buffer][self._slices(region)] = value
        else:
            self.region_data[(node, region.buffer, region.bounds)] = \
                np.array(value, dtype=np.float64)

    # -- op execution -----------------------------------------------------------
    def run_op(self, op: ScheduledOp, selection: Selection):
        if op.kind in ("copy", "writeback"):
            self.write(op.dst, op.region, self.read(op.src, op.region))
        elif op.kind == "compute":
            self._run_compute(op, selection)
        else:  # pragma: no cover
            raise ExecutionError(f"unknown op kind {op.kind}")

    def _run_compute(self, op: ScheduledOp, selection: Selection):
        tile = op.tile
        si = selection.instrs[tile.instr_idx]
        mem = self.sched.graph.computes[op.device].memory
        needle = _sized_needle(si, tile)

        ins: dict[str, np.ndarray] = {}
        out_specs: list[tuple[str, Region, np.ndarray]] = []
        for nb_name, region, r, w in tile.operands:
            if r:
                arr = np.asarray(self.read(mem, region), dtype=np.float64)
            else:  # write-only operand: fresh storage, never read
                arr = np.zeros(region.shape, dtype=np.float64)
            view = _operand_view(arr, si, nb_name, needle)
            ins[nb_name] = view
            if w:
                out_specs.append((nb_name, region, arr))

        # The machine state is f64 end-to-end; rounding tile outputs to the
        # buffer dtype here would make multi-tile accumulation chains (and
        # chip-chained fabric reductions) diverge from the oracle's
        # single-final-cast contract.
        outs = interpret(needle, ins, cast_outputs=False)
        for nb_name, region, arr in out_specs:
            res = outs[nb_name]
            inv = _operand_view_inverse(arr.shape, si, nb_name, res)
            self.write(mem, region, inv)


def _sized_needle(si: SelectedInstr, tile) -> Program:
    """Clone the needle with concrete axis extents (= tile sizes) and buffer
    shapes derived from its accesses.  Elementwise needles whose outer axes
    were coalesced get a single flattened axis of the full tile volume."""
    from .instructions import is_elementwise
    axis_map = dict(si.mapping.axis_map)
    if is_elementwise(si.needle.name):
        vol = 1
        for v in tile.sizes.values():
            vol *= v
        sizes = {na: vol for na in axis_map}
    else:
        sizes = {na: tile.sizes.get(ha, 1) for na, ha in axis_map.items()}
    axes = tuple(Axis(a.name, sizes.get(a.name, a.size or 1))
                 for a in si.needle.axes)
    ext = {a.name: a.size for a in axes}

    def buf_shape(b: Buffer) -> tuple[int, ...]:
        # extent of each dim from any access of this buffer
        shape = list(b.shape)
        for s in si.needle.statements:
            for acc in (s.lhs, s.rhs):
                if acc.buffer != b.name:
                    continue
                for d, (row, off) in enumerate(zip(acc.matrix, acc.offset)):
                    span = 1 + off
                    for ai, coeff in enumerate(row):
                        if coeff:
                            span += abs(coeff) * (ext[si.needle.axes[ai].name] - 1)
                    shape[d] = max(shape[d] or 0, span)
        return tuple(max(1, s) for s in shape)

    buffers = tuple(Buffer(b.name, buf_shape(b), b.dtype, b.temp)
                    for b in si.needle.buffers)
    return Program(si.needle.name, axes, buffers, si.needle.statements,
                   si.needle.outputs)


def _operand_view(arr: np.ndarray, si: SelectedInstr, nb_name: str,
                  needle: Program) -> np.ndarray:
    """Reorder a haystack region array into the needle operand's dim order:
    needle dim d corresponds to haystack dim D = dim_map[d]; remaining
    haystack dims must be singleton (outer-axis offsets) and are dropped.
    Coalesced elementwise tiles flatten the whole region."""
    from .instructions import is_elementwise
    if is_elementwise(si.needle.name):
        return np.ascontiguousarray(arr).reshape(-1)
    dm = dict(si.mapping.dim_map)[nb_name]
    nb = needle.buffer(nb_name)
    # choose, for each needle dim, the haystack dim index
    take = list(dm)
    rest = [d for d in range(arr.ndim) if d not in take]
    for d in rest:
        if arr.shape[d] != 1:
            raise ExecutionError(
                f"unmapped haystack dim {d} of {nb_name} region has extent "
                f"{arr.shape[d]} (expected 1)")
    perm = take + rest
    view = np.transpose(arr, perm)
    view = view.reshape(view.shape[:len(take)])
    # pad/crop to needle shape (boundary tiles are smaller than the block)
    target = nb.shape
    if view.shape != tuple(target):
        pad = [(0, t - s) for s, t in zip(view.shape, target)]
        if any(p[1] < 0 for p in pad):
            raise ExecutionError(
                f"operand {nb_name} region {view.shape} exceeds needle shape "
                f"{target}")
        view = np.pad(view, pad)
    return view


def _operand_view_inverse(region_shape: tuple[int, ...], si: SelectedInstr,
                          nb_name: str, result: np.ndarray) -> np.ndarray:
    """Inverse of _operand_view for written operands."""
    from .instructions import is_elementwise
    if is_elementwise(si.needle.name):
        return result.reshape(region_shape)
    dm = dict(si.mapping.dim_map)[nb_name]
    take = list(dm)
    rest = [d for d in range(len(region_shape)) if d not in take]
    # crop padding back off
    crop = tuple(slice(0, region_shape[d]) for d in take)
    res = result[crop]
    res = res.reshape(res.shape + (1,) * len(rest))
    # res dims currently: needle-dim order then singleton rest; invert perm
    perm = take + rest
    inv = np.argsort(perm)
    return np.transpose(res, inv)


def execute(schedule: Schedule, selection: Selection,
            inputs: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Run the schedule; return the program outputs read from their homes."""
    m = Machine(schedule, inputs)
    for op in schedule.ops:
        m.run_op(op, selection)
    out = {}
    for name in schedule.program.outputs:
        out[name] = m.home_data[name].astype(np.float32)
    return out
