"""Recurrent-model scheduling (paper Section 3.6).

A recurrent loop body (e.g. a GRU cell) is scheduled **three times**:

  * **priming**   — executes one instance from a cold state and leaves data
    buffers as close to the compute devices as possible (no output
    write-back);
  * **recursive** — scheduled from the priming iteration's residency with the
    loop carry rebound (outputs overwrite the corresponding inputs), so
    persistent data — weights above all — stays resident and the stream
    contains no redundant copies;
  * **finish**    — one final instance that places the outputs where the next
    instruction in the program needs them (their home memories).

At execution time a driver runs priming once, the recursive stream as many
times as needed, then the finish stream — exactly the paper's protocol.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .approach import Approach
from .executor import Machine
from .isel import Selection
from .scheduler import Schedule, Scheduler, SchedulerState
from .sysgraph import SystemGraph


@dataclass
class RecurrentSchedule:
    prime: Schedule
    recursive: Schedule
    finish: Schedule
    carry: dict[str, str]            # output buffer -> input buffer overwritten
    streamed: tuple[str, ...]        # per-step inputs (invalidate every step)

    def total_time(self, steps: int) -> float:
        if steps <= 1:
            return self.prime.makespan + self.finish.makespan
        return (self.prime.makespan
                + (steps - 2) * self.recursive.makespan
                + self.finish.makespan)

    def copy_counts(self) -> dict[str, int]:
        return {name: sum(1 for op in s.ops if op.kind in ("copy", "writeback"))
                for name, s in (("prime", self.prime),
                                ("recursive", self.recursive),
                                ("finish", self.finish))}


def _rebind_state(state: SchedulerState, selection: Selection,
                  carry: dict[str, str], streamed: tuple[str, ...],
                  homes: dict[str, str]):
    """Advance the scheduling state across the loop boundary: zero the
    accumulated temporaries, invalidate the per-step streamed inputs, and
    rename carry outputs onto the inputs they overwrite."""
    prog = selection.program

    def drop_all(buf: str):
        for k in [k for k in list(state.copies) if k[0] == buf]:
            for node in list(state.copies[k]):
                state.drop(node, k)
            state.copies.pop(k, None)
            state.version.pop(k, None)

    for b in prog.buffers:
        if b.name in homes and prog.buffer(b.name).temp:
            drop_all(b.name)         # temps restart from zero
    for name in streamed:
        drop_all(name)               # fresh content arrives at home
    for out_buf, in_buf in carry.items():
        drop_all(in_buf)
        for k in [k for k in list(state.copies) if k[0] == out_buf]:
            nk = (in_buf, k[1])
            state.copies[nk] = state.copies.pop(k)
            if k in state.version:
                state.version[nk] = state.version.pop(k)
            for (node, kk) in list(state.lru):
                if kk == k:
                    state.lru[(node, nk)] = state.lru.pop((node, kk))


def schedule_recurrent(selection: Selection, graph: SystemGraph,
                       carry: dict[str, str],
                       streamed: tuple[str, ...] = (),
                       approach: Approach | None = None) -> RecurrentSchedule:
    # priming iteration: cold start, keep data hot (no writeback)
    s_prime = Scheduler(selection, graph, approach)
    homes = s_prime.homes
    prime = s_prime.run_body(writeback=False)
    state = s_prime.state

    # recursive iteration: carry rebound, steady-state stream
    _rebind_state(state, selection, carry, streamed, homes)
    s_rec = Scheduler(selection, graph, approach, state=state)
    recursive = s_rec.run_body(writeback=False)

    # finish iteration: carry rebound again, outputs placed at home
    _rebind_state(s_rec.state, selection, carry, streamed, homes)
    s_fin = Scheduler(selection, graph, approach, state=s_rec.state)
    finish = s_fin.run_body(writeback=True)

    return RecurrentSchedule(prime, recursive, finish, dict(carry),
                             tuple(streamed))


# --------------------------------------------------------------------------- #
# Execution driver
# --------------------------------------------------------------------------- #


def execute_recurrent(rs: RecurrentSchedule, selection: Selection,
                      step_inputs: list[dict[str, np.ndarray]],
                      initial: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Run priming + (T-2) x recursive + finish with real data.

    ``step_inputs[t]`` holds the streamed buffers for step t; ``initial``
    holds weights and the initial carried state.
    """
    prog = selection.program
    steps = len(step_inputs)
    machine = Machine(rs.prime, {**initial, **step_inputs[0]})

    def rebind_machine(t: int):
        # zero temps
        for b in prog.buffers:
            if b.name in rs.prime.homes and prog.buffer(b.name).temp:
                machine.home_data[b.name][...] = 0.0
                for key in [k for k in list(machine.region_data)
                            if k[1] == b.name]:
                    del machine.region_data[key]
        # streamed inputs: new content lands at home
        for name in rs.streamed:
            machine.home_data[name] = np.asarray(
                step_inputs[t][name], dtype=np.float64).copy()
            for key in [k for k in list(machine.region_data) if k[1] == name]:
                del machine.region_data[key]
        # carry: outputs become inputs
        for out_buf, in_buf in rs.carry.items():
            machine.home_data[in_buf] = machine.home_data[out_buf].copy()
            for key in [k for k in list(machine.region_data) if k[1] == in_buf]:
                del machine.region_data[key]
            for key in [k for k in list(machine.region_data) if k[1] == out_buf]:
                node, _, bounds = key
                machine.region_data[(node, in_buf, bounds)] = \
                    machine.region_data.pop(key)
            machine.home_data[out_buf][...] = 0.0

    for op in rs.prime.ops:
        machine.run_op(op, selection)
    for t in range(1, steps - 1):
        rebind_machine(t)
        for op in rs.recursive.ops:
            machine.run_op(op, selection)
    if steps > 1:
        rebind_machine(steps - 1)
        for op in rs.finish.ops:
            machine.run_op(op, selection)
    return {name: machine.home_data[name].astype(np.float32)
            for name in prog.outputs}
