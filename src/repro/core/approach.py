"""The Approach class — unified interface to all compiler choices (Section 4).

Every combinatorial decision the compiler makes is routed through one of the
methods below: instruction ranking, tiling factors, unroll order, device
allocation, copy-source selection, memory paths, and buffer homes.  The
default ``GreedyApproach`` implements the paper's heuristics; CostModel- and
random-sampling Approaches plug in without touching compiler internals.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:  # pragma: no cover
    from .isel import SelectedInstr
    from .scheduler import ComputeTile, SchedulerState
    from .sysgraph import ComputeNode, MoveEdge, SystemGraph


#: Unroll-order sort keys.  Both keep reduction offsets ascending within a
#: fixed output region, so tiled accumulation replays the oracle's summation
#: order exactly (the executor-vs-interpret bit-exactness the search
#: subsystem validates against).
UNROLL_POLICIES = {
    # outputs adjacent, reduction innermost (the paper's 3.3 heuristic)
    "out_major": lambda t: (t.instr_idx, t.out_key(), t.red_key()),
    # sweep the reduction front across outputs (panel-major issue order)
    "red_major": lambda t: (t.instr_idx, t.red_key(), t.out_key()),
}

#: Allocation policies for choose_device.
DEVICE_POLICIES = ("locality", "load", "round_robin")

#: Copy-source policies for choose_source.
SOURCE_POLICIES = ("cheapest", "first")


class Approach:
    """Base class: every method has the paper's default heuristic.

    Every *decision point* is also exposed as plain data (the class
    attributes below), so search-based Approaches (``repro.search``) can
    drive the full mapping/schedule space from an explicit config vector
    without overriding methods.  The defaults reproduce ``GreedyApproach``
    exactly.
    """

    # ---- decision points as data (driven by repro.search.space) -----------
    #: ceiling on the staging-memory bytes a tile working set may claim.
    #: The effective budget is min(this, the target graph's
    #: ``staging_budget``) — on real targets the graph-derived budget (TPU
    #: VMEM, GPU shared memory, register files) is the binding term and
    #: this constant only caps budget-free calls.
    tile_vmem_budget: int = 96 << 20
    #: fraction of the (device-capped) budget the tile may actually use
    vmem_frac: float = 1.0
    #: explicit (i, j, k) tile caps; ``None`` entries fall back to the
    #: hardware tile (i, j) / budget-deep streaming (k)
    tile_caps: tuple[int | None, int | None, int | None] = (None, None, None)
    #: stream the reduction axis as deep as the VMEM budget allows
    stream_k: bool = True
    #: grow the j tile into leftover budget (fewer output routings)
    grow_j: bool = True
    #: key into UNROLL_POLICIES
    unroll_policy: str = "out_major"
    #: one of DEVICE_POLICIES
    device_policy: str = "locality"
    #: one of SOURCE_POLICIES
    source_policy: str = "cheapest"

    # ---- instruction selection (Section 2.4) ------------------------------
    def rank_instruction(self, si: "SelectedInstr", prog):
        """Sort key: minimum final instruction count — widest window first,
        then fewest invocations."""
        return (-len(si.mapping.stmt_map), si.mapping.calls(prog))

    # ---- tiling (Section 3.3) ---------------------------------------------
    def choose_tile_shape(self, needle_name: str, extents: dict[str, int],
                          hw_tile: tuple[int, int, int],
                          vmem_budget: int | None = None) -> dict[str, int]:
        """Tile sizes for the mapped (i, j, k) axes of a matmul-like needle.

        By default output dims (i, j) tile at the hardware shape and the
        reduction axis streams as deep as the VMEM budget allows (copy
        coalescing: one big panel DMA replaces ceil(K/tk) small ones, and
        the MXU pipelines the k-passes within the tile).  ``tile_caps`` /
        ``stream_k`` / ``grow_j`` / ``vmem_frac`` override each piece."""
        ti, tj, tk = hw_tile
        cap_i = self.tile_caps[0] or ti
        cap_j = self.tile_caps[1] or tj
        cap_k = self.tile_caps[2]
        out = {}
        for axis, ext in extents.items():
            cap = {"i": cap_i, "j": cap_j}.get(axis)
            if cap is not None:
                out[axis] = min(ext, cap)
        budget = self.tile_vmem_budget
        if vmem_budget is not None:
            budget = min(budget, vmem_budget)
        budget = int(budget * self.vmem_frac)
        if "k" in extents:
            bm = out.get("i", cap_i)
            bn = out.get("j", cap_j)
            if cap_k is not None:
                out["k"] = min(extents["k"], max(tk, cap_k))
            elif self.stream_k:
                # A panel (bm, k) + B panel (k, bn) + C tile, 4B each
                k_max = max(tk, (budget // 4 - bm * bn) // max(bm + bn, 1))
                out["k"] = min(extents["k"], k_max)
            else:
                out["k"] = min(extents["k"], tk)
            # grow the j tile into leftover budget (fewer output routings),
            # MXU-aligned
            bk = out["k"]
            if self.grow_j and "j" in extents:
                j_max = (budget // 4 - bm * bk) // max(bk + bm, 1)
                j_max = max(tj, (j_max // tj) * tj)
                out["j"] = min(extents["j"], max(out.get("j", tj), j_max))
        for axis, ext in extents.items():
            out.setdefault(axis, min(ext, max(ti, tj, tk)))
        return out

    # ---- unrolling (Section 3.3) ------------------------------------------
    def unroll_order(self, tiles: list["ComputeTile"]) -> list["ComputeTile"]:
        """Dependency/issue order, selected by ``unroll_policy``.  Default
        (paper 3.3): place computations which use the same memory close
        together — sort by output region so accumulation chains are
        adjacent, keeping the reduction (k) innermost."""
        return sorted(tiles, key=UNROLL_POLICIES[self.unroll_policy])

    # ---- device allocation (Section 3.4) ------------------------------------
    def choose_device(self, tile: "ComputeTile",
                      candidates: Sequence["ComputeNode"],
                      state: "SchedulerState") -> "ComputeNode":
        """Balance memory locality against parallelism (paper 3.4).  The
        default ``locality`` policy prefers the device whose memory already
        holds the most operand bytes (so persistent weights pin work to
        their core), then least-loaded; ``load`` inverts the priority;
        ``round_robin`` spreads tiles blindly."""
        if self.device_policy == "round_robin":
            # the cursor lives on the per-run scheduler state, so a reused
            # Approach instance stays deterministic across schedule() calls
            order = sorted(candidates, key=lambda c: c.name)
            rr = getattr(state, "_rr_cursor", 0)
            state._rr_cursor = rr + 1
            return order[rr % len(order)]
        best, best_key = None, None
        for c in candidates:
            missing = 0
            for _, region, r, w in tile.operands:
                resident = state.holds_region(c.memory, region)
                if (r or w) and not resident:
                    missing += state.nbytes(region)
            load = state.device_load.get(c.name, 0.0)
            key = ((load, missing) if self.device_policy == "load"
                   else (missing, load))
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best

    # ---- memory movement (Section 3.5) ---------------------------------------
    def choose_source(self, options: list[tuple[str, float]]) -> str:
        """Pick which existing copy to read from: (memory node, est. cost)."""
        if self.source_policy == "first":
            return options[0][0]
        return min(options, key=lambda o: o[1])[0]

    def choose_path(self, graph: "SystemGraph", src: str, dst: str,
                    nbytes: int) -> list["MoveEdge"]:
        return graph.shortest_path(src, dst, nbytes)

    def choose_home(self, buffer_name: str, nbytes: int,
                    graph: "SystemGraph") -> str:
        """Initial residence of a buffer: round-robin across the level-1
        (HBM) modules, falling back to host for oversized buffers."""
        hbms = sorted(m.name for m in graph.memories.values() if m.level == 1)
        if not hbms:
            return "host"
        pick = hbms[hash(buffer_name) % len(hbms)]
        if nbytes > graph.memories[pick].capacity // 2:
            return "host"
        return pick


class GreedyApproach(Approach):
    """The paper's reported configuration: pure heuristics."""


@dataclass
class RandomApproach(Approach):
    """Random choices — the sampling primitive for search-based approaches."""

    seed: int = 0
    rng: random.Random = field(init=False)

    def __post_init__(self):
        self.rng = random.Random(self.seed)

    def choose_device(self, tile, candidates, state):
        return self.rng.choice(list(candidates))

    def unroll_order(self, tiles):
        tiles = list(tiles)
        self.rng.shuffle(tiles)
        # keep accumulation chains valid: stable-sort back by output region
        tiles.sort(key=lambda t: (t.instr_idx, t.out_key()))
        return tiles


class CostModelApproach(Approach):
    """Samples N candidate Approaches, schedules with each, and keeps the one
    whose *modeled makespan* (scheduler cost model) is lowest.  This is the
    'cost models and potentially machine learning' extension point of
    Section 4 — implemented as schedule-level search."""

    def __init__(self, samples: int = 8, seed: int = 0):
        self.samples = samples
        self.seed = seed

    def candidates(self) -> list[Approach]:
        return [GreedyApproach()] + [RandomApproach(self.seed + s)
                                     for s in range(self.samples - 1)]
