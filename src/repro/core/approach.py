"""The Approach class — unified interface to all compiler choices (Section 4).

Every combinatorial decision the compiler makes is routed through one of the
methods below: instruction ranking, tiling factors, unroll order, device
allocation, copy-source selection, memory paths, and buffer homes.  The
default ``GreedyApproach`` implements the paper's heuristics; CostModel- and
random-sampling Approaches plug in without touching compiler internals.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:  # pragma: no cover
    from .isel import SelectedInstr
    from .scheduler import ComputeTile, SchedulerState
    from .sysgraph import ComputeNode, MoveEdge, SystemGraph


class Approach:
    """Base class: every method has the paper's default heuristic."""

    # ---- instruction selection (Section 2.4) ------------------------------
    def rank_instruction(self, si: "SelectedInstr", prog):
        """Sort key: minimum final instruction count — widest window first,
        then fewest invocations."""
        return (-len(si.mapping.stmt_map), si.mapping.calls(prog))

    # ---- tiling (Section 3.3) ---------------------------------------------
    #: VMEM budget the tile working set may claim (bytes)
    tile_vmem_budget: int = 96 << 20

    def choose_tile_shape(self, needle_name: str, extents: dict[str, int],
                          hw_tile: tuple[int, int, int],
                          vmem_budget: int | None = None) -> dict[str, int]:
        """Tile sizes for the mapped (i, j, k) axes of a matmul-like needle.

        Output dims (i, j) tile at the hardware shape; the reduction axis
        streams as deep as the VMEM budget allows (copy coalescing: one big
        panel DMA replaces ceil(K/tk) small ones, and the MXU pipelines the
        k-passes within the tile)."""
        ti, tj, tk = hw_tile
        out = {}
        for axis, ext in extents.items():
            cap = {"i": ti, "j": tj}.get(axis)
            if cap is not None:
                out[axis] = min(ext, cap)
        budget = self.tile_vmem_budget
        if vmem_budget is not None:
            budget = min(budget, vmem_budget)
        if "k" in extents:
            bm = out.get("i", ti)
            bn = out.get("j", tj)
            # A panel (bm, k) + B panel (k, bn) + C tile, 4B each
            k_max = max(tk, (budget // 4 - bm * bn) // max(bm + bn, 1))
            out["k"] = min(extents["k"], k_max)
            # grow the j tile into leftover budget (fewer output routings),
            # MXU-aligned
            bk = out["k"]
            j_max = (budget // 4 - bm * bk) // max(bk + bm, 1)
            j_max = max(tj, (j_max // tj) * tj)
            if "j" in extents:
                out["j"] = min(extents["j"], max(out.get("j", tj), j_max))
        for axis, ext in extents.items():
            out.setdefault(axis, min(ext, max(ti, tj, tk)))
        return out

    # ---- unrolling (Section 3.3) ------------------------------------------
    def unroll_order(self, tiles: list["ComputeTile"]) -> list["ComputeTile"]:
        """Dependency/issue order.  Default heuristic (paper 3.3): place
        computations which use the same memory close together — sort by
        output region so accumulation chains are adjacent, keeping the
        reduction (k) innermost."""
        return sorted(tiles, key=lambda t: (t.instr_idx, t.out_key(), t.red_key()))

    # ---- device allocation (Section 3.4) ------------------------------------
    def choose_device(self, tile: "ComputeTile",
                      candidates: Sequence["ComputeNode"],
                      state: "SchedulerState") -> "ComputeNode":
        """Balance memory locality against parallelism (paper 3.4): prefer
        the device whose memory already holds the most operand bytes (so
        persistent weights pin work to their core), then least-loaded."""
        best, best_key = None, None
        for c in candidates:
            missing = 0
            for _, region, r, w in tile.operands:
                resident = state.holds_region(c.memory, region)
                if (r or w) and not resident:
                    missing += region.nbytes()
            load = state.device_load.get(c.name, 0.0)
            key = (missing, load)
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best

    # ---- memory movement (Section 3.5) ---------------------------------------
    def choose_source(self, options: list[tuple[str, float]]) -> str:
        """Pick which existing copy to read from: (memory node, est. cost)."""
        return min(options, key=lambda o: o[1])[0]

    def choose_path(self, graph: "SystemGraph", src: str, dst: str,
                    nbytes: int) -> list["MoveEdge"]:
        return graph.shortest_path(src, dst, nbytes)

    def choose_home(self, buffer_name: str, nbytes: int,
                    graph: "SystemGraph") -> str:
        """Initial residence of a buffer: round-robin across the level-1
        (HBM) modules, falling back to host for oversized buffers."""
        hbms = sorted(m.name for m in graph.memories.values() if m.level == 1)
        if not hbms:
            return "host"
        pick = hbms[hash(buffer_name) % len(hbms)]
        if nbytes > graph.memories[pick].capacity // 2:
            return "host"
        return pick


class GreedyApproach(Approach):
    """The paper's reported configuration: pure heuristics."""


@dataclass
class RandomApproach(Approach):
    """Random choices — the sampling primitive for search-based approaches."""

    seed: int = 0
    rng: random.Random = field(init=False)

    def __post_init__(self):
        self.rng = random.Random(self.seed)

    def choose_device(self, tile, candidates, state):
        return self.rng.choice(list(candidates))

    def unroll_order(self, tiles):
        tiles = list(tiles)
        self.rng.shuffle(tiles)
        # keep accumulation chains valid: stable-sort back by output region
        tiles.sort(key=lambda t: (t.instr_idx, t.out_key()))
        return tiles


class CostModelApproach(Approach):
    """Samples N candidate Approaches, schedules with each, and keeps the one
    whose *modeled makespan* (scheduler cost model) is lowest.  This is the
    'cost models and potentially machine learning' extension point of
    Section 4 — implemented as schedule-level search."""

    def __init__(self, samples: int = 8, seed: int = 0):
        self.samples = samples
        self.seed = seed

    def candidates(self) -> list[Approach]:
        return [GreedyApproach()] + [RandomApproach(self.seed + s)
                                     for s in range(self.samples - 1)]
