"""IR transformations + the non-deterministic mapping search (Section 2.3).

When the deterministic mapper fails, its structured failures drive the choice
of algebraic transformation to apply next.  The canonical example is the
separable-depthwise convolution (paper Listing 3): the reduction chain
contains *two* multiplications, so no matmul window is extractable; the
**factor-out-of-reduction** transformation splits the single reduction into a
depthwise reduction followed by a pointwise (matmul-mappable) reduction.

Transformations are semantics-preserving (the hypothesis property tests check
them against the NumPy oracle), modulo buffer-view adaptation exposed through
``adapt_inputs`` / ``adapt_outputs``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import numpy as np

from .ir import Access, Axis, Buffer, IRError, Program, Statement
from .mapper import MapFailure, MapResult, map_program

# --------------------------------------------------------------------------- #
# Transform interface
# --------------------------------------------------------------------------- #


class Transform:
    """A semantics-preserving rewrite of an ISAMIR program."""

    name: str = "transform"

    def apply(self, prog: Program) -> Program:  # pragma: no cover - interface
        raise NotImplementedError

    # Buffer-shape adaptation (identity for most transforms).
    def adapt_inputs(self, inputs: dict) -> dict:
        return inputs

    def adapt_outputs(self, outputs: dict) -> dict:
        return outputs

    def __repr__(self) -> str:
        return self.name


def _identity_access(buffer: str, axes: list[str], axis_names: tuple[str, ...]) -> Access:
    mat = tuple(tuple(1 if an == ax else 0 for an in axis_names) for ax in axes)
    return Access(buffer, mat)


def _axes_used(prog: Program, acc: Access) -> list[str]:
    """Axes with nonzero coefficient, in program axis order."""
    return [an for ai, an in enumerate(prog.axis_names)
            if any(row[ai] for row in acc.matrix)]


# --------------------------------------------------------------------------- #
# Factor-out-of-reduction (the separable-depthwise enabler)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ReductionChain:
    """Statements ``t := A; t *= B1; ...; t *= Bm; C += t`` (m >= 1)."""

    start: int            # index of the ':=' statement
    muls: tuple[int, ...] # indices of the '*=' statements
    end: int              # index of the '+=' statement
    temp: str             # the chain temporary


def find_reduction_chains(prog: Program, min_muls: int = 1) -> list[ReductionChain]:
    chains = []
    i = 0
    stmts = prog.statements
    while i < len(stmts):
        s = stmts[i]
        if s.op == ":=" and prog.buffer(s.lhs.buffer).temp:
            t = s.lhs.buffer
            j = i + 1
            muls = []
            while j < len(stmts) and stmts[j].op == "*=" and stmts[j].lhs.buffer == t:
                muls.append(j)
                j += 1
            if (len(muls) >= min_muls and j < len(stmts)
                    and stmts[j].op == "+=" and stmts[j].rhs.buffer == t):
                # the temp must not be used anywhere else
                uses = [k for k, s2 in enumerate(stmts)
                        if t in prog.reads(s2) or prog.writes(s2) == t]
                if set(uses) <= set([i, j] + muls):
                    chains.append(ReductionChain(i, tuple(muls), j, t))
                    i = j + 1
                    continue
        i += 1
    return chains


@dataclass(frozen=True, repr=False)
class FactorReduction(Transform):
    """Rewrite  ``C += A * B1 * ... * Bm``  (reduction R) into

        U  += A * B1 * ... * B_{f-1} * B_{f+1} * ... * Bm   (reduction R1)
        C  += U * B_f                                        (reduction R2)

    where R1 = R \\ axes(B_f) — the algebraic fact ``sum_R x*y = sum_R2 y *
    (sum_R1 x)`` when y is independent of R1 (associativity + distributivity,
    the paper's "small set of core algebraic transformations")."""

    chain: ReductionChain
    factor_mul: int  # index into chain.muls of the multiplicand to factor out

    @property
    def name(self) -> str:
        return f"factor_reduction(@{self.chain.start},mul={self.factor_mul})"

    def apply(self, prog: Program) -> Program:
        ch = self.chain
        stmts = prog.statements
        s_init = stmts[ch.start]
        s_muls = [stmts[m] for m in ch.muls]
        s_end = stmts[ch.end]
        bf = s_muls[self.factor_mul]
        rest = [s for idx, s in enumerate(s_muls) if idx != self.factor_mul]

        out_axes = set(_axes_used(prog, s_end.lhs))
        group1_axes: set[str] = set(_axes_used(prog, s_init.rhs))
        for s in rest:
            group1_axes |= set(_axes_used(prog, s.rhs))
        bf_axes = set(_axes_used(prog, bf.rhs))
        chain_axes = set(_axes_used(prog, s_end.rhs))  # all axes of the temp
        reduction = chain_axes - out_axes
        r1 = (reduction - bf_axes) & group1_axes
        if not r1:
            raise IRError("factoring does not reduce anything (R1 empty)")

        order = list(prog.axis_names)
        u_axes = sorted((group1_axes - r1) | (bf_axes & chain_axes & group1_axes),
                        key=order.index)
        # U must carry everything group 2 still needs from group 1:
        u_axes = sorted(group1_axes - r1, key=order.index)
        ta_axes = sorted(group1_axes, key=order.index)
        tb_axes = sorted((set(u_axes) | bf_axes | out_axes) & (chain_axes | out_axes),
                         key=order.index)

        sz = {a.name: a.size for a in prog.axes}
        ta = Buffer(f"{ch.temp}_a", tuple(sz[a] for a in ta_axes), temp=True)
        U = Buffer(f"{ch.temp}_u", tuple(sz[a] for a in u_axes), temp=True)
        tb = Buffer(f"{ch.temp}_b", tuple(sz[a] for a in tb_axes), temp=True)
        names = prog.axis_names

        new_stmts = list(stmts[:ch.start])
        # group 1: ta := A; ta *= B_i (i != f); U += ta
        new_stmts.append(Statement(":=", _identity_access(ta.name, ta_axes, names),
                                   s_init.rhs))
        for s in rest:
            new_stmts.append(Statement("*=", _identity_access(ta.name, ta_axes, names),
                                       s.rhs))
        new_stmts.append(Statement("+=", _identity_access(U.name, u_axes, names),
                                   _identity_access(ta.name, ta_axes, names)))
        # group 2: tb := U; tb *= B_f; C += tb
        new_stmts.append(Statement(":=", _identity_access(tb.name, tb_axes, names),
                                   _identity_access(U.name, u_axes, names)))
        new_stmts.append(Statement("*=", _identity_access(tb.name, tb_axes, names),
                                   bf.rhs))
        new_stmts.append(Statement("+=", s_end.lhs,
                                   _identity_access(tb.name, tb_axes, names)))
        new_stmts.extend(stmts[ch.end + 1:])

        buffers = tuple(b for b in prog.buffers if b.name != ch.temp) + (ta, U, tb)
        return Program(prog.name + "+fct", prog.axes, buffers, tuple(new_stmts),
                       prog.outputs)


# --------------------------------------------------------------------------- #
# Axis splitting (tiling to fixed-extent needles)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True, repr=False)
class SplitAxis(Transform):
    """Split axis ``a`` (extent N = outer*factor) into ``a_o``, ``a_i``:
    every access coefficient ``c*a`` becomes ``c*factor*a_o + c*a_i``."""

    axis: str
    factor: int

    @property
    def name(self) -> str:
        return f"split_axis({self.axis},{self.factor})"

    def apply(self, prog: Program) -> Program:
        ai = prog.axis_index(self.axis)
        old = prog.axes[ai]
        if old.size % self.factor:
            raise IRError(f"extent {old.size} not divisible by {self.factor}")
        outer = Axis(f"{self.axis}_o", old.size // self.factor)
        inner = Axis(f"{self.axis}_i", self.factor)
        axes = prog.axes[:ai] + (outer, inner) + prog.axes[ai + 1:]

        def rewrite(acc: Access) -> Access:
            mat = []
            for row in acc.matrix:
                c = row[ai]
                mat.append(row[:ai] + (c * self.factor, c) + row[ai + 1:])
            return Access(acc.buffer, tuple(mat), acc.offset)

        stmts = tuple(Statement(s.op, rewrite(s.lhs), rewrite(s.rhs), s.fn)
                      for s in prog.statements)
        return Program(prog.name + f"+split_{self.axis}", axes, prog.buffers,
                       stmts, prog.outputs)


# --------------------------------------------------------------------------- #
# Unit-dimension insertion (rank adaptation)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True, repr=False)
class InsertUnitDim(Transform):
    """Append a size-1 dimension to ``buffer`` (and a fresh size-1 axis), so
    lower-rank haystack buffers can satisfy higher-rank needle operands."""

    buffer: str

    @property
    def name(self) -> str:
        return f"insert_unit_dim({self.buffer})"

    def apply(self, prog: Program) -> Program:
        uax = Axis(f"_u_{self.buffer}", 1)
        axes = prog.axes + (uax,)
        buffers = []
        for b in prog.buffers:
            if b.name == self.buffer:
                buffers.append(Buffer(b.name, b.shape + (1,), b.dtype, b.temp))
            else:
                buffers.append(b)

        ncols = len(prog.axes)

        def rewrite(acc: Access) -> Access:
            mat = tuple(row + (0,) for row in acc.matrix)
            if acc.buffer == self.buffer:
                mat = mat + ((0,) * ncols + (1,),)
                return Access(acc.buffer, mat, acc.offset + (0,))
            return Access(acc.buffer, mat, acc.offset)

        stmts = tuple(Statement(s.op, rewrite(s.lhs), rewrite(s.rhs), s.fn)
                      for s in prog.statements)
        return Program(prog.name + f"+unit_{self.buffer}", tuple(axes),
                       tuple(buffers), stmts, prog.outputs)

    def adapt_inputs(self, inputs: dict) -> dict:
        out = dict(inputs)
        if self.buffer in out:
            out[self.buffer] = np.asarray(out[self.buffer])[..., None]
        return out

    def adapt_outputs(self, outputs: dict) -> dict:
        out = dict(outputs)
        if self.buffer in out:
            out[self.buffer] = np.asarray(out[self.buffer])[..., 0]
        return out


# --------------------------------------------------------------------------- #
# Axis fusion (call-count optimization: fold batch/spatial loops into GEMM M)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True, repr=False)
class DropUnitAxes(Transform):
    """Remove extent-1 axes (their index contribution is always 0).  A
    cleanup pass that unblocks FuseAxes on e.g. 1x1 convolutions whose
    kernel axes survive with size 1."""

    @property
    def name(self) -> str:
        return "drop_unit_axes"

    def apply(self, prog: Program) -> Program:
        keep = [i for i, a in enumerate(prog.axes) if a.size != 1]
        if len(keep) == len(prog.axes):
            raise IRError("no unit axes")

        def rewrite(acc: Access) -> Access:
            return Access(acc.buffer,
                          tuple(tuple(row[c] for c in keep)
                                for row in acc.matrix), acc.offset)

        stmts = tuple(Statement(s.op, rewrite(s.lhs), rewrite(s.rhs), s.fn)
                      for s in prog.statements)
        return Program(prog.name + "+duax",
                       tuple(prog.axes[i] for i in keep), prog.buffers,
                       stmts, prog.outputs)


@dataclass(frozen=True, repr=False)
class FuseAxes(Transform):
    """Fuse adjacent axes ``a1, a2`` into one (row-major: a1*n2 + a2).

    Legal when every access that touches either axis indexes them through two
    consecutive dedicated coeff-1 dims whose inner buffer dim is *exactly*
    ``n2`` — then merging the dims preserves the linear index.  This is what
    turns a 1x1 convolution's (b, y, x) loop nest into a single GEMM M
    dimension (the ISAM-TVM reordering of paper Section 7)."""

    a1: str
    a2: str

    @property
    def name(self) -> str:
        return f"fuse_axes({self.a1},{self.a2})"

    def apply(self, prog: Program) -> Program:
        i1, i2 = prog.axis_index(self.a1), prog.axis_index(self.a2)
        n1, n2 = prog.axis(self.a1).size, prog.axis(self.a2).size
        merges: dict[str, tuple[int, int]] = {}
        for s in prog.statements:
            for acc in (s.lhs, s.rhs):
                r1 = [d for d, row in enumerate(acc.matrix) if row[i1]]
                r2 = [d for d, row in enumerate(acc.matrix) if row[i2]]
                if not r1 and not r2:
                    continue
                if len(r1) != 1 or len(r2) != 1 or r2[0] != r1[0] + 1:
                    raise IRError(f"{acc.buffer}: axes not in consecutive "
                                  f"dedicated dims")
                d1, d2 = r1[0], r2[0]
                row1, row2 = acc.matrix[d1], acc.matrix[d2]
                if (row1[i1] != 1 or row2[i2] != 1
                        or any(c for j, c in enumerate(row1) if j != i1)
                        or any(c for j, c in enumerate(row2) if j != i2)
                        or acc.offset[d1] or acc.offset[d2]):
                    raise IRError(f"{acc.buffer}: non-identity axis usage")
                if prog.buffer(acc.buffer).shape[d2] != n2:
                    raise IRError(f"{acc.buffer}: inner dim != axis extent")
                prev = merges.get(acc.buffer)
                if prev is not None and prev != (d1, d2):
                    raise IRError(f"{acc.buffer}: inconsistent merge dims")
                merges[acc.buffer] = (d1, d2)
        if not merges:
            raise IRError("fusion touches nothing")
        object.__setattr__(self, "_merges", merges)

        fused_name = f"{self.a1}{self.a2}"
        axes = []
        for idx, a in enumerate(prog.axes):
            if idx == i1:
                axes.append(Axis(fused_name, n1 * n2))
            elif idx == i2:
                continue
            else:
                axes.append(a)
        keep_cols = [idx for idx in range(len(prog.axes)) if idx != i2]
        fused_col = keep_cols.index(i1)

        buffers = []
        for b in prog.buffers:
            if b.name in merges:
                d1, d2 = merges[b.name]
                shape = (b.shape[:d1] + (b.shape[d1] * b.shape[d2],)
                         + b.shape[d2 + 1:])
                buffers.append(Buffer(b.name, shape, b.dtype, b.temp))
            else:
                buffers.append(b)

        def rewrite(acc: Access) -> Access:
            rows = [tuple(row[c] for c in keep_cols) for row in acc.matrix]
            offs = list(acc.offset)
            if acc.buffer in merges:
                d1, d2 = merges[acc.buffer]
                merged = list(rows[d1])
                merged[fused_col] = 1
                rows = rows[:d1] + [tuple(merged)] + rows[d2 + 1:]
                offs = offs[:d1] + [0] + offs[d2 + 1:]
            return Access(acc.buffer, tuple(rows), tuple(offs))

        stmts = tuple(Statement(s.op, rewrite(s.lhs), rewrite(s.rhs), s.fn)
                      for s in prog.statements)
        return Program(prog.name + f"+fuse_{self.a1}{self.a2}", tuple(axes),
                       tuple(buffers), stmts, prog.outputs)

    def _reshape(self, arrs: dict, inverse: bool) -> dict:
        merges = getattr(self, "_merges", {})
        out = dict(arrs)
        for bname, (d1, d2) in merges.items():
            if bname not in out:
                continue
            a = np.asarray(out[bname])
            if inverse:
                # only outputs come back; shapes tracked by caller
                continue
            shape = a.shape[:d1] + (a.shape[d1] * a.shape[d2],) + a.shape[d2 + 1:]
            out[bname] = a.reshape(shape)
        return out

    def adapt_inputs(self, inputs: dict) -> dict:
        return self._reshape(inputs, inverse=False)

    def adapt_outputs(self, outputs: dict) -> dict:
        # callers compare against original shapes; un-merge is shape-driven
        merges = getattr(self, "_merges", {})
        out = dict(outputs)
        for bname, (d1, d2) in merges.items():
            if bname in out:
                a = np.asarray(out[bname])
                out[bname] = a  # shape restored by caller reshape if needed
        return out


def fuse_axes_for_calls(prog: Program, isa: list[Program],
                        max_fusions: int = 4):
    """Greedy performance pass: keep fusing axis pairs while the selected
    instruction cover needs fewer total calls (the Approach-style heuristic
    behind the ISAM-TVM loop-nest reordering)."""
    from .isel import select_instructions
    steps: list[Transform] = []
    try:
        t0 = DropUnitAxes()
        prog = t0.apply(prog)
        steps.append(t0)
    except IRError:
        pass
    sel = select_instructions(prog, isa, allow_transforms=False)
    for _ in range(max_fusions):
        best = None
        names = prog.axis_names
        for x1 in names:
            for x2 in names:
                if x1 == x2:
                    continue
                t = FuseAxes(x1, x2)
                try:
                    p2 = t.apply(prog)
                except IRError:
                    continue
                sel2 = select_instructions(p2, isa, allow_transforms=False)
                if not sel2.complete:
                    continue
                if best is None or sel2.total_calls() < best[1].total_calls():
                    best = (p2, sel2, t)
        if best is None or best[1].total_calls() >= sel.total_calls():
            break
        prog, sel, t = best
        steps.append(t)
    return prog, sel, steps


# --------------------------------------------------------------------------- #
# Feedback-guided proposal + search (the non-deterministic mapper)
# --------------------------------------------------------------------------- #


def propose_transforms(prog: Program, failures: Iterable[MapFailure],
                       needle: Program) -> list[Transform]:
    """Paper Section 2.3: 'the deterministic mapper can report where and why
    it failed to map ... the non-deterministic mapper can then use this
    information, along with prior knowledge of what the factorization pass
    does, to determine that performing the factorization pass would make the
    needed change.'"""
    props: list[Transform] = []
    kinds = {f.kind for f in failures}

    # Extra multiplication blocking a reduction window -> factor it out.
    if kinds & {"not_extractable", "op_mismatch"}:
        for ch in find_reduction_chains(prog, min_muls=2):
            for f in range(len(ch.muls)):
                props.append(FactorReduction(ch, f))

    # Fixed-extent needle axes -> tile haystack axes by splitting.
    for f in failures:
        if f.kind == "extent_mismatch":
            # detail: "... needs extent E, haystack <axis> has N"
            for na in needle.axes:
                if not na.size:
                    continue
                for ha in prog.axes:
                    if ha.size > na.size and ha.size % na.size == 0:
                        t = SplitAxis(ha.name, na.size)
                        if t.name not in {p.name for p in props}:
                            props.append(t)

    # Needle operand rank exceeds haystack buffer rank -> add unit dims.
    if "dim_exhausted" in kinds:
        for b in prog.buffers:
            if not b.temp:
                props.append(InsertUnitDim(b.name))

    return props


@dataclass
class SearchResult:
    program: Program
    steps: tuple[Transform, ...]
    mapping_result: MapResult

    def adapt_inputs(self, inputs: dict) -> dict:
        for t in self.steps:
            inputs = t.adapt_inputs(inputs)
        return inputs

    def adapt_outputs(self, outputs: dict) -> dict:
        for t in reversed(self.steps):
            outputs = t.adapt_outputs(outputs)
        return outputs


def search_mappings(haystack: Program, needle: Program, max_depth: int = 3,
                    beam: int = 24, max_results: int = 8) -> list[SearchResult]:
    """Breadth-first, feedback-guided search over transformation sequences
    (Figure 1's loop between the non-deterministic sampler and the
    deterministic mapper).  Returns programs on which the needle maps."""
    results: list[SearchResult] = []
    frontier: list[tuple[Program, tuple[Transform, ...]]] = [(haystack, ())]
    seen = {haystack.signature()}

    for _ in range(max_depth + 1):
        nxt: list[tuple[Program, tuple[Transform, ...]]] = []
        for prog, steps in frontier:
            res = map_program(prog, needle)
            if res.ok:
                results.append(SearchResult(prog, steps, res))
                if len(results) >= max_results:
                    return results
                continue  # mapped — no need to transform further
            for t in propose_transforms(prog, res.failures, needle):
                try:
                    p2 = t.apply(prog)
                except IRError:
                    continue
                sig = p2.signature()
                if sig in seen:
                    continue
                seen.add(sig)
                nxt.append((p2, steps + (t,)))
                if len(nxt) >= beam:
                    break
        frontier = nxt
        if not frontier:
            break
    return results


# --------------------------------------------------------------------------- #
# Epilogue fusion (the graph tier's producer+consumer composition)
# --------------------------------------------------------------------------- #


def _is_identity_access(prog: Program, acc: Access) -> bool:
    """True iff dim d of the access reads axis d directly (the elementwise
    same-shape pattern): identity coefficient matrix over a prefix of the
    program axes, zero offsets."""
    if any(o != 0 for o in acc.offset):
        return False
    for d, row in enumerate(acc.matrix):
        for a, coeff in enumerate(row):
            if coeff != (1 if a == d else 0):
                return False
    return True


def _output_axes(prog: Program, out: str) -> list[str]:
    """The program axes indexing each dim of output buffer ``out`` — every
    access of ``out`` must agree and use exactly one axis per dim."""
    axes: list[str] | None = None
    for s in prog.statements:
        for acc in (s.lhs, s.rhs):
            if acc.buffer != out:
                continue
            cur = []
            for row, off in zip(acc.matrix, acc.offset):
                hits = [a for a, c in enumerate(row) if c]
                if off != 0 or len(hits) != 1 or row[hits[0]] != 1:
                    raise IRError(
                        f"{prog.name}: output {out} access is not "
                        f"axis-aligned; cannot fuse an epilogue onto it")
                cur.append(prog.axis_names[hits[0]])
            if axes is None:
                axes = cur
            elif axes != cur:
                raise IRError(
                    f"{prog.name}: output {out} accessed with inconsistent "
                    f"axis order")
    if axes is None:
        raise IRError(f"{prog.name}: output {out} is never accessed")
    return axes


def fuse_epilogue(producer: Program, consumer: Program, wire: str,
                  name: str | None = None,
                  return_map: bool = False):
    """Fold an elementwise ``consumer`` program into ``producer``.

    ``wire`` names the consumer buffer fed by the producer's (single)
    output.  The composed program applies the consumer's statements directly
    to the producer's output buffer — the graph tier's generalization of the
    conv→matmul extraction idiom: compose programs, let instruction
    selection cover the result with fused/VPU needles.

    Supported consumer shapes (everything ``repro.graph.trace`` emits):

      * unary chains starting from ``wire`` — ``O := fn(W); O := fn(O); ...``
      * copy-accumulate — ``O := W; O op= B; ...``
      * accumulate-into — ``O := B; O op= W`` with commutative ``op``
        (rewritten as ``C op= B``, valid because C already holds W's value)

    Raises ``IRError`` when the consumer does not match (the fusion pass
    treats that as "not fusable", not as an error).
    """
    if len(producer.outputs) != 1 or len(consumer.outputs) != 1:
        raise IRError("epilogue fusion needs single-output programs")
    c_name = producer.outputs[0]
    out = consumer.outputs[0]
    if wire == out or wire not in {b.name for b in consumer.buffers}:
        raise IRError(f"bad wire buffer {wire!r}")
    c_buf = producer.buffer(c_name)
    c_axes = _output_axes(producer, c_name)
    ax_index = {a: i for i, a in enumerate(producer.axis_names)}

    # the consumer must be pure elementwise over the producer-output shape
    if tuple(a.size for a in consumer.axes) != tuple(c_buf.shape):
        raise IRError("consumer iteration space != producer output shape")
    for s in consumer.statements:
        for acc in (s.lhs, s.rhs):
            if not _is_identity_access(consumer, acc):
                raise IRError("consumer access is not identity/elementwise")
    for b in consumer.buffers:
        if tuple(b.shape) != tuple(c_buf.shape):
            raise IRError("consumer buffer shape != producer output shape")
    if sum(s.rhs.buffer == wire for s in consumer.statements) != 1:
        raise IRError("wire buffer must be read exactly once")

    # rename consumer buffers into the producer namespace
    taken = {b.name for b in producer.buffers}
    rename = {wire: c_name, out: c_name}
    extra: list[Buffer] = []
    for b in consumer.buffers:
        if b.name in rename:
            continue
        nn, i = b.name, 0
        while nn in taken:
            i += 1
            nn = f"{b.name}_e{i}"
        taken.add(nn)
        rename[b.name] = nn
        extra.append(Buffer(nn, tuple(b.shape), b.dtype, b.temp))

    mat = tuple(tuple(1 if a == ax_index[c_axes[d]] else 0
                      for a in range(len(producer.axes)))
                for d in range(len(c_axes)))

    def remap(acc: Access) -> Access:
        return Access(rename[acc.buffer], mat)

    stmts = list(consumer.statements)
    epilogue: list[Statement] = []
    if stmts and stmts[0].rhs.buffer != wire:
        # accumulate-into: O := B; O op= W  ->  C op= B
        if (len(stmts) != 2 or stmts[0].op != ":="
                or stmts[1].rhs.buffer != wire
                or stmts[1].op not in ("+=", "*=", "max=")):
            raise IRError("unsupported epilogue shape")
        epilogue.append(Statement(stmts[1].op, remap(stmts[1].lhs),
                                  remap(stmts[0].rhs)))
    else:
        for i, s in enumerate(stmts):
            if i == 0 and s.op == ":=":
                continue                      # O := W — C already holds it
            epilogue.append(Statement(s.op, remap(s.lhs), remap(s.rhs),
                                      s.fn))

    fused = Program(name or f"{producer.name}+{consumer.name}",
                    producer.axes, producer.buffers + tuple(extra),
                    producer.statements + tuple(epilogue), producer.outputs)
    return (fused, dict(rename)) if return_map else fused
