"""One dtype-size table for the whole stack.

Three copies of this table used to live in ``core/scheduler.py``,
``launch/hlo_flops.py`` and ``launch/hlo_analysis.py``; they are consolidated
here so the ISAMIR scheduler, the HLO analyses and the fabric partitioner all
price bytes from the same source.  Names cover both the ISAMIR dtype
vocabulary (``f32``/``f64``/``bf16``/``i32``) and XLA's HLO element types
(``pred``/``s32``/``u8``/...).
"""
from __future__ import annotations

DTYPE_BYTES: dict[str, int] = {
    # ISAMIR buffer dtypes
    "f32": 4, "f64": 8, "bf16": 2, "i32": 4,
    # XLA HLO element types
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}


def dtype_bytes(name: str, default: int = 4) -> int:
    """Bytes per element of ``name``; unknown dtypes fall back to f32."""
    return DTYPE_BYTES.get(name, default)
