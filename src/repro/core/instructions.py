"""The target "ISA" described in ISAMIR needles (paper Sections 2.1, 5).

On TPU the instruction set exposed to the mapper is:

  * ``mxu.matmul``    — C[i,j] += A[i,k] * B[k,j]   (the MXU; any extents —
                         the scheduler tiles macro-calls into 128^3 hardware
                         tiles, see scheduler.py)
  * ``mxu.matmul128`` — fixed 128x128x128 variant (the literal hardware tile)
  * ``vpu.dot``       — c[] += a[k] * b[k]
  * ``vpu.mul`` / ``vpu.add`` / ``vpu.sub`` / ``vpu.max`` — elementwise binary
  * ``vpu.<fn>``      — elementwise unary (sigmoid, tanh, relu, exp, ...)
  * ``vpu.reduce_sum`` / ``vpu.reduce_max`` — axis reduction
  * ``fused.matmul_bias_<fn>`` — fused GEMM + bias + activation (the paper's
                         "fused instructions" used by instruction selection)

Needle axis size 0 = symbolic (matches any extent).  Buffers named abstractly;
the mapper's buffer map ties them to real haystack buffers.
"""
from __future__ import annotations

from functools import lru_cache

from .ir import Program, ProgramBuilder, UNARY_FNS


@lru_cache(maxsize=None)
def mxu_matmul(ti: int = 0, tj: int = 0, tk: int = 0, name: str = "mxu.matmul") -> Program:
    pb = ProgramBuilder(name)
    i, j, k = pb.axis("i", ti), pb.axis("j", tj), pb.axis("k", tk)
    A = pb.buffer("A", (ti, tk))
    B = pb.buffer("B", (tk, tj))
    C = pb.buffer("C", (ti, tj))
    t = pb.temp("t", (ti, tj, tk))
    pb.stmt(t[i, j, k], ":=", A[i, k])
    pb.stmt(t[i, j, k], "*=", B[k, j])
    pb.stmt(C[i, j], "+=", t[i, j, k])
    return pb.build()


@lru_cache(maxsize=None)
def mxu_matmul128() -> Program:
    return mxu_matmul(128, 128, 128, name="mxu.matmul128")


@lru_cache(maxsize=None)
def vpu_dot() -> Program:
    pb = ProgramBuilder("vpu.dot")
    k = pb.axis("k", 0)
    a = pb.buffer("a", (0,))
    b = pb.buffer("b", (0,))
    c = pb.buffer("c", (1,))
    t = pb.temp("t", (0,))
    pb.stmt(t[k], ":=", a[k])
    pb.stmt(t[k], "*=", b[k])
    pb.stmt(c[0], "+=", t[k])
    return pb.build()


@lru_cache(maxsize=None)
def vpu_binary(op: str) -> Program:
    """Elementwise binary: y <op>= x over one symbolic axis."""
    sym = {"*=": "mul", "+=": "add", "-=": "sub", "max=": "max"}[op]
    pb = ProgramBuilder(f"vpu.{sym}")
    e = pb.axis("e", 0)
    x = pb.buffer("x", (0,))
    y = pb.buffer("y", (0,))
    pb.stmt(y[e], op, x[e])
    return pb.build()


@lru_cache(maxsize=None)
def vpu_unary(fn: str) -> Program:
    assert fn in UNARY_FNS, fn
    pb = ProgramBuilder(f"vpu.{fn}")
    e = pb.axis("e", 0)
    x = pb.buffer("x", (0,))
    y = pb.buffer("y", (0,))
    pb.apply(y[e], fn, x[e])
    return pb.build()


@lru_cache(maxsize=None)
def vpu_unary_inplace(fn: str) -> Program:
    """In-place elementwise unary: x := fn(x) (operands may alias on the VPU)."""
    assert fn in UNARY_FNS, fn
    pb = ProgramBuilder(f"vpu.{fn}_")
    e = pb.axis("e", 0)
    x = pb.buffer("x", (0,))
    pb.apply(x[e], fn, x[e])
    return pb.build()


@lru_cache(maxsize=None)
def vpu_copy() -> Program:
    pb = ProgramBuilder("vpu.copy")
    e = pb.axis("e", 0)
    x = pb.buffer("x", (0,))
    y = pb.buffer("y", (0,))
    pb.stmt(y[e], ":=", x[e])
    return pb.build()


@lru_cache(maxsize=None)
def vpu_reduce(op: str = "+=") -> Program:
    sym = {"+=": "reduce_sum", "max=": "reduce_max"}[op]
    pb = ProgramBuilder(f"vpu.{sym}")
    r = pb.axis("r", 0)
    x = pb.buffer("x", (0,))
    y = pb.buffer("y", (1,))
    pb.stmt(y[0], op, x[r])
    return pb.build()


@lru_cache(maxsize=None)
def fused_matmul_bias(fn: str = "") -> Program:
    """C[i,j] = fn(sum_k A[i,k] B[k,j] + b[j]) — a fused MXU+VPU instruction.

    Exposing this lets instruction selection (Section 2.4) choose between one
    fused call and three separate calls; the GRU benchmark exercises it.
    """
    name = "fused.matmul_bias" + (f"_{fn}" if fn else "")
    pb = ProgramBuilder(name)
    i, j, k = pb.axis("i", 0), pb.axis("j", 0), pb.axis("k", 0)
    A = pb.buffer("A", (0, 0))
    B = pb.buffer("B", (0, 0))
    b = pb.buffer("b", (0,))
    C = pb.buffer("C", (0, 0))
    t = pb.temp("t", (0, 0, 0))
    pb.stmt(t[i, j, k], ":=", A[i, k])
    pb.stmt(t[i, j, k], "*=", B[k, j])
    pb.stmt(C[i, j], "+=", t[i, j, k])
    pb.stmt(C[i, j], "+=", b[j])
    if fn:
        pb.apply(C[i, j], fn, C[i, j])
    return pb.build()


def tpu_isa(include_fused: bool = True) -> list[Program]:
    """The full needle library, most-specific (largest) first — instruction
    selection prefers needles that cover more statements per call."""
    isa: list[Program] = []
    if include_fused:
        isa += [fused_matmul_bias("sigmoid"), fused_matmul_bias("tanh"),
                fused_matmul_bias()]
    isa.append(mxu_matmul())
    isa.append(vpu_dot())
    isa += [vpu_binary(op) for op in ("*=", "+=", "-=", "max=")]
    for fn in ("sigmoid", "tanh", "relu", "exp", "sub_from_one", "neg",
               "recip", "halve"):
        isa.append(vpu_unary(fn))
        isa.append(vpu_unary_inplace(fn))
    isa += [vpu_reduce("+="), vpu_reduce("max="), vpu_copy()]
    return isa


def is_elementwise(needle_name: str) -> bool:
    """Pure elementwise VPU instructions (no reductions): their calls can be
    coalesced across outer axes by the scheduler (one big vector op instead
    of one call per outer point)."""
    if not needle_name.startswith("vpu."):
        return False
    return needle_name != "vpu.dot" and not needle_name.startswith("vpu.reduce")
