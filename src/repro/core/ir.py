"""ISAMIR — the paper's intermediate representation (Section 2.1).

Both the program to execute (the "haystack") and every hardware instruction
(a "needle") are expressed in the same IR:

  * a set of *loop axes* with integer extents (the ``forall`` domain — the IR is
    iteration-order invariant, so the axis set carries no ordering semantics),
  * a set of *buffers* (named, shaped, dtyped tensors),
  * a list of three-operand *statements*, each performing exactly one operation
    ``lhs <op>= rhs`` where both sides are affine *accesses* into buffers.

Each access is represented by an integer *access matrix* with one row per
buffer dimension and one column per loop axis, plus a constant offset vector —
exactly the polyhedral-style representation the paper uses for mapping
(Section 2.2).  Statements are executed (for analysis semantics) one at a time
over their full iteration domain.

This module also provides a NumPy interpreter used as the semantic oracle for
mapper / transformation correctness tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

import numpy as np

# --------------------------------------------------------------------------- #
# Operations
# --------------------------------------------------------------------------- #

#: Binary accumulate / assign operations, in the paper's ``<op>=`` notation.
OPS = (":=", "+=", "*=", "-=", "max=")

#: Unary elementwise functions supported by APPLY statements (``lhs := f(rhs)``).
UNARY_FNS: dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "sigmoid": lambda x: 1.0 / (1.0 + np.exp(-x)),
    "tanh": np.tanh,
    "relu": lambda x: np.maximum(x, 0.0),
    "exp": np.exp,
    "neg": np.negative,
    "recip": lambda x: 1.0 / x,
    "sub_from_one": lambda x: 1.0 - x,  # common in gates: (1 - z)
    "halve": lambda x: 0.5 * x,  # exact in binary fp: attention 1/sqrt(d)
    "id": lambda x: x,
}


class IRError(ValueError):
    """Raised on malformed ISAMIR constructs."""


# --------------------------------------------------------------------------- #
# Core node types
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Axis:
    """A loop axis: name + extent.  Extent ``0`` means symbolic (needles)."""

    name: str
    size: int = 0

    @property
    def symbolic(self) -> bool:
        return self.size == 0


@dataclass(frozen=True)
class Buffer:
    """A named tensor.  ``temp`` buffers exist only for 3-operand analysis and
    are removed / replaced before execution (paper Section 2.1)."""

    name: str
    shape: tuple[int, ...]
    dtype: str = "f32"
    temp: bool = False

    @property
    def rank(self) -> int:
        return len(self.shape)


@dataclass(frozen=True)
class Access:
    """Affine access into ``buffer``: index of dim ``d`` at iteration point
    ``x`` (a vector over program axes, in program axis order) is

        ``index[d] = sum_a matrix[d][a] * x[a] + offset[d]``.
    """

    buffer: str
    matrix: tuple[tuple[int, ...], ...]  # rows = buffer dims, cols = prog axes
    offset: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.offset:
            object.__setattr__(self, "offset", (0,) * len(self.matrix))
        if len(self.offset) != len(self.matrix):
            raise IRError(f"offset rank {len(self.offset)} != matrix rows {len(self.matrix)}")

    @property
    def rank(self) -> int:
        return len(self.matrix)

    def np_matrix(self) -> np.ndarray:
        return np.array(self.matrix, dtype=np.int64).reshape(self.rank, -1)

    def axes_used(self, axis_names: Sequence[str]) -> frozenset[str]:
        """Names of program axes with any nonzero coefficient."""
        used = set()
        for row in self.matrix:
            for a, coeff in enumerate(row):
                if coeff != 0:
                    used.add(axis_names[a])
        return frozenset(used)


@dataclass(frozen=True)
class Statement:
    """``lhs <op>= rhs``; or, for ``op='apply'``, ``lhs := fn(rhs)``."""

    op: str
    lhs: Access
    rhs: Access
    fn: str = ""

    def __post_init__(self):
        if self.op == "apply":
            if self.fn not in UNARY_FNS:
                raise IRError(f"unknown unary fn {self.fn!r}")
        elif self.op not in OPS:
            raise IRError(f"unknown op {self.op!r}")

    @property
    def kind(self) -> str:
        """Op discriminator used for statement matching (op + fn)."""
        return f"apply:{self.fn}" if self.op == "apply" else self.op


@dataclass(frozen=True)
class Program:
    """An ISAMIR program: axes, buffers, and an ordered statement list.

    ``outputs`` names the buffers whose final contents are the program result
    (everything else — in particular temps — is scratch).
    """

    name: str
    axes: tuple[Axis, ...]
    buffers: tuple[Buffer, ...]
    statements: tuple[Statement, ...]
    outputs: tuple[str, ...] = ()

    # -- construction helpers ------------------------------------------------
    def __post_init__(self):
        names = [a.name for a in self.axes]
        if len(set(names)) != len(names):
            raise IRError(f"duplicate axis names in {names}")
        bnames = [b.name for b in self.buffers]
        if len(set(bnames)) != len(bnames):
            raise IRError(f"duplicate buffer names in {bnames}")
        ncols = len(self.axes)
        for s in self.statements:
            for acc in (s.lhs, s.rhs):
                if acc.buffer not in bnames:
                    raise IRError(f"access to unknown buffer {acc.buffer!r}")
                buf = self.buffer(acc.buffer)
                if acc.rank != buf.rank:
                    raise IRError(
                        f"access rank {acc.rank} != buffer {buf.name} rank {buf.rank}")
                for row in acc.matrix:
                    if len(row) != ncols:
                        raise IRError(
                            f"access matrix row width {len(row)} != n axes {ncols}")
        if not self.outputs:
            non_temp_written = []
            for s in self.statements:
                b = self.buffer(s.lhs.buffer)
                if not b.temp and b.name not in non_temp_written:
                    non_temp_written.append(b.name)
            object.__setattr__(self, "outputs", tuple(non_temp_written))

    # -- lookups --------------------------------------------------------------
    def axis(self, name: str) -> Axis:
        for a in self.axes:
            if a.name == name:
                return a
        raise KeyError(name)

    def axis_index(self, name: str) -> int:
        for i, a in enumerate(self.axes):
            if a.name == name:
                return i
        raise KeyError(name)

    def buffer(self, name: str) -> Buffer:
        for b in self.buffers:
            if b.name == name:
                return b
        raise KeyError(name)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    # -- derived properties ----------------------------------------------------
    def reads(self, stmt: Statement) -> tuple[str, ...]:
        """Buffers read by a statement (accumulating ops also read the lhs)."""
        if stmt.op in (":=", "apply"):
            return (stmt.rhs.buffer,)
        return (stmt.rhs.buffer, stmt.lhs.buffer)

    def writes(self, stmt: Statement) -> str:
        return stmt.lhs.buffer

    def signature(self) -> str:
        """Canonical structural string (used for search-space dedup)."""
        parts = [
            ",".join(f"{a.name}:{a.size}" for a in self.axes),
            ",".join(f"{b.name}:{b.shape}:{int(b.temp)}" for b in self.buffers),
        ]
        for s in self.statements:
            parts.append(
                f"{s.kind}|{s.lhs.buffer}{s.lhs.matrix}{s.lhs.offset}"
                f"|{s.rhs.buffer}{s.rhs.matrix}{s.rhs.offset}")
        return ";".join(parts)

    # -- pretty printing --------------------------------------------------------
    def _fmt_access(self, acc: Access) -> str:
        names = self.axis_names
        idxs = []
        for row, off in zip(acc.matrix, acc.offset):
            terms = []
            for a, coeff in enumerate(row):
                if coeff == 1:
                    terms.append(names[a])
                elif coeff != 0:
                    terms.append(f"{coeff}*{names[a]}")
            if off:
                terms.append(str(off))
            idxs.append("+".join(terms) if terms else "0")
        return f"{acc.buffer}[" + "][".join(idxs) + "]"

    def pretty(self) -> str:
        hdr = ", ".join("{}<{}".format(a.name, a.size or "?") for a in self.axes)
        lines = ["forall " + hdr + " {"]
        for s in self.statements:
            lhs, rhs = self._fmt_access(s.lhs), self._fmt_access(s.rhs)
            if s.op == "apply":
                lines.append(f"  {lhs} := {s.fn}({rhs});")
            else:
                lines.append(f"  {lhs} {s.op} {rhs};")
        lines.append("}")
        return "\n".join(lines)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return self.pretty()


# --------------------------------------------------------------------------- #
# Builder — ergonomic front-end for writing ISAMIR programs in tests/configs
# --------------------------------------------------------------------------- #


class ProgramBuilder:
    """Small DSL::

        pb = ProgramBuilder("matmul")
        i, j, k = pb.axes(i=64, j=64, k=64)
        A, B, C = pb.buffer("A", (64, 64)), ...
        t = pb.temp("tmp", (64, 64, 64))
        pb.stmt(t[i, j, k], ":=", A[i, k])
        pb.stmt(t[i, j, k], "*=", B[k, j])
        pb.stmt(C[i, j], "+=", t[i, j, k])
        prog = pb.build()

    Index expressions are linear combinations of axis handles plus ints, e.g.
    ``A[2 * i + d + 1, k]``.
    """

    def __init__(self, name: str):
        self.name = name
        self._axes: list[Axis] = []
        self._buffers: list[Buffer] = []
        self._stmts: list[Statement] = []
        self._outputs: list[str] = []

    # axes ---------------------------------------------------------------
    def axis(self, name: str, size: int = 0) -> "AxisExpr":
        self._axes.append(Axis(name, size))
        return AxisExpr({name: 1}, 0)

    def axes(self, **sizes: int) -> tuple["AxisExpr", ...]:
        return tuple(self.axis(n, s) for n, s in sizes.items())

    # buffers --------------------------------------------------------------
    def buffer(self, name: str, shape: tuple[int, ...], dtype: str = "f32",
               temp: bool = False) -> "BufferHandle":
        self._buffers.append(Buffer(name, tuple(shape), dtype, temp))
        return BufferHandle(self, name)

    def temp(self, name: str, shape: tuple[int, ...], dtype: str = "f32") -> "BufferHandle":
        return self.buffer(name, shape, dtype, temp=True)

    def output(self, *names: str) -> None:
        self._outputs.extend(names)

    # statements --------------------------------------------------------------
    def stmt(self, lhs: "AccessExpr", op: str, rhs: "AccessExpr", fn: str = "") -> None:
        self._stmts.append(Statement(op, lhs.to_access(self), rhs.to_access(self), fn))

    def apply(self, lhs: "AccessExpr", fn: str, rhs: "AccessExpr") -> None:
        self.stmt(lhs, "apply", rhs, fn=fn)

    # finalize ------------------------------------------------------------------
    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self._axes)

    def build(self) -> Program:
        return Program(self.name, tuple(self._axes), tuple(self._buffers),
                       tuple(self._stmts), tuple(self._outputs))


@dataclass(frozen=True)
class AxisExpr:
    """Linear combination of axes + constant, e.g. ``2*i + d + 1``."""

    coeffs: Mapping[str, int]
    const: int = 0

    def __add__(self, other):
        if isinstance(other, int):
            return AxisExpr(self.coeffs, self.const + other)
        merged = dict(self.coeffs)
        for k, v in other.coeffs.items():
            merged[k] = merged.get(k, 0) + v
        return AxisExpr(merged, self.const + other.const)

    __radd__ = __add__

    def __mul__(self, c: int):
        return AxisExpr({k: v * c for k, v in self.coeffs.items()}, self.const * c)

    __rmul__ = __mul__


@dataclass(frozen=True)
class BufferHandle:
    pb: "ProgramBuilder"
    name: str

    def __getitem__(self, idx) -> "AccessExpr":
        if not isinstance(idx, tuple):
            idx = (idx,)
        exprs = []
        for e in idx:
            if isinstance(e, int):
                exprs.append(AxisExpr({}, e))
            else:
                exprs.append(e)
        return AccessExpr(self.name, tuple(exprs))


@dataclass(frozen=True)
class AccessExpr:
    buffer: str
    indices: tuple[AxisExpr, ...]

    def to_access(self, pb: ProgramBuilder) -> Access:
        names = pb.axis_names
        matrix, offset = [], []
        for e in self.indices:
            matrix.append(tuple(e.coeffs.get(n, 0) for n in names))
            offset.append(e.const)
        return Access(self.buffer, tuple(matrix), tuple(offset))


# --------------------------------------------------------------------------- #
# Interpreter — the semantic oracle
# --------------------------------------------------------------------------- #


def _np_dtype(dtype: str):
    return {"f32": np.float32, "f64": np.float64, "bf16": np.float32,
            "i32": np.int32}.get(dtype, np.float32)


def interpret(prog: Program, inputs: Mapping[str, np.ndarray],
              accumulate_f64: bool = True,
              cast_outputs: bool = True) -> dict[str, np.ndarray]:
    """Execute ``prog`` per ISAMIR analysis semantics: each statement runs to
    completion over the full iteration domain before the next begins.

    Buffers not present in ``inputs`` are zero-initialised.  Returns the final
    contents of ``prog.outputs``, cast to each buffer's dtype unless
    ``cast_outputs`` is false (the executor replays needle programs *inside*
    a larger f64 computation and must not round intermediate accumulators —
    only the whole program's final outputs are cast, like the oracle).
    """
    for a in prog.axes:
        if a.symbolic:
            raise IRError(f"cannot interpret program with symbolic axis {a.name}")

    # Materialize buffers (work in f64 to keep the oracle exact-ish).
    bufs: dict[str, np.ndarray] = {}
    for b in prog.buffers:
        if b.name in inputs:
            arr = np.asarray(inputs[b.name], dtype=np.float64)
            if arr.shape != b.shape:
                raise IRError(f"input {b.name} shape {arr.shape} != {b.shape}")
            bufs[b.name] = arr.copy()
        else:
            bufs[b.name] = np.zeros(b.shape, dtype=np.float64)

    # Per the paper, statements range over *loop domains*: a statement's
    # domain is the set of axes its accesses actually use (iterating unused
    # axes would double-count `+=` contributions).
    def stmt_grids(s: Statement) -> np.ndarray:
        used = [a for ai, a in enumerate(prog.axes)
                if any(row[ai] for acc in (s.lhs, s.rhs) for row in acc.matrix)]
        sizes = tuple(a.size for a in used) or (1,)
        cols = [prog.axis_index(a.name) for a in used]
        sub = np.indices(sizes).reshape(len(sizes), -1)
        full = np.zeros((len(prog.axes), sub.shape[1]), dtype=np.int64)
        for r, c in enumerate(cols):
            full[c] = sub[r]
        return full

    def gather_indices(acc: Access, grids: np.ndarray) -> tuple[np.ndarray, ...]:
        mat = acc.np_matrix()  # (rank, n_axes)
        off = np.array(acc.offset, dtype=np.int64)[:, None]
        idx = mat @ grids + off  # (rank, n_points)
        return tuple(idx)

    for s in prog.statements:
        grids = stmt_grids(s)
        li = gather_indices(s.lhs, grids)
        ri = gather_indices(s.rhs, grids)
        rvals = bufs[s.rhs.buffer][ri]
        out = bufs[s.lhs.buffer]
        if s.op == ":=":
            out[li] = rvals
        elif s.op == "apply":
            out[li] = UNARY_FNS[s.fn](rvals)
        elif s.op == "+=":
            np.add.at(out, li, rvals)
        elif s.op == "-=":
            np.subtract.at(out, li, rvals)
        elif s.op == "*=":
            np.multiply.at(out, li, rvals)
        elif s.op == "max=":
            np.maximum.at(out, li, rvals)
        else:  # pragma: no cover
            raise IRError(f"unhandled op {s.op}")

    if not cast_outputs:
        return {name: bufs[name] for name in prog.outputs}
    return {name: bufs[name].astype(_np_dtype(prog.buffer(name).dtype))
            for name in prog.outputs}


def random_inputs(prog: Program, rng: np.random.Generator,
                  lo: float = -1.0, hi: float = 1.0) -> dict[str, np.ndarray]:
    """Random inputs for every non-temp buffer that is read before written."""
    written: set[str] = set()
    needed: set[str] = set()
    for s in prog.statements:
        for r in prog.reads(s):
            if r not in written and not prog.buffer(r).temp:
                needed.add(r)
        written.add(s.lhs.buffer)
    return {n: rng.uniform(lo, hi, size=prog.buffer(n).shape).astype(np.float64)
            for n in sorted(needed)}
