"""Static "dry-run" scheduler (paper Section 3).

Given an instruction Selection (isel.py), a SystemGraph (sysgraph.py) and an
Approach (approach.py), the scheduler performs a simulated execution of the
program, recording the instruction stream each device must execute:

  1. **Unrolling** (3.3)     — each selected instruction is tiled over its
     outer axes and over hardware tile shapes on the mapped axes, producing
     *compute tiles*; the Approach orders them (dependency order).
  2. **Device allocation** (3.4) — each tile is assigned to a compute node.
  3. **Memory movement** (3.5)  — buffer regions are tracked as versioned
     copies across memory nodes; reads route from the best existing copy via
     the movement graph (intermediate copies become cached copies); writes
     perform virtual *cache invalidation* of stale copies; capacity overflow
     triggers LRU eviction with dirty write-back.

The emitted ``Schedule`` carries COPY / COMPUTE ops with full region info.
``cost_model()`` replays the stream on per-resource timelines (DMA engines
overlap with compute) to produce modeled seconds/cycles — the "profile" used
by the benchmarks and by CostModelApproach.
"""
from __future__ import annotations

import copy
import math
from dataclasses import dataclass, field


from .approach import Approach, GreedyApproach
from .dtypes import DTYPE_BYTES  # noqa: F401  (re-exported; one shared table)
from .ir import Program
from .isel import SelectedInstr, Selection
from .sysgraph import ComputeNode, SystemGraph

# --------------------------------------------------------------------------- #
# Regions and tiles
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Region:
    """A rectangular region of a buffer: (start, size) per dimension."""

    buffer: str
    bounds: tuple[tuple[int, int], ...]

    def nbytes(self, dtype: str = "f32") -> int:
        n = 1
        for _, s in self.bounds:
            n *= s
        return n * DTYPE_BYTES.get(dtype, 4)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(s for _, s in self.bounds)


@dataclass
class ComputeTile:
    """One instruction invocation: a tile of a SelectedInstr's iteration
    space.  ``offsets``/``sizes`` cover every haystack axis in the
    instruction's window domain; operands are (needle buffer, region,
    reads, writes) in needle-buffer order."""

    instr_idx: int
    needle_name: str
    offsets: dict[str, int]
    sizes: dict[str, int]
    operands: list[tuple[str, Region, bool, bool]]  # (needle buf, region, r, w)
    device: str = ""

    def output_region(self) -> Region | None:
        for _, reg, _, w in self.operands:
            if w:
                return reg
        return None

    def out_key(self):
        r = self.output_region()
        return (r.buffer, r.bounds) if r else ("", ())

    def red_key(self):
        """Offsets on non-output axes (reduction/outer) — orders k-innermost."""
        return tuple(sorted(self.offsets.items()))

    def flops(self) -> float:
        if self.needle_name.startswith(("mxu.", "fused.")):
            n = 1
            for s in self.sizes.values():
                n *= s
            return 2.0 * n
        n = 1
        for s in self.sizes.values():
            n *= s
        return float(n)


@dataclass
class ScheduledOp:
    uid: int
    kind: str                      # 'copy' | 'compute' | 'writeback'
    device: str                    # issuing compute node (or 'host')
    # copy / writeback:
    src: str = ""
    dst: str = ""
    region: Region | None = None
    # compute:
    tile: ComputeTile | None = None
    # filled by cost model:
    start: float = 0.0
    end: float = 0.0

    def describe(self) -> str:  # pragma: no cover - debugging aid
        if self.kind == "compute":
            return (f"[{self.device}] {self.tile.needle_name} "
                    f"@{self.tile.offsets} x{self.tile.sizes}")
        return (f"[{self.device}] {self.kind} {self.region.buffer}"
                f"{self.region.bounds} {self.src}->{self.dst}")


@dataclass
class Schedule:
    program: Program
    graph: SystemGraph
    ops: list[ScheduledOp]
    final_residency: dict          # (buffer, bounds) -> {node: version}
    homes: dict[str, str]
    makespan: float = 0.0
    device_busy: dict[str, float] = field(default_factory=dict)

    def counts(self) -> dict[str, int]:
        c: dict[str, int] = {}
        for op in self.ops:
            c[op.kind] = c.get(op.kind, 0) + 1
        return c

    def region_nbytes(self, region: Region) -> int:
        """Byte size of a region under its buffer's declared dtype (regions
        themselves are dtype-blind element ranges)."""
        try:
            dtype = self.program.buffer(region.buffer).dtype
        except KeyError:
            dtype = "f32"
        return region.nbytes(dtype)

    def bytes_moved(self) -> int:
        return sum(self.region_nbytes(op.region) for op in self.ops
                   if op.kind in ("copy", "writeback"))


# --------------------------------------------------------------------------- #
# Scheduler state: versioned region copies across memory nodes
# --------------------------------------------------------------------------- #


def _bounds_overlap(b1: tuple, b2: tuple) -> bool:
    if len(b1) != len(b2):
        return False
    for (s1, n1), (s2, n2) in zip(b1, b2):
        if s1 >= s2 + n2 or s2 >= s1 + n1:
            return False
    return True


class SchedulerState:
    """The 'critical objects which interact during the scheduling process by
    retaining the system state' (paper 3.2).

    Buffer contents are tracked as *versioned region copies* across memory
    nodes.  Because different instructions may tile the same buffer at
    different granularities, overlapping region keys are kept coherent by a
    reconcile-to-home protocol: before a read (or an overlapping write), any
    intersecting dirty region is written back to the buffer's home memory,
    which then serves as the authoritative merge point.  Writes perform the
    paper's virtual cache invalidation on every stale copy.
    """

    def __init__(self, graph: SystemGraph, homes: dict[str, str],
                 dtypes: dict[str, str] | None = None):
        self.graph = graph
        self.homes = homes                      # buffer -> home memory node
        self.dtypes = dict(dtypes or {})        # buffer -> dtype
        self.version: dict[tuple, int] = {}     # region key -> latest version
        # region key -> {memory node -> version held}
        self.copies: dict[tuple, dict[str, int]] = {}
        self.used: dict[str, int] = {m: 0 for m in graph.memories}
        self.lru: dict[tuple[str, tuple], int] = {}   # (node, region key)
        self.clock = 0
        self.device_load: dict[str, float] = {}

    def clone(self) -> "SchedulerState":
        """Cheap structural copy for segment snapshots: the immutable
        context (graph, homes, dtypes) is shared, every mutable table is
        copied one level deep (``copies`` two levels: its values are
        per-node version dicts).  ``copy.deepcopy`` would also clone the
        SystemGraph — ~1000x the work for the incremental scheduler's
        per-instruction snapshots."""
        s = SchedulerState.__new__(SchedulerState)
        s.graph = self.graph
        s.homes = self.homes
        s.dtypes = self.dtypes
        s.version = dict(self.version)
        s.copies = {k: dict(v) for k, v in self.copies.items()}
        s.used = dict(self.used)
        s.lru = dict(self.lru)
        s.clock = self.clock
        s.device_load = dict(self.device_load)
        # round_robin's per-run cursor lives on the state (approach.py), so
        # a resumed suffix continues the rotation exactly where the parent
        # run stood at the snapshot.
        s._rr_cursor = getattr(self, "_rr_cursor", 0)
        return s

    # -- region bookkeeping ---------------------------------------------------
    @staticmethod
    def key(region: Region) -> tuple:
        return (region.buffer, region.bounds)

    def nbytes(self, region: Region) -> int:
        """Region size under the owning buffer's dtype (f32 when unknown)."""
        return region.nbytes(self.dtypes.get(region.buffer, "f32"))

    def holders(self, region: Region) -> dict[str, int]:
        """Memory nodes holding the LATEST version of this region.  The home
        node implicitly holds version 0 of everything."""
        k = self.key(region)
        v = self.version.get(k, 0)
        held = {n: ver for n, ver in self.copies.get(k, {}).items() if ver == v}
        if v == 0:
            held.setdefault(self.homes[region.buffer], 0)
        return held

    def holds_region(self, node: str, region: Region | None) -> bool:
        if region is None:
            return False
        return node in self.holders(region)

    def touch(self, node: str, region: Region):
        self.clock += 1
        self.lru[(node, self.key(region))] = self.clock

    def _add_copy(self, node: str, region: Region, version: int):
        k = self.key(region)
        holders = self.copies.setdefault(k, {})
        if node not in holders:
            self.used[node] = self.used.get(node, 0) + self.nbytes(region)
        holders[node] = version
        self.touch(node, region)

    def install(self, node: str, region: Region, dirty: bool = False):
        k = self.key(region)
        if dirty:
            v = self.version.get(k, 0) + 1      # cache invalidation
            self.version[k] = v
            for stale in list(self.copies.get(k, {})):
                if stale != node:
                    self.drop(stale, k)
            self._add_copy(node, region, v)
        else:
            self._add_copy(node, region, self.version.get(k, 0))

    def drop(self, node: str, region_key: tuple):
        holders = self.copies.get(region_key, {})
        if node in holders:
            holders.pop(node)
            self.used[node] -= self.nbytes(Region(*region_key))
        self.lru.pop((node, region_key), None)

    def overlapping_dirty(self, region: Region,
                          include_exact: bool = False) -> list[tuple]:
        """Keys of regions intersecting ``region`` with uncommitted writes
        (version > 0 not present at home)."""
        k = self.key(region)
        home = self.homes[region.buffer]
        out = []
        for k2, holders in self.copies.items():
            if k2[0] != region.buffer or (k2 == k and not include_exact):
                continue
            v2 = self.version.get(k2, 0)
            if v2 == 0 or holders.get(home) == v2:
                continue
            if _bounds_overlap(k2[1], region.bounds):
                out.append(k2)
        return out


# --------------------------------------------------------------------------- #
# The scheduler
# --------------------------------------------------------------------------- #


class ScheduleError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, selection: Selection, graph: SystemGraph,
                 approach: Approach | None = None,
                 state: SchedulerState | None = None):
        self.sel = selection
        self.prog = selection.program
        self.graph = graph
        self.approach = approach or GreedyApproach()
        if selection.uncovered:
            raise ScheduleError(
                f"selection leaves statements uncovered: {selection.uncovered}")
        self.homes = state.homes if state else {
            b.name: self.approach.choose_home(
                b.name, self._buffer_bytes(b.name), graph)
            for b in self.prog.buffers if not b.temp or self._materialized(b.name)}
        self.state = state or SchedulerState(
            graph, self.homes, dtypes={b.name: b.dtype
                                       for b in self.prog.buffers})
        self.ops: list[ScheduledOp] = []
        self._uid = 0
        # instr idx -> (op count, state snapshot) taken right after the last
        # tile of that instruction retired; filled by
        # run_body(record_segments=True) and consumed by schedule_incremental.
        self.segments: dict[int, tuple[int, SchedulerState]] = {}

    # -- helpers ------------------------------------------------------------
    def _buffer_bytes(self, name: str) -> int:
        b = self.prog.buffer(name)
        n = 1
        for s in b.shape:
            n *= s
        return n * DTYPE_BYTES.get(b.dtype, 4)

    def _materialized(self, name: str) -> bool:
        """Temps that survive instruction selection (inter-instruction temps
        like the factored U buffer) are materialized; needle-internal chain
        temps are not."""
        b = self.prog.buffer(name)
        if not b.temp:
            return True
        for si in self.sel.instrs:
            bm = dict(si.mapping.buffer_map)
            # buffer appears as a *non-temp* needle operand -> materialized
            for nb in si.needle.buffers:
                if bm.get(nb.name) == name and not nb.temp:
                    return True
        return False

    def _emit(self, **kw) -> ScheduledOp:
        op = ScheduledOp(uid=self._uid, **kw)
        self._uid += 1
        self.ops.append(op)
        return op

    # -- tiling (Section 3.3) --------------------------------------------------
    def _needle_axis_roles(self, si: SelectedInstr) -> dict[str, str]:
        """needle axis name -> haystack axis name."""
        return {na: ha for na, ha in si.mapping.axis_map}

    def _tiles_for(self, idx: int, si: SelectedInstr,
                   device_tile: tuple[int, int, int]) -> list[ComputeTile]:
        m = si.mapping
        axis_map = dict(m.axis_map)           # needle axis -> haystack axis
        mapped_h = {h: n for n, h in axis_map.items()}

        # Extents of the window domain axes.
        window_axes: list[str] = []
        for hi in m.stmt_map:
            s = self.prog.statements[hi]
            for acc in (s.lhs, s.rhs):
                for a in acc.axes_used(self.prog.axis_names):
                    if a not in window_axes:
                        window_axes.append(a)

        devices = self.graph.compute_nodes_for(si.needle.name)
        tile_req = self.approach.choose_tile_shape(
            si.needle.name,
            {na: self.prog.axis(ha).size for na, ha in axis_map.items()},
            device_tile,
            vmem_budget=self.graph.staging_budget(devices) if devices
            else None)

        # Per-axis tile size: mapped axes tile by hardware shape, outer axes
        # advance one point per call — except for pure elementwise
        # instructions, where foldable outer axes coalesce into one call
        # (one long vector op instead of thousands of tiny ones).
        foldable = self._foldable_outer(si, window_axes, mapped_h)
        tile_sz: dict[str, int] = {}
        for a in window_axes:
            if a in mapped_h:
                tile_sz[a] = max(1, min(tile_req.get(mapped_h[a], 1 << 30),
                                        self.prog.axis(a).size))
            elif a in foldable:
                tile_sz[a] = self.prog.axis(a).size
            else:
                tile_sz[a] = 1

        # Cartesian tiling of the window domain.
        axes = window_axes
        counts = [math.ceil(self.prog.axis(a).size / tile_sz[a]) for a in axes]
        tiles: list[ComputeTile] = []
        total = 1
        for c in counts:
            total *= c
        for flat in range(total):
            rem, offs, szs = flat, {}, {}
            for a, c in zip(axes, counts):
                pos = rem % c
                rem //= c
                offs[a] = pos * tile_sz[a]
                szs[a] = min(tile_sz[a], self.prog.axis(a).size - offs[a])
            tiles.append(ComputeTile(
                instr_idx=idx, needle_name=si.needle.name,
                offsets=offs, sizes=szs,
                operands=self._tile_operands(si, offs, szs)))
        return tiles

    def _foldable_outer(self, si: SelectedInstr, window_axes,
                        mapped_h) -> set[str]:
        """Outer axes that every window access indexes through a dedicated
        coeff-1 dimension — safe to coalesce for elementwise instructions."""
        from .instructions import is_elementwise
        if not is_elementwise(si.needle.name):
            return set()
        folds = set()
        for a in window_axes:
            if a in mapped_h:
                continue
            ai = self.prog.axis_index(a)
            ok = True
            for hi in si.mapping.stmt_map:
                st = self.prog.statements[hi]
                for acc in (st.lhs, st.rhs):
                    rows = [i for i, row in enumerate(acc.matrix) if row[ai]]
                    if len(rows) != 1:
                        ok = False
                        break
                    row = acc.matrix[rows[0]]
                    if row[ai] != 1 or any(c for j, c in enumerate(row)
                                           if j != ai):
                        ok = False
                        break
                if not ok:
                    break
            if ok:
                folds.add(a)
        return folds

    def _tile_operands(self, si: SelectedInstr, offs: dict[str, int],
                       szs: dict[str, int]) -> list:
        """Regions of each materialized needle operand for one tile."""
        m = si.mapping
        bm = dict(m.buffer_map)
        operands = []
        reads: set[str] = set()
        writes: set[str] = set()
        for ns in si.needle.statements:
            if ns.op in (":=", "apply"):
                reads.add(ns.rhs.buffer)
            else:
                reads.add(ns.rhs.buffer)
                reads.add(ns.lhs.buffer)
            writes.add(ns.lhs.buffer)
        for nb in si.needle.buffers:
            if nb.temp or nb.name not in bm:
                continue
            hb = bm[nb.name]
            region = self._operand_region(si, nb.name, hb, offs, szs)
            operands.append((nb.name, region,
                             nb.name in reads, nb.name in writes))
        return operands

    def _operand_region(self, si: SelectedInstr, nb: str, hb: str,
                        offs: dict[str, int], szs: dict[str, int]) -> Region:
        # find a representative haystack access of hb inside the window
        acc = None
        for hi in si.mapping.stmt_map:
            s = self.prog.statements[hi]
            for cand in (s.lhs, s.rhs):
                if cand.buffer == hb:
                    acc = cand
                    break
            if acc:
                break
        assert acc is not None, (nb, hb)
        names = self.prog.axis_names
        bounds = []
        for row, const in zip(acc.matrix, acc.offset):
            start, span = const, 1
            for ai, coeff in enumerate(row):
                if coeff == 0:
                    continue
                a = names[ai]
                o = offs.get(a, 0)
                s_ = szs.get(a, self.prog.axis(a).size if a in offs else 1)
                if a not in offs:      # axis outside this window: full extent
                    o, s_ = 0, self.prog.axis(a).size
                if coeff > 0:
                    start += coeff * o
                    span += coeff * (s_ - 1)
                else:
                    start += coeff * (o + s_ - 1)
                    span += -coeff * (s_ - 1)
            bounds.append((start, span))
        return Region(hb, tuple(bounds))

    # -- memory movement (Section 3.5) ------------------------------------------
    def _reconcile(self, region: Region):
        """Flush intersecting dirty regions of other granularities back to the
        buffer's home so it is authoritative for this region's bytes."""
        others = self.state.overlapping_dirty(region)
        if not others:
            return
        home = self.homes[region.buffer]
        flush = others + self.state.overlapping_dirty(region, include_exact=True)
        seen = set()
        for k2 in flush:
            if k2 in seen:
                continue
            seen.add(k2)
            r2 = Region(*k2)
            v2 = self.state.version.get(k2, 0)
            src = next((n for n, v in self.state.copies.get(k2, {}).items()
                        if v == v2), None)
            if src is None or src == home:
                continue
            for e in self.graph.shortest_path(src, home,
                                              self.state.nbytes(r2)):
                self._emit(kind="writeback", device=e.issuer, src=e.src,
                           dst=e.dst, region=r2)
            self.state.install(home, r2, dirty=False)
            # ensure home registers the *latest* version, not version 0
            self.state.copies[k2][home] = v2

    def _invalidate_overlaps(self, region: Region):
        """After a write, stale copies of intersecting region keys may only
        survive at home (which _reconcile keeps authoritative)."""
        k = self.state.key(region)
        home = self.homes[region.buffer]
        for k2 in list(self.state.copies):
            if k2 == k or k2[0] != region.buffer:
                continue
            if not _bounds_overlap(k2[1], region.bounds):
                continue
            for node in list(self.state.copies[k2]):
                if node != home:
                    self.state.drop(node, k2)

    def _route_region(self, region: Region, dst: str, device: str,
                      pinned: frozenset = frozenset()):
        """Ensure the latest version of ``region`` resides in memory ``dst``,
        emitting COPY ops along an Approach-chosen path.  Intermediate copies
        are installed too — they act as caches for later reuse."""
        self._reconcile(region)
        holders = self.state.holders(region)
        if dst in holders:
            self.state.touch(dst, region)
            return
        nbytes = self.state.nbytes(region)
        options = []
        for node in holders:
            try:
                path = self.approach.choose_path(self.graph, node, dst, nbytes)
            except KeyError:
                continue
            cost = sum(e.latency + nbytes / e.bandwidth for e in path)
            options.append((node, cost, path))
        if not options:
            raise ScheduleError(f"no path to move {region} to {dst}")
        src = self.approach.choose_source([(n, c) for n, c, _ in options])
        path = next(p for n, _, p in options if n == src)
        for e in path:
            self._make_room(e.dst, nbytes,
                            pinned | {self.state.key(region)})
            self._emit(kind="copy", device=e.issuer, src=e.src, dst=e.dst,
                       region=region)
            self.state.install(e.dst, region, dirty=False)

    def _make_room(self, node: str, nbytes: int, pinned: frozenset | set):
        cap = self.graph.memories[node].capacity
        if self.state.used.get(node, 0) + nbytes <= cap:
            return
        # LRU eviction; dirty copies are written back to their home first.
        lru_items = sorted(
            ((n, k) for (n, k) in self.state.lru if n == node and k not in pinned),
            key=lambda nk: self.state.lru[nk])
        for n, k in lru_items:
            if self.state.used[node] + nbytes <= cap:
                return
            buf, bnds = k
            region = Region(buf, bnds)
            ver = self.state.copies.get(k, {}).get(node)
            latest = self.state.version.get(k, 0)
            home = self.homes[buf]
            if ver == latest and latest > 0 and node != home \
                    and self.state.copies.get(k, {}).get(home) != latest:
                # dirty sole-latest copy: write back along the path home
                for e in self.graph.shortest_path(node, home,
                                                  self.state.nbytes(region)):
                    self._emit(kind="writeback", device=e.issuer, src=e.src,
                               dst=e.dst, region=region)
                self.state.install(home, region, dirty=False)
                self.state.copies[k][home] = latest
            self.state.drop(node, k)
        if self.state.used[node] + nbytes > cap:
            raise ScheduleError(
                f"memory node {node} cannot fit {nbytes} bytes "
                f"(capacity {cap}, used {self.state.used[node]})")

    # -- main entry -----------------------------------------------------------
    def run(self) -> Schedule:
        return self.run_body(writeback=True)

    def run_body(self, writeback: bool = True, first_instr: int = 0,
                 record_segments: bool = False) -> Schedule:
        """Schedule instructions ``first_instr..`` on top of the current
        state/ops (both empty for a fresh run; pre-seeded with a parent's
        prefix for an incremental resume).  Skipping a prefix is sound
        because both unroll policies sort by ``instr_idx`` first, so the
        tile stream of a suffix equals the suffix of the full tile stream.

        With ``record_segments`` the scheduler snapshots ``(op count,
        state)`` after the last tile of every instruction (except the final
        one), keyed by instr idx — the resume points ``schedule_incremental``
        splices from."""
        all_tiles: list[ComputeTile] = []
        for idx, si in enumerate(self.sel.instrs):
            devices = self.graph.compute_nodes_for(si.needle.name)
            if not devices:
                raise ScheduleError(f"no device executes {si.needle.name}")
            hw_tile = devices[0].matmul_tile
            if idx < first_instr:
                continue
            all_tiles.extend(self._tiles_for(idx, si, hw_tile))

        tiles = self.approach.unroll_order(all_tiles)

        prev_idx: int | None = None
        for tile in tiles:
            if record_segments and prev_idx is not None \
                    and tile.instr_idx != prev_idx:
                self.segments[prev_idx] = (len(self.ops), self.state.clone())
            prev_idx = tile.instr_idx
            devices = self.graph.compute_nodes_for(tile.needle_name)
            dev = self.approach.choose_device(tile, devices, self.state)
            tile.device = dev.name
            mem = dev.memory
            pinned = frozenset(self.state.key(region)
                               for _, region, _, _ in tile.operands)
            for nb, region, r, w in tile.operands:
                if r:
                    self._route_region(region, mem, dev.name, pinned)
                else:
                    self._reconcile(region)  # overlapping dirty data -> home
                    self._make_room(mem, self.state.nbytes(region), pinned)
                    self.state.install(mem, region, dirty=False)
            self._emit(kind="compute", device=dev.name, tile=tile)
            self.state.device_load[dev.name] = (
                self.state.device_load.get(dev.name, 0.0)
                + self._compute_time(dev, tile))
            for nb, region, r, w in tile.operands:
                if w:
                    self.state.install(mem, region, dirty=True)  # invalidates
                    self._invalidate_overlaps(region)

        if writeback:
            self._writeback_outputs()
        sched = Schedule(self.prog, self.graph, self.ops,
                         final_residency={k: dict(v) for k, v in
                                          self.state.copies.items()},
                         homes=dict(self.homes))
        cost_model(sched)
        return sched

    def _writeback_outputs(self):
        """Move final output regions back to their home memories."""
        for k, holders in list(self.state.copies.items()):
            buf, bnds = k
            if buf not in self.prog.outputs:
                continue
            region = Region(buf, bnds)
            latest = self.state.version.get(k, 0)
            home = self.homes[buf]
            if latest == 0:
                continue
            if self.state.copies.get(k, {}).get(home) == latest:
                continue
            src = next(n for n, v in holders.items() if v == latest)
            for e in self.graph.shortest_path(src, home,
                                              self.state.nbytes(region)):
                self._emit(kind="writeback", device=e.issuer, src=e.src,
                           dst=e.dst, region=region)
            self.state.install(home, region, dirty=False)

    # -- cost model -------------------------------------------------------------
    def _compute_time(self, dev: ComputeNode, tile: ComputeTile) -> float:
        return compute_time(dev, tile)


def compute_time(dev: ComputeNode, tile: ComputeTile) -> float:
    """Modeled execution time of one tile on one device.

    Matmul tiles are charged in whole MXU passes (a 1x128x128 call costs a
    full 128^3 pass) — this is what makes library-unfriendly skinny GEMMs
    expensive and reproduces the paper's Figure 3(d) effect.
    """
    name = tile.needle_name
    if name.startswith(("mxu.matmul", "fused.matmul")):
        ti, tj, tk = dev.matmul_tile
        out = tile.output_region()
        vol = 1
        for s in tile.sizes.values():
            vol *= s
        out_vol = 1
        for s in (out.shape if out else ()):
            out_vol *= s
        k_vol = max(1, vol // max(out_vol, 1))
        passes = (math.ceil(out_vol / (ti * tj)) * math.ceil(k_vol / tk))
        t = passes * (ti * tj * tk * 2) / dev.flops_per_sec
        if name.startswith("fused."):
            t += out_vol / (dev.vector_lanes * dev.clock_hz) * 2
        return t
    # VPU-style ops: elements / lanes
    vol = 1
    for s in tile.sizes.values():
        vol *= s
    return vol / (dev.vector_lanes * dev.clock_hz)


def cost_model(sched: Schedule) -> float:
    """Replay the op stream on per-resource timelines.  DMA engines (one per
    edge) run asynchronously from compute nodes, so copies for tile t+1
    overlap with tile t's compute when dependencies allow."""
    g = sched.graph
    resource_free: dict[str, float] = {}
    region_avail: dict[tuple[tuple, str], float] = {}  # (region key, node) -> t

    def avail(region: Region, node: str) -> float:
        return region_avail.get(((region.buffer, region.bounds), node), 0.0)

    for op in sched.ops:
        if op.kind in ("copy", "writeback"):
            e = g.edge(op.src, op.dst)
            res = f"dma:{op.src}->{op.dst}"
            ready = avail(op.region, op.src)
            start = max(resource_free.get(res, 0.0), ready)
            dur = e.latency + sched.region_nbytes(op.region) / e.bandwidth
            end = start + dur
            resource_free[res] = end
            key = ((op.region.buffer, op.region.bounds), op.dst)
            region_avail[key] = end
        else:
            dev = g.computes[op.device]
            mem = dev.memory
            ready = 0.0
            for _, region, r, _ in op.tile.operands:
                if r:
                    ready = max(ready, avail(region, mem))
            start = max(resource_free.get(op.device, 0.0), ready)
            end = start + compute_time(dev, op.tile)
            resource_free[op.device] = end
            for _, region, _, w in op.tile.operands:
                if w:
                    region_avail[((region.buffer, region.bounds), mem)] = end
        op.start, op.end = start, end

    sched.makespan = max((op.end for op in sched.ops), default=0.0)
    sched.device_busy = {
        d: sum(op.end - op.start for op in sched.ops
               if op.kind == "compute" and op.device == d)
        for d in g.computes}
    return sched.makespan


def schedule(selection: Selection, graph: SystemGraph,
             approach: Approach | None = None,
             state: SchedulerState | None = None) -> Schedule:
    """Convenience entry point."""
    from .approach import CostModelApproach
    if isinstance(approach, CostModelApproach):
        best = None
        for cand in approach.candidates():
            s = Scheduler(selection, graph, cand,
                          state=None if state is None else _clone_state(state)).run()
            if best is None or s.makespan < best.makespan:
                best = s
        return best
    return Scheduler(selection, graph, approach, state=state).run()


def _clone_state(state: SchedulerState) -> SchedulerState:
    return copy.deepcopy(state)


# --------------------------------------------------------------------------- #
# Incremental re-scheduling (local-walk neighbors)
# --------------------------------------------------------------------------- #


def schedule_with_segments(
        selection: Selection, graph: SystemGraph,
        approach: Approach) -> tuple[Schedule, dict]:
    """Full schedule plus per-instruction resume points.  The returned
    ``segments`` map (instr idx -> (op count, state snapshot)) is the anchor
    a later :func:`schedule_incremental` call resumes from."""
    sch = Scheduler(selection, graph, approach)
    sched = sch.run_body(writeback=True, record_segments=True)
    return sched, sch.segments


def schedule_incremental(
        selection: Selection, graph: SystemGraph, approach: Approach,
        parent_sched: Schedule, segments: dict,
        first_changed: int, record: bool = False) -> tuple[Schedule, dict]:
    """Re-schedule reusing the parent's op stream for every instruction
    before ``first_changed`` (the first SelectedInstr whose resolved tile
    differs from the parent's).  Sound because tile streams are instr-major
    (suffix-sort equality), the snapshot carries the full versioned-copy
    state plus the round_robin cursor, and the cost model's replay is
    prefix-causal — so the spliced prefix replays to identical times and the
    suffix is scheduled exactly as a from-scratch run would schedule it.

    Falls back to a from-scratch :func:`schedule_with_segments` when no
    snapshot precedes ``first_changed`` (e.g. the first instruction
    changed)."""
    if first_changed <= 0 or (first_changed - 1) not in segments:
        return schedule_with_segments(selection, graph, approach)
    boundary, snap = segments[first_changed - 1]
    sch = Scheduler(selection, graph, approach, state=snap.clone())
    # Prefix ops are shallow-copied: cost_model mutates op.start/end, and the
    # parent schedule must keep its own timings.
    sch.ops = [copy.copy(op) for op in parent_sched.ops[:boundary]]
    sch._uid = boundary
    sched = sch.run_body(writeback=True, first_instr=first_changed,
                         record_segments=record)
    # The parent's prefix snapshots remain valid resume points for the
    # child (the spliced prefix is identical by construction).
    for idx, ent in segments.items():
        if idx < first_changed:
            sch.segments.setdefault(idx, ent)
    return sched, sch.segments
