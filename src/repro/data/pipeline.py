"""Deterministic, step-keyed data pipeline.

Batches are pure functions of (seed, step) — after a restart the pipeline
resumes mid-stream with no replay drift and no state to checkpoint.  Sources:
``SyntheticLM`` (structured pseudo-text: mixture of Zipfian unigrams and
repeated n-grams so models have something learnable) and ``TokenFileSource``
(memory-mapped pre-tokenized corpus).
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    source: str = "synthetic"       # synthetic | file
    path: str = ""


def _step_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    h = hashlib.sha256(f"{cfg.seed}:{step}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


class SyntheticLM:
    """Zipf unigrams + planted n-gram motifs (learnable structure)."""

    def __init__(self, cfg: DataConfig, vocab_size: int):
        self.cfg = cfg
        self.vocab = vocab_size
        base = np.random.default_rng(cfg.seed)
        n_motifs = 64
        self.motifs = base.integers(0, vocab_size,
                                    size=(n_motifs, 8)).astype(np.int32)

    def batch(self, step: int) -> dict:
        rng = _step_rng(self.cfg, step)
        B, T = self.cfg.global_batch, self.cfg.seq_len
        # Zipfian unigram background
        ranks = rng.zipf(1.3, size=(B, T)).astype(np.int64)
        tokens = (ranks % self.vocab).astype(np.int32)
        # plant motifs: ~25% of positions covered by repeated 8-grams
        n_plants = max(1, (B * T) // 32)
        rows = rng.integers(0, B, n_plants)
        cols = rng.integers(0, max(T - 8, 1), n_plants)
        which = rng.integers(0, len(self.motifs), n_plants)
        for r, c, w in zip(rows, cols, which):
            tokens[r, c:c + 8] = self.motifs[w]
        return {"tokens": tokens}


class TokenFileSource:
    """Memory-mapped int32 token file; step-keyed random windows."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> dict:
        rng = _step_rng(self.cfg, step)
        B, T = self.cfg.global_batch, self.cfg.seq_len
        starts = rng.integers(0, len(self.data) - T - 1, size=B)
        toks = np.stack([self.data[s:s + T] for s in starts])
        return {"tokens": toks.astype(np.int32)}


def make_source(cfg: DataConfig, model_cfg: ModelConfig):
    if cfg.source == "file":
        return TokenFileSource(cfg)
    return SyntheticLM(cfg, model_cfg.vocab_size)


def host_local_batch(batch: dict, mesh, shardings) -> dict:
    """Device-put a host batch with the training shardings applied."""
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}


def add_frontend_stub(batch: dict, model_cfg: ModelConfig, step: int,
                      seed: int = 0) -> dict:
    """VLM / audio archs: attach deterministic precomputed embeddings."""
    if model_cfg.family not in ("vlm", "audio"):
        return batch
    B = batch["tokens"].shape[0]
    rng = np.random.default_rng(seed * 7919 + step)
    emb = rng.standard_normal(
        (B, model_cfg.frontend_tokens, model_cfg.d_model)).astype(np.float32)
    key = "patch_embeds" if model_cfg.family == "vlm" else "audio_embeds"
    out = dict(batch)
    out[key] = (emb * 0.02).astype(np.dtype(model_cfg.dtype))
    return out
