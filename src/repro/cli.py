"""The ``repro`` console script — one entry point for every CLI in the repo.

    repro tune --suite gemm --trials 32        # repro.search.tune
    repro model train --suite gemm,conv ...    # repro.search.model
    repro compile --suite smoke --validate     # repro.compile
    repro graph --validate --cache arts.json   # repro.graph (CompiledGraph)
    repro fabric --shape 5124x700x2048 ...     # repro.fabric.simulate
    repro dryrun --all --mesh both             # repro.launch.dryrun
    repro train / repro serve                  # repro.launch.{train,serve}
    repro servesim --compare --requests 64     # repro.serve (batching sim)
    repro bench --only tuned --json out.json   # benchmarks.run (repo checkout)

Installed via ``[project.scripts]``, so a ``pip install -e .`` is enough —
no ``PYTHONPATH=src`` stanzas; the CI workflows rely on this.  Each
subcommand defers to the module's own ``main``/argparse, so ``repro tune
--help`` shows exactly what ``python -m repro.search.tune --help`` does.
"""
from __future__ import annotations

import sys

#: subcommand -> (module, description).  Modules import lazily: several pull
#: in jax, and the dispatcher must stay instant for --help.
COMMANDS = {
    "tune": ("repro.search.tune", "joint mapping/schedule autotuner"),
    "model": ("repro.search.model", "learned cost model train/eval/export"),
    "compile": ("repro.compile.__main__", "compilation driver CLI"),
    "verify": ("repro.verify.cli", "static analyzer sweep + mutation "
                                   "harness"),
    "graph": ("repro.graph.__main__", "whole-model graph trace/fuse/"
                                      "compile"),
    "fabric": ("repro.fabric.simulate", "multi-chip fabric simulator"),
    "dryrun": ("repro.launch.dryrun", "dry-run roofline matrix"),
    "train": ("repro.launch.train", "training launch"),
    "serve": ("repro.launch.serve", "serving launch"),
    "servesim": ("repro.serve.__main__", "online continuous-batching "
                                         "serving simulator"),
    "bench": ("benchmarks.run", "benchmark harness (needs the repo "
                                "checkout on sys.path / as cwd)"),
}


def _usage(out=sys.stderr) -> None:
    print("usage: repro <command> [args...]\n\ncommands:", file=out)
    for name, (_, desc) in COMMANDS.items():
        print(f"  {name:<9} {desc}", file=out)
    print("\n'repro <command> --help' shows the command's own options.",
          file=out)


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        _usage(sys.stdout if argv else sys.stderr)
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd not in COMMANDS:
        print(f"repro: unknown command {cmd!r}", file=sys.stderr)
        _usage()
        return 2
    module_name = COMMANDS[cmd][0]
    import importlib
    if cmd == "bench":
        # Console scripts don't put the cwd on sys.path, and the benchmarks
        # package ships with the repo checkout, not the wheel.
        import os
        if os.path.isfile(os.path.join(os.getcwd(), "benchmarks",
                                       "run.py")) \
                and os.getcwd() not in sys.path:
            sys.path.insert(0, os.getcwd())
    try:
        module = importlib.import_module(module_name)
    except ImportError as e:
        print(f"repro {cmd}: cannot import {module_name} ({e})",
              file=sys.stderr)
        if cmd == "bench":
            print("the benchmarks package lives in the repo checkout, not "
                  "the installed wheel — run from the repo root",
                  file=sys.stderr)
        return 2
    run = getattr(module, "main", None)
    if run is None:                     # pragma: no cover - all have main()
        print(f"repro {cmd}: {module_name} has no main()", file=sys.stderr)
        return 2
    # Modules whose main() calls sys.exit / parses sys.argv directly get
    # the argv slice spliced in; ours all accept an argv parameter or use
    # argparse's default (sys.argv), so rewrite sys.argv for uniformity.
    sys.argv = [f"repro {cmd}"] + rest
    try:
        ret = run()
    except SystemExit as e:
        if isinstance(e.code, str):      # sys.exit("message") convention
            print(e.code, file=sys.stderr)
            return 1
        return int(e.code or 0)
    return int(ret or 0)


if __name__ == "__main__":
    raise SystemExit(main())
