"""``repro.graph`` — the whole-model tier above ``repro.compile``.

A ``KernelGraph`` (``ir.py``) is a DAG of kernel-level ISAMIR programs
connected by named tensor edges; tracers (``trace.py``) lower model
configs into one; the fusion pass (``fuse.py``) folds elementwise
epilogues into their producer GEMMs; and the graph compiler
(``compile.py``) drives every node through the existing pass pipeline —
deduped via the artifact cache — into a serializable ``CompiledGraph``
with an inter-kernel buffer placement and an event-simulated end-to-end
makespan.  ``python -m repro.graph`` (or ``repro graph``) is the CLI.
"""
from __future__ import annotations

from .compile import (CompiledGraph, Placement, compile_graph, edge_bytes,
                      plan_placement)
from .fuse import FusionDecision, fuse_epilogues
from .ir import (GRAPH_SCHEMA, GraphBuilder, GraphError, GraphNode,
                 KernelGraph, TensorSpec, interpret_graph, program_from_dict,
                 program_to_dict)
from .trace import (EXACT_F32_BOUND, assert_exactness_bound, block_inputs,
                    trace_block, trace_gru_chain)

__all__ = [
    "GRAPH_SCHEMA", "GraphBuilder", "GraphError", "GraphNode", "KernelGraph",
    "TensorSpec", "interpret_graph", "program_to_dict", "program_from_dict",
    "trace_block", "trace_gru_chain", "block_inputs",
    "assert_exactness_bound", "EXACT_F32_BOUND", "FusionDecision",
    "fuse_epilogues", "CompiledGraph", "Placement", "compile_graph",
    "plan_placement", "edge_bytes",
]
