"""Tracers: lower a model config into a ``KernelGraph`` of ISAMIR kernels.

``trace_block`` lowers one decoder block — the QKV / attention-matmul / FFN
GEMM skeleton of ``repro.models.transformer`` — into per-kernel nodes:

    x ──> q_h/k_h/v_h GEMMs ──> s_h = q_h·k_hᵀ ──> scale+relu ──> a_h = s_h·v_h
      └──────────────┐             (per head h)                     │
                     v                                              v
    y1 = x + Σ_h a_h·wo_h   ──>  g = relu(y1·w_gate), u = y1·w_up,
                                 y2 = y1 + (g + u)·w_down

Two deliberate liberties keep the **bit-exactness contract** with the
plain-jax reference (``repro.models.traceable``) machine-checkable:

  * the attention score scaling is the canonical ``1/sqrt(head_dim)`` with
    ``head_dim`` a power of four, expressed as a chain of ``halve`` ops —
    multiplication by a power of two is *exact* in binary floating point;
  * the usual transcendental nonlinearities (softmax, silu) are replaced by
    ``relu`` attention weights and an additive relu-gated FFN — every traced
    op (dot products, adds, max, powers of two) is exact over the dyadic
    values ``block_inputs`` generates, so any summation order — the ISAMIR
    interpreter's, the executor replay's, or XLA's — produces the same bits.

Norms are folded away (a norm-free block, cf. residual-scaled NFNet-style
stacks); the graph tier cares about the GEMM + epilogue dataflow, not the
pointwise statistics.

``trace_gru_chain`` is the stretch tracer: an unrolled GRU layer whose
steps all share one kernel program — the extreme artifact-dedupe case
(N nodes, 1 compile).
"""
from __future__ import annotations

import numpy as np

from ..core import kernels_ir as K
from ..core.ir import Program, ProgramBuilder
from ..models.config import ModelConfig
from .ir import GraphBuilder, GraphError, KernelGraph

#: past this magnitude an f32 node-boundary cast starts rounding integer
#: values, and the cross-backend bit-exactness argument no longer holds.
EXACT_F32_BOUND = float(1 << 24)


# --------------------------------------------------------------------------- #
# Kernel program builders (deterministically named by shape, so identical
# shapes share a fingerprint and the artifact cache dedupes them)
# --------------------------------------------------------------------------- #


def matmul_nt(m: int, n: int, k: int) -> Program:
    """C[i,j] += A[i,d] * B[j,d] — GEMM against a transposed RHS, the shape
    of attention scores q·kᵀ.  Maps onto ``mxu.matmul`` with a permuted
    buffer dim map."""
    pb = ProgramBuilder(f"matmul_nt_{m}x{n}x{k}")
    i, j, d = pb.axes(i=m, j=n, k=k)
    A = pb.buffer("A", (m, k))
    B = pb.buffer("B", (n, k))
    C = pb.buffer("C", (m, n))
    t = pb.temp("tmp", (m, n, k))
    pb.stmt(t[i, j, d], ":=", A[i, d])
    pb.stmt(t[i, j, d], "*=", B[j, d])
    pb.stmt(C[i, j], "+=", t[i, j, d])
    pb.output("C")
    return pb.build()


def ew_add(m: int, n: int) -> Program:
    """O = X + Y (elementwise)."""
    pb = ProgramBuilder(f"ewadd_{m}x{n}")
    a, b = pb.axes(a=m, b=n)
    X = pb.buffer("X", (m, n))
    Y = pb.buffer("Y", (m, n))
    O = pb.buffer("O", (m, n))
    pb.stmt(O[a, b], ":=", X[a, b])
    pb.stmt(O[a, b], "+=", Y[a, b])
    pb.output("O")
    return pb.build()


def ew_relu(m: int, n: int) -> Program:
    """O = relu(X)."""
    pb = ProgramBuilder(f"ewrelu_{m}x{n}")
    a, b = pb.axes(a=m, b=n)
    X = pb.buffer("X", (m, n))
    O = pb.buffer("O", (m, n))
    pb.apply(O[a, b], "relu", X[a, b])
    pb.output("O")
    return pb.build()


def ew_scale_relu(m: int, n: int, halvings: int) -> Program:
    """O = relu(X * 2**-halvings) — the attention-score epilogue."""
    pb = ProgramBuilder(f"scalerelu_{m}x{n}_h{halvings}")
    a, b = pb.axes(a=m, b=n)
    X = pb.buffer("X", (m, n))
    O = pb.buffer("O", (m, n))
    pb.apply(O[a, b], "halve", X[a, b])
    for _ in range(halvings - 1):
        pb.apply(O[a, b], "halve", O[a, b])
    pb.apply(O[a, b], "relu", O[a, b])
    pb.output("O")
    return pb.build()


# --------------------------------------------------------------------------- #
# The transformer-block tracer
# --------------------------------------------------------------------------- #


def trace_block(cfg: ModelConfig, seq_len: int = 8,
                name: str | None = None) -> KernelGraph:
    """Lower one decoder block of ``cfg`` into a ``KernelGraph``.

    Deterministic: the same (config dims, seq_len) produce the same graph
    fingerprint.  Requires ``cfg.hd`` (head dim) to be a power of four so the
    1/sqrt(head_dim) score scale is a whole number of halvings.
    """
    T, D, H, F = seq_len, cfg.d_model, cfg.n_heads, cfg.d_ff
    Dh = cfg.hd
    if H * Dh != D:
        raise GraphError(f"trace_block needs n_heads*head_dim == d_model "
                         f"(got {H}*{Dh} != {D})")
    halvings = (Dh.bit_length() - 1) // 2
    if 4 ** halvings != Dh:
        raise GraphError(f"trace_block needs a power-of-4 head_dim for the "
                         f"exact 1/sqrt(d) scale (got {Dh})")

    gb = GraphBuilder(name or f"block_{cfg.name}_T{T}")
    x = gb.tensor("x", (T, D), is_input=True)
    for h in range(H):
        for w in ("wq", "wk", "wv"):
            gb.tensor(f"{w}{h}", (D, Dh), is_input=True)
        gb.tensor(f"wo{h}", (Dh, D), is_input=True)
    for w, shape in (("w_gate", (D, F)), ("w_up", (D, F)),
                     ("w_down", (F, D))):
        gb.tensor(w, shape, is_input=True)

    def gemm(out: str, shape, prog: Program, a: str, b: str) -> str:
        gb.tensor(out, shape)
        gb.node(out, prog, {"A": a, "B": b}, {"C": out}, kind="gemm")
        return out

    def add(out: str, a: str, b: str) -> str:
        shape = gb.tensors[a].shape
        gb.tensor(out, shape)
        gb.node(out, ew_add(*shape), {"X": a, "Y": b}, {"O": out},
                kind="elementwise")
        return out

    mm_qkv = K.matmul(T, Dh, D)       # x (T,D) @ w (D,Dh)
    mm_scores = matmul_nt(T, T, Dh)   # q (T,Dh) @ k (T,Dh)^T
    mm_av = K.matmul(T, Dh, T)        # s (T,T) @ v (T,Dh)
    mm_proj = K.matmul(T, D, Dh)      # a (T,Dh) @ wo (Dh,D)
    mm_ffn = K.matmul(T, F, D)        # y1 (T,D) @ w (D,F)
    mm_down = K.matmul(T, D, F)       # h (T,F) @ w_down (F,D)

    # -- attention: per-head GEMM chains, head outputs summed ---------------
    projs = []
    for h in range(H):
        q = gemm(f"q{h}", (T, Dh), mm_qkv, x, f"wq{h}")
        k = gemm(f"k{h}", (T, Dh), mm_qkv, x, f"wk{h}")
        v = gemm(f"v{h}", (T, Dh), mm_qkv, x, f"wv{h}")
        sraw = gemm(f"sraw{h}", (T, T), mm_scores, q, k)
        s = gb.tensor(f"s{h}", (T, T))
        gb.node(f"s{h}", ew_scale_relu(T, T, halvings), {"X": sraw},
                {"O": s}, kind="elementwise")
        a = gemm(f"a{h}", (T, Dh), mm_av, s, v)
        projs.append(gemm(f"p{h}", (T, D), mm_proj, a, f"wo{h}"))
    attn = projs[0]
    for h in range(1, H):
        attn = add(f"attn{h}" if h < H - 1 else "attn", attn, projs[h])
    y1 = add("y1", x, attn)

    # -- FFN: additive relu gate (g + u, exact — no value-squaring mul) -----
    graw = gemm("graw", (T, F), mm_ffn, y1, "w_gate")
    g = gb.tensor("g", (T, F))
    gb.node("g", ew_relu(T, F), {"X": graw}, {"O": g}, kind="elementwise")
    u = gemm("u", (T, F), mm_ffn, y1, "w_up")
    hid = add("hid", g, u)
    o = gemm("o", (T, D), mm_down, hid, "w_down")
    add("y2", y1, o)
    gb.output("y2")
    return gb.build()


def trace_gru_chain(batch: int = 4, hidden: int = 16, inp: int = 16,
                    steps: int = 4) -> KernelGraph:
    """Stretch tracer: an unrolled GRU layer.  Every step is the *same*
    kernel program — N nodes, one compile (the dedupe-extreme case)."""
    gb = GraphBuilder(f"gru_{batch}x{hidden}x{inp}_s{steps}")
    prog = K.gru_cell(batch, hidden, inp)
    weights = {}
    for b in prog.buffers:
        if b.temp or b.name in ("X", "H", "Hout"):
            continue
        weights[b.name] = gb.tensor(b.name, b.shape, is_input=True)
    h = gb.tensor("h0", (batch, hidden), is_input=True)
    for t in range(steps):
        x = gb.tensor(f"x{t}", (batch, inp), is_input=True)
        nxt = gb.tensor(f"h{t + 1}", (batch, hidden))
        gb.node(f"step{t}", prog, {"X": x, "H": h, **weights},
                {"Hout": nxt}, kind="gemm")
        h = nxt
    gb.output(h)
    return gb.build()


# --------------------------------------------------------------------------- #
# Oracle inputs
# --------------------------------------------------------------------------- #


def block_inputs(g: KernelGraph, seed: int = 0) -> dict[str, np.ndarray]:
    """Ternary {-1, 0, +1} inputs for every graph input tensor.

    Integer-valued data keeps every traced op exact in any summation order
    (see module docstring); the fixed seed keeps the whole contract
    deterministic.  ``assert_exactness_bound`` checks the magnitudes stay
    inside the f32-exact range."""
    rng = np.random.default_rng(seed)
    return {t: rng.integers(-1, 2, g.tensors[t].shape).astype(np.float32)
            for t in g.inputs}


def assert_exactness_bound(env: dict[str, np.ndarray]) -> float:
    """Guard: every tensor must stay below 2**24 so f32 node-boundary casts
    are exact.  Returns the observed max magnitude."""
    worst = 0.0
    for t, arr in env.items():
        m = float(np.max(np.abs(arr))) if arr.size else 0.0
        if m >= EXACT_F32_BOUND:
            raise GraphError(
                f"tensor {t} magnitude {m:.3e} exceeds the f32-exact bound "
                f"2^24; shrink the traced shapes or sparsify the inputs")
        worst = max(worst, m)
    return worst
