"""The whole-model graph IR — the tier above ``repro.compile``'s kernels.

A ``KernelGraph`` is a DAG of ``GraphNode``s connected by named tensor edges
(``TensorSpec``).  Each node carries one kernel-level ISAMIR ``Program`` plus
a role-tagged wiring that binds the program's non-temp buffers to graph
tensors: ``inputs`` maps program buffers to the tensors they read,
``outputs`` to the tensors they produce.  The same invariants the kernel
tier enforces structurally (``Program.__post_init__``) hold one level up:

  * nodes are stored in a valid topological order — every tensor a node
    reads is a graph input or was produced by an earlier node;
  * every tensor has exactly one producer (a node or the graph boundary);
  * wired program buffers agree with their tensor's shape and dtype.

``validate()`` raises ``GraphError`` on violation; the tolerant
diagnostic-emitting twin lives in ``repro.verify.graph`` (``gra.*`` rules).

Graphs round-trip through JSON (``to_dict``/``from_dict``) including their
node programs, and ``fingerprint()`` gives the content hash the tracer
determinism contract and the ``CompiledGraph`` artifact key on.
``interpret_graph`` is the graph-level oracle: it runs every node program
through ``core.ir.interpret`` (f64 internally) and casts each produced
tensor to its declared dtype at the node boundary — exactly the numeric
contract the per-node executor replay and the plain-jax reference follow.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

from ..core.dtypes import dtype_bytes
from ..core.ir import (Access, Axis, Buffer, Program, Statement, interpret)

GRAPH_SCHEMA = 1

_NP_DTYPES = {"f32": np.float32, "f64": np.float64, "bf16": np.float32,
              "i32": np.int32}


class GraphError(ValueError):
    """Raised on malformed kernel graphs."""


# --------------------------------------------------------------------------- #
# Program (de)serialization — the graph tier is the first consumer that has
# to persist whole ISAMIR programs, not just their fingerprints.
# --------------------------------------------------------------------------- #


def program_to_dict(p: Program) -> dict:
    def acc(a: Access) -> dict:
        return {"buffer": a.buffer, "matrix": [list(r) for r in a.matrix],
                "offset": list(a.offset)}

    return {"name": p.name,
            "axes": [[a.name, a.size] for a in p.axes],
            "buffers": [[b.name, list(b.shape), b.dtype, int(b.temp)]
                        for b in p.buffers],
            "statements": [{"op": s.op, "fn": s.fn,
                            "lhs": acc(s.lhs), "rhs": acc(s.rhs)}
                           for s in p.statements],
            "outputs": list(p.outputs)}


def program_from_dict(d: dict) -> Program:
    def acc(a: dict) -> Access:
        return Access(a["buffer"], tuple(tuple(r) for r in a["matrix"]),
                      tuple(a["offset"]))

    return Program(
        d["name"],
        tuple(Axis(n, int(s)) for n, s in d["axes"]),
        tuple(Buffer(n, tuple(sh), dt, bool(t))
              for n, sh, dt, t in d["buffers"]),
        tuple(Statement(s["op"], acc(s["lhs"]), acc(s["rhs"]),
                        s.get("fn", "")) for s in d["statements"]),
        tuple(d.get("outputs", ())))


# --------------------------------------------------------------------------- #
# Nodes and edges
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class TensorSpec:
    """One graph edge: a named tensor with shape and dtype."""

    name: str
    shape: tuple[int, ...]
    dtype: str = "f32"

    @property
    def nbytes(self) -> int:
        n = dtype_bytes(self.dtype)
        for s in self.shape:
            n *= s
        return n

    def to_dict(self) -> dict:
        return {"name": self.name, "shape": list(self.shape),
                "dtype": self.dtype}

    @classmethod
    def from_dict(cls, d: dict) -> "TensorSpec":
        return cls(d["name"], tuple(d["shape"]), d.get("dtype", "f32"))


@dataclass(frozen=True)
class GraphNode:
    """One kernel: an ISAMIR program plus its tensor wiring.

    ``inputs``/``outputs`` are (program buffer, graph tensor) pairs; ``kind``
    tags the node for the fusion pass (``gemm`` | ``elementwise`` |
    ``fused``).
    """

    name: str
    program: Program
    inputs: tuple[tuple[str, str], ...]
    outputs: tuple[tuple[str, str], ...]
    kind: str = ""

    def consumed(self) -> tuple[str, ...]:
        return tuple(t for _, t in self.inputs)

    def produced(self) -> tuple[str, ...]:
        return tuple(t for _, t in self.outputs)

    def tensor_of(self, buf: str) -> str:
        for b, t in self.inputs + self.outputs:
            if b == buf:
                return t
        raise KeyError(buf)

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind,
                "program": program_to_dict(self.program),
                "inputs": [list(p) for p in self.inputs],
                "outputs": [list(p) for p in self.outputs]}

    @classmethod
    def from_dict(cls, d: dict) -> "GraphNode":
        return cls(d["name"], program_from_dict(d["program"]),
                   tuple((b, t) for b, t in d["inputs"]),
                   tuple((b, t) for b, t in d["outputs"]),
                   d.get("kind", ""))


@dataclass
class KernelGraph:
    """A DAG of kernel nodes over named tensors (see module docstring)."""

    name: str
    tensors: dict[str, TensorSpec]
    nodes: tuple[GraphNode, ...]
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]

    # -- invariants ----------------------------------------------------------
    def validate(self) -> None:
        known = set(self.tensors)
        for t in list(self.inputs) + list(self.outputs):
            if t not in known:
                raise GraphError(f"graph boundary names unknown tensor {t!r}")
        produced: set[str] = set(self.inputs)
        producers: dict[str, str] = {}
        names = set()
        for node in self.nodes:
            if node.name in names:
                raise GraphError(f"duplicate node name {node.name!r}")
            names.add(node.name)
            for buf, t in node.inputs + node.outputs:
                if t not in known:
                    raise GraphError(
                        f"{node.name}: wires unknown tensor {t!r}")
                try:
                    b = node.program.buffer(buf)
                except KeyError:
                    raise GraphError(
                        f"{node.name}: wires unknown buffer {buf!r}")
                spec = self.tensors[t]
                if tuple(b.shape) != tuple(spec.shape):
                    raise GraphError(
                        f"{node.name}: buffer {buf} shape {b.shape} != "
                        f"tensor {t} shape {spec.shape}")
                if b.dtype != spec.dtype:
                    raise GraphError(
                        f"{node.name}: buffer {buf} dtype {b.dtype} != "
                        f"tensor {t} dtype {spec.dtype}")
            for _, t in node.inputs:
                if t not in produced:
                    raise GraphError(
                        f"{node.name}: consumes {t!r} before it is produced "
                        f"(cycle or bad topological order)")
            for buf, t in node.outputs:
                if t in produced:
                    raise GraphError(
                        f"{node.name}: tensor {t!r} already has a producer "
                        f"({producers.get(t, 'graph input')})")
                if buf not in node.program.outputs:
                    raise GraphError(
                        f"{node.name}: wired output buffer {buf!r} is not a "
                        f"program output")
                produced.add(t)
                producers[t] = node.name
        for t in self.outputs:
            if t not in produced:
                raise GraphError(f"graph output {t!r} is never produced")

    # -- derived wiring maps -------------------------------------------------
    def producers(self) -> dict[str, str]:
        """tensor -> producing node name (graph inputs absent)."""
        return {t: n.name for n in self.nodes for t in n.produced()}

    def consumers(self) -> dict[str, list[str]]:
        """tensor -> consuming node names (graph outputs add ``<out>``)."""
        cons: dict[str, list[str]] = {t: [] for t in self.tensors}
        for n in self.nodes:
            for t in n.consumed():
                cons[t].append(n.name)
        for t in self.outputs:
            cons[t].append("<out>")
        return cons

    def node(self, name: str) -> GraphNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def intermediates(self) -> list[str]:
        """Tensors produced by a node and consumed inside the graph (the
        activations buffer placement decides over)."""
        boundary = set(self.inputs) | set(self.outputs)
        return [t for n in self.nodes for t in n.produced()
                if t not in boundary]

    # -- fingerprint / serialization ----------------------------------------
    def fingerprint(self) -> str:
        h = hashlib.sha256()
        h.update(json.dumps(self.to_dict(), sort_keys=True).encode())
        return h.hexdigest()[:16]

    def to_dict(self) -> dict:
        return {"schema": GRAPH_SCHEMA, "name": self.name,
                "tensors": [self.tensors[t].to_dict() for t in self.tensors],
                "nodes": [n.to_dict() for n in self.nodes],
                "inputs": list(self.inputs), "outputs": list(self.outputs)}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelGraph":
        specs = [TensorSpec.from_dict(t) for t in d.get("tensors", [])]
        g = cls(name=d.get("name", ""),
                tensors={t.name: t for t in specs},
                nodes=tuple(GraphNode.from_dict(n)
                            for n in d.get("nodes", [])),
                inputs=tuple(d.get("inputs", ())),
                outputs=tuple(d.get("outputs", ())))
        g.validate()
        return g

    def summary(self) -> str:
        kinds: dict[str, int] = {}
        for n in self.nodes:
            kinds[n.kind or "?"] = kinds.get(n.kind or "?", 0) + 1
        ks = " ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
        return (f"{self.name}: {len(self.nodes)} node(s) "
                f"({ks}), {len(self.tensors)} tensor(s), "
                f"fp={self.fingerprint()}")


@dataclass
class GraphBuilder:
    """Ergonomic front-end the tracer uses; ``build()`` validates."""

    name: str
    tensors: dict[str, TensorSpec] = field(default_factory=dict)
    nodes: list[GraphNode] = field(default_factory=list)
    inputs: list[str] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)

    def tensor(self, name: str, shape, dtype: str = "f32",
               is_input: bool = False) -> str:
        if name in self.tensors:
            raise GraphError(f"duplicate tensor {name!r}")
        self.tensors[name] = TensorSpec(name, tuple(shape), dtype)
        if is_input:
            self.inputs.append(name)
        return name

    def node(self, name: str, program: Program, inputs: dict[str, str],
             outputs: dict[str, str], kind: str = "") -> GraphNode:
        n = GraphNode(name, program, tuple(sorted(inputs.items())),
                      tuple(sorted(outputs.items())), kind)
        self.nodes.append(n)
        return n

    def output(self, *names: str) -> None:
        self.outputs.extend(names)

    def build(self) -> KernelGraph:
        g = KernelGraph(self.name, dict(self.tensors), tuple(self.nodes),
                        tuple(self.inputs), tuple(self.outputs))
        g.validate()
        return g


# --------------------------------------------------------------------------- #
# The graph-level oracle
# --------------------------------------------------------------------------- #


def np_dtype(name: str):
    return _NP_DTYPES.get(name, np.float32)


def interpret_graph(g: KernelGraph, inputs: dict[str, np.ndarray],
                    return_all: bool = False) -> dict[str, np.ndarray]:
    """Run every node program through the ISAMIR interpreter, casting each
    produced tensor to its declared dtype at the node boundary."""
    env: dict[str, np.ndarray] = {}
    for t in g.inputs:
        if t not in inputs:
            raise GraphError(f"missing graph input {t!r}")
        arr = np.asarray(inputs[t], dtype=np_dtype(g.tensors[t].dtype))
        if arr.shape != g.tensors[t].shape:
            raise GraphError(
                f"input {t}: shape {arr.shape} != {g.tensors[t].shape}")
        env[t] = arr
    for node in g.nodes:
        ins = {buf: env[t] for buf, t in node.inputs}
        outs = interpret(node.program, ins)
        for buf, t in node.outputs:
            env[t] = outs[buf].astype(np_dtype(g.tensors[t].dtype))
    if return_all:
        return env
    return {t: env[t] for t in g.outputs}
