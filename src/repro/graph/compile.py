"""The graph compiler: ``KernelGraph`` → ``CompiledGraph``.

Every node's kernel program goes through the *existing* pass pipeline
(``compile.driver.compile_program``), so the graph tier adds no second
compilation path — it adds reuse and placement on top:

  * **dedupe** — nodes are keyed by their program fingerprint; N nodes with
    the same shape issue one compile (in-process memo + ``ArtifactCache``),
    and the stats record exactly how many compiles were saved;
  * **placement** — ``plan_placement`` decides which inter-kernel tensors
    stay resident in VMEM and which spill to HBM, greedily by liveness
    under a byte budget (half the VMEM by default: the kernels' own tile
    working sets use the other half, cf. ``Approach.vmem_frac``);
  * **schedule** — the node DAG plus the placement-implied DMA traffic
    replays on the event simulator (``fabric.simulate.simulate_kernel_graph``)
    for an end-to-end modeled makespan on one chip.

The resulting ``CompiledGraph`` serializes to JSON (graph + per-node
kernel payloads + placement + stats) and — while its kernels are live or
after ``ensure_kernels`` — executes inputs through the per-node scheduled
replay (``core.executor``), bit-exact against ``interpret_graph`` and the
plain-jax reference.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..compile.artifact import CompiledKernel
from ..compile.driver import compile_program
from ..core.instructions import tpu_isa
from ..core.sysgraph import SystemGraph, tpu_v5e
from ..search.space import program_fingerprint
from .ir import GRAPH_SCHEMA, GraphError, KernelGraph, np_dtype

#: fraction of VMEM the placement planner may fill with resident tensors
#: (the kernels' own tile working sets get the rest, cf. vmem_frac).
RESIDENCY_FRAC = 0.5


# --------------------------------------------------------------------------- #
# Inter-kernel buffer placement
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Placement:
    """Where each intermediate tensor lives between kernels."""

    locations: dict  # tensor -> "vmem" | "hbm"
    peak_vmem: int   # max simultaneously-resident bytes the plan commits
    budget: int

    def spilled(self) -> list[str]:
        return sorted(t for t, loc in self.locations.items() if loc == "hbm")

    def to_dict(self) -> dict:
        return {"locations": dict(self.locations),
                "peak_vmem": self.peak_vmem, "budget": self.budget}

    @classmethod
    def from_dict(cls, d: dict) -> "Placement":
        return cls(dict(d.get("locations", {})),
                   int(d.get("peak_vmem", 0)), int(d.get("budget", 0)))


def plan_placement(g: KernelGraph, budget: int) -> Placement:
    """Greedy liveness-aware VMEM residency for the graph's intermediates.

    Walks nodes in (topological) order keeping a resident set: a produced
    intermediate goes to VMEM if it fits under ``budget``, otherwise it
    spills to HBM; residents are freed after their last consumer.  Pure —
    no compilation involved — so the verifier's ``gra.capacity`` replay
    (``verify.graph.verify_placement``) can re-check any plan.
    """
    inter = set(g.intermediates())
    last_use = {}
    for i, node in enumerate(g.nodes):
        for t in node.consumed():
            if t in inter:
                last_use[t] = i
    locations: dict[str, str] = {}
    resident: dict[str, int] = {}
    used = peak = 0
    for i, node in enumerate(g.nodes):
        for t in node.produced():
            if t not in inter:
                continue
            nb = g.tensors[t].nbytes
            if t in last_use and used + nb <= budget:
                locations[t] = "vmem"
                resident[t] = nb
                used += nb
                peak = max(peak, used)
            else:
                locations[t] = "hbm"
        for t in [t for t, li in last_use.items()
                  if li <= i and t in resident]:
            used -= resident.pop(t)
    return Placement(locations, peak, budget)


def edge_bytes(g: KernelGraph) -> int:
    """Placement-independent inter-kernel traffic: every tensor is written
    once by its producer and read once per consumer (graph outputs count
    one boundary read).  Fusing an epilogue deletes its wire tensor, so
    this is the modeled-bytes number the fusion benchmarks assert on."""
    producers = g.producers()
    consumers = g.consumers()
    total = 0
    for t, spec in g.tensors.items():
        writes = 1 if t in producers else 0
        total += (writes + len(consumers.get(t, []))) * spec.nbytes
    return total


# --------------------------------------------------------------------------- #
# The CompiledGraph artifact
# --------------------------------------------------------------------------- #

GRAPH_ARTIFACT_SCHEMA = 1


@dataclass
class CompiledGraph:
    """Serializable result of compiling a whole ``KernelGraph``.

    ``kernels`` holds one ``CompiledKernel`` per *unique* program
    fingerprint; ``node_kernels`` maps every node onto its (shared)
    kernel.  Kernels carry live selection/schedule attachments on a fresh
    compile; after ``from_dict`` call ``ensure_kernels`` to reattach them
    (cache hits make that cheap) before ``execute``.
    """

    name: str
    graph_fp: str
    kernels: dict = field(default_factory=dict)       # program fp -> kernel
    node_kernels: dict = field(default_factory=dict)  # node name -> program fp
    placement: Placement | None = None
    makespan: float = 0.0
    hbm_bytes: int = 0
    edge_bytes: int = 0
    stats: dict = field(default_factory=dict)
    decisions: list = field(default_factory=list)     # fusion decision dicts
    graph: KernelGraph | None = None

    # -- execution -----------------------------------------------------------
    def execute(self, inputs: dict) -> dict:
        """Replay every node's compiled schedule through ``core.executor``
        in graph order — the executed twin of ``interpret_graph`` (same
        per-node dtype boundaries, so bit-exact against it)."""
        g = self.graph
        if g is None:
            raise GraphError("CompiledGraph has no graph attached; "
                             "rebuild via from_dict/compile_graph")
        from ..core.executor import execute as execute_schedule
        env: dict[str, np.ndarray] = {}
        for t in g.inputs:
            env[t] = np.asarray(inputs[t], dtype=np_dtype(g.tensors[t].dtype))
        for node in g.nodes:
            art = self.kernels[self.node_kernels[node.name]]
            art.ensure_schedule()
            ins = {buf: env[t] for buf, t in node.inputs}
            outs = execute_schedule(art.schedule, art.selection, ins)
            for buf, t in node.outputs:
                env[t] = outs[buf].astype(np_dtype(g.tensors[t].dtype))
        return {t: env[t] for t in g.outputs}

    def ensure_kernels(self, graph: SystemGraph | None = None, approach=None,
                       isa=None, *, cache=None, use_cache: bool = True):
        """Reattach live selections/schedules after deserialization by
        re-driving each unique program through the compiler (artifact-cache
        hits skip the expensive stages)."""
        if self.graph is None:
            raise GraphError("CompiledGraph has no graph attached")
        sysgraph = graph if graph is not None else tpu_v5e(1)
        isa = list(isa) if isa else tpu_isa()
        for node in self.graph.nodes:
            fp = self.node_kernels[node.name]
            art = self.kernels[fp]
            if art.schedule is not None or art.program is not None:
                continue
            self.kernels[fp] = compile_program(
                node.program, sysgraph, approach, isa,
                allow_transforms=False, cache=cache, use_cache=use_cache,
                meta={"graph": self.name, "node": node.name})
        return self

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": GRAPH_ARTIFACT_SCHEMA,
                "graph_schema": GRAPH_SCHEMA,
                "name": self.name, "graph_fp": self.graph_fp,
                "kernels": {fp: k.to_dict()
                            for fp, k in sorted(self.kernels.items())},
                "node_kernels": dict(self.node_kernels),
                "placement": (self.placement.to_dict()
                              if self.placement else None),
                "makespan": self.makespan, "hbm_bytes": self.hbm_bytes,
                "edge_bytes": self.edge_bytes, "stats": dict(self.stats),
                "decisions": list(self.decisions),
                "graph": self.graph.to_dict() if self.graph else None}

    @classmethod
    def from_dict(cls, d: dict) -> "CompiledGraph":
        return cls(
            name=d.get("name", ""), graph_fp=d.get("graph_fp", ""),
            kernels={fp: CompiledKernel.from_dict(k)
                     for fp, k in d.get("kernels", {}).items()},
            node_kernels=dict(d.get("node_kernels", {})),
            placement=(Placement.from_dict(d["placement"])
                       if d.get("placement") else None),
            makespan=float(d.get("makespan", 0.0)),
            hbm_bytes=int(d.get("hbm_bytes", 0)),
            edge_bytes=int(d.get("edge_bytes", 0)),
            stats=dict(d.get("stats", {})),
            decisions=list(d.get("decisions", [])),
            graph=(KernelGraph.from_dict(d["graph"])
                   if d.get("graph") else None))

    def summary(self) -> str:
        s = self.stats
        spills = len(self.placement.spilled()) if self.placement else 0
        return (f"{self.name}: {s.get('nodes', 0)} node(s) -> "
                f"{s.get('unique_programs', 0)} compile(s) "
                f"({s.get('cache_hits', 0)} cached), "
                f"{spills} spill(s), makespan={self.makespan:.3e}s, "
                f"hbm={self.hbm_bytes}B edge={self.edge_bytes}B")


# --------------------------------------------------------------------------- #
# The driver
# --------------------------------------------------------------------------- #


def compile_graph(g: KernelGraph, graph: SystemGraph | None = None,
                  approach=None, isa=None, *, cache=None,
                  use_cache: bool = True, vmem_budget: int | None = None,
                  decisions=None, verify: bool = True) -> CompiledGraph:
    """Compile every node of ``g`` through the kernel pipeline and assemble
    the graph-level artifact.  ``decisions`` (from ``fuse_epilogues``)
    rides along for provenance; ``vmem_budget`` defaults to
    ``RESIDENCY_FRAC`` of the chip's fastest memory."""
    g.validate()
    sysgraph = graph if graph is not None else tpu_v5e(1)
    isa = list(isa) if isa else tpu_isa()
    vmem = max(sysgraph.memories.values(), key=lambda m: m.level)
    budget = (int(vmem.capacity * RESIDENCY_FRAC)
              if vmem_budget is None else int(vmem_budget))

    kernels: dict[str, CompiledKernel] = {}
    node_kernels: dict[str, str] = {}
    fresh = hits = 0
    for node in g.nodes:
        fp = program_fingerprint(node.program)
        node_kernels[node.name] = fp
        if fp in kernels:
            continue
        art = compile_program(node.program, sysgraph, approach, isa,
                              allow_transforms=False, cache=cache,
                              use_cache=use_cache, verify=verify,
                              meta={"graph": g.name, "node": node.name})
        kernels[fp] = art
        fresh += not art.from_cache
        hits += art.from_cache

    placement = plan_placement(g, budget)
    from ..fabric.simulate import simulate_kernel_graph
    sim = simulate_kernel_graph(
        g, {n.name: kernels[node_kernels[n.name]].cost for n in g.nodes},
        placement.locations, sysgraph)

    gemm_nodes = [n for n in g.nodes if n.kind in ("gemm", "fused")]
    stats = {
        "nodes": len(g.nodes),
        "unique_programs": len(kernels),
        "compiles_issued": len(kernels),
        "fresh_compiles": fresh,
        "cache_hits": hits,
        "dedupe": round(len(g.nodes) / max(1, len(kernels)), 3),
        "gemm_nodes": len(gemm_nodes),
        "unique_gemm_programs": len({node_kernels[n.name]
                                     for n in gemm_nodes}),
        "spilled": len(placement.spilled()),
        "sim_tasks": sim["n_tasks"],
    }
    return CompiledGraph(
        name=g.name, graph_fp=g.fingerprint(), kernels=kernels,
        node_kernels=node_kernels, placement=placement,
        makespan=sim["makespan"], hbm_bytes=sim["hbm_bytes"],
        edge_bytes=edge_bytes(g), stats=stats,
        decisions=[d.to_dict() for d in (decisions or [])], graph=g)
