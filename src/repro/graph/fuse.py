"""Epilogue fusion — fold elementwise nodes into their producer GEMM.

A candidate is an ``elementwise`` node whose *wire* input tensor is
produced by a ``gemm``/``fused`` node, is consumed by nobody else, and is
not a graph output.  The two kernel programs are composed with
``core.transforms.fuse_epilogue`` — the producer keeps its GEMM statements
and gains the consumer's elementwise tail on its output buffer, so
instruction selection covers the result with ``mxu.matmul`` + VPU needles
(or the ``fused.*`` needles when they match).  The wire tensor disappears
from the graph entirely: that is the modeled-bytes win the benchmarks and
the CI lane assert.

The pass runs to fixpoint, so chains fold fully: ``gemm → relu → add``
becomes one node.  Every decision is recorded (consumer, producer, tensor,
bytes saved) for the CLI report and the ``CompiledGraph`` artifact.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.ir import IRError
from ..core.transforms import fuse_epilogue
from .ir import GraphNode, KernelGraph

FUSABLE_PRODUCERS = ("gemm", "fused")


@dataclass(frozen=True)
class FusionDecision:
    consumer: str          # elementwise node folded away
    producer: str          # node it was folded into
    tensor: str            # wire tensor eliminated from the graph
    saved_bytes: int       # the wire tensor's size (write + one read)

    def to_dict(self) -> dict:
        return {"consumer": self.consumer, "producer": self.producer,
                "tensor": self.tensor, "saved_bytes": self.saved_bytes}


def _fuse_once(g: KernelGraph) -> tuple[KernelGraph, FusionDecision] | None:
    producers = g.producers()
    consumers = g.consumers()
    for node in g.nodes:
        if node.kind != "elementwise":
            continue
        for buf, t in node.inputs:
            if consumers.get(t) != [node.name]:
                continue
            pname = producers.get(t)
            if pname is None:
                continue
            prod = g.node(pname)
            if prod.kind not in FUSABLE_PRODUCERS:
                continue
            try:
                fused_prog, rename = fuse_epilogue(
                    prod.program, node.program, buf, return_map=True)
            except IRError:
                continue
            out_buf = prod.program.outputs[0]
            inputs = dict(prod.inputs)
            for b2, t2 in node.inputs:
                if t2 != t:
                    # consumer's extra operands keep their (possibly
                    # uniquified) buffer binding in the fused program
                    inputs[rename.get(b2, b2)] = t2
            fused = GraphNode(
                name=f"{prod.name}+{node.name}", program=fused_prog,
                inputs=tuple(sorted(inputs.items())),
                outputs=tuple((out_buf, t2) for _, t2 in node.outputs),
                kind="fused")
            # the fused node takes the *consumer's* slot: the producer's
            # only product was the wire, so no node in between needs it,
            # while the consumer's other operands may be produced late
            nodes = tuple(fused if n.name == node.name else n
                          for n in g.nodes if n.name != pname)
            tensors = {k: v for k, v in g.tensors.items() if k != t}
            g2 = KernelGraph(g.name, tensors, nodes, g.inputs, g.outputs)
            g2.validate()
            return g2, FusionDecision(node.name, pname, t,
                                      2 * g.tensors[t].nbytes)
    return None


def fuse_epilogues(g: KernelGraph) -> tuple[KernelGraph,
                                            list[FusionDecision]]:
    """Run epilogue fusion to fixpoint; returns (fused graph, decisions)."""
    decisions: list[FusionDecision] = []
    while True:
        step = _fuse_once(g)
        if step is None:
            return g, decisions
        g, d = step
        decisions.append(d)
