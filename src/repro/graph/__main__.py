"""``repro graph`` — trace, fuse, compile and validate a whole model block.

    repro graph                                   # olmo-1b block, fused
    repro graph --arch qwen2-7b --seq 16          # another config / seq len
    repro graph --no-fuse                         # keep epilogues standalone
    repro graph --gru                             # the unrolled-GRU tracer
    repro graph --cache arts.json                 # persistent artifact cache
    repro graph --cache arts.json --expect-cached # 2nd run: all hits, or fail
    repro graph --validate                        # oracle + executed replay
                                                  #   vs plain jax, bit-exact
    repro graph --json report.json

Per-node table shows which kernel each node mapped to and whether the
compile was deduped (same program fingerprint) or served from the cache.
Exit status: 0 iff compilation, ``--validate`` and ``--expect-cached`` all
hold.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro graph",
        description="Whole-model graph compilation: trace a model config "
                    "into a kernel graph, fuse epilogues, compile every "
                    "node (deduped), place buffers and report the "
                    "simulated end-to-end makespan.")
    ap.add_argument("--arch", default="olmo-1b",
                    help="model config to trace (default olmo-1b)")
    ap.add_argument("--seq", type=int, default=8,
                    help="trace sequence length (default 8)")
    ap.add_argument("--gru", action="store_true",
                    help="trace the unrolled GRU chain instead of the "
                         "transformer block")
    ap.add_argument("--no-fuse", action="store_true",
                    help="skip epilogue fusion")
    ap.add_argument("--budget", type=int, default=None,
                    help="vmem residency budget in bytes (default: half "
                         "the chip's vmem)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="artifact cache file (enables cross-run reuse)")
    ap.add_argument("--expect-cached", action="store_true",
                    help="fail unless every unique compile is a cache hit")
    ap.add_argument("--validate", action="store_true",
                    help="check interpreted + executed outputs bit-exact "
                         "(vs plain jax for the block tracer)")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    from ..compile.cache import ArtifactCache
    from ..configs.registry import get_trace_config
    from ..models.traceable import block_reference
    from .compile import compile_graph
    from .fuse import fuse_epilogues
    from .ir import interpret_graph
    from .trace import (assert_exactness_bound, block_inputs, trace_block,
                        trace_gru_chain)

    failures = 0
    if args.gru:
        cfg = None
        g = trace_gru_chain()
    else:
        cfg = get_trace_config(args.arch)
        g = trace_block(cfg, seq_len=args.seq)
    print(f"traced   {g.summary()}")

    decisions = []
    if not args.no_fuse:
        g, decisions = fuse_epilogues(g)
        for d in decisions:
            print(f"  fused  {d.consumer} -> {d.producer} "
                  f"(-{d.saved_bytes}B via {d.tensor})")
        print(f"fused    {g.summary()}")

    cache = ArtifactCache(args.cache) if args.cache else None
    cg = compile_graph(g, cache=cache, use_cache=cache is not None,
                       vmem_budget=args.budget, decisions=decisions)

    seen: set[str] = set()
    for node in g.nodes:
        fp = cg.node_kernels[node.name]
        art = cg.kernels[fp]
        if fp in seen:
            src = "dedup"
        else:
            src = "cache" if art.from_cache else "fresh"
            seen.add(fp)
        print(f"  {node.name:<14} {node.program.name:<40} "
              f"cost={art.cost:.3e}s [{src}]")
    s = cg.stats
    print(f"compiled {cg.summary()}")
    print(f"         dedupe={s['dedupe']}x "
          f"({s['nodes']} nodes / {s['unique_programs']} compiles), "
          f"fresh={s['fresh_compiles']} cached={s['cache_hits']}")
    if cg.placement and cg.placement.spilled():
        print(f"         spilled to hbm: {', '.join(cg.placement.spilled())}")

    if args.expect_cached and s["fresh_compiles"]:
        print(f"[FAIL] --expect-cached: {s['fresh_compiles']} fresh "
              f"compile(s), expected all {s['unique_programs']} from cache")
        failures += 1

    validated = None
    if args.validate:
        inputs = block_inputs(g)
        interp = interpret_graph(g, inputs)
        worst = assert_exactness_bound(interpret_graph(g, inputs,
                                                       return_all=True))
        executed = cg.execute(inputs)
        checks = [("executed-vs-interpreted",
                   all(np.array_equal(executed[t], interp[t])
                       for t in interp))]
        if cfg is not None:
            ref = block_reference(inputs, cfg, args.seq)
            checks += [("interpreted-vs-jax",
                        all(np.array_equal(v, ref) for v in interp.values())),
                       ("executed-vs-jax",
                        all(np.array_equal(v, ref)
                            for v in executed.values()))]
        validated = all(ok for _, ok in checks)
        for name, ok in checks:
            print(f"  [{'ok' if ok else 'FAIL'}] {name}: bit-exact={ok}")
            failures += not ok
        print(f"validate max |tensor| = {worst:.1f} "
              f"(f32-exact bound 2^24)")

    if args.json:
        payload = {"schema": 1, "failures": failures,
                   "graph": g.summary(), "graph_fp": g.fingerprint(),
                   "stats": dict(s), "makespan": cg.makespan,
                   "hbm_bytes": cg.hbm_bytes, "edge_bytes": cg.edge_bytes,
                   "decisions": [d.to_dict() for d in decisions],
                   "placement": cg.placement.to_dict(),
                   "validated": validated}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# report: {args.json}")
    print(f"# makespan={cg.makespan:.3e}s hbm={cg.hbm_bytes}B "
          f"edge={cg.edge_bytes}B, {failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
