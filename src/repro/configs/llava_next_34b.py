"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres patch tiling (frontend STUB: input_specs provides
precomputed patch embeddings) [hf:llava-hf/llava-v1.6...; unverified]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    frontend_tokens=576,          # anyres base grid 24x24
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=128, frontend_tokens=8,
                         remat=False)
