"""whisper-medium [audio]: 24L(+24 enc) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — encoder-decoder; conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    encoder_layers=24,
    frontend_tokens=1500,         # 30s of audio at 50 Hz after conv stub
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=128, encoder_layers=2,
                         frontend_tokens=16, remat=False)
