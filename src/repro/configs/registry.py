"""Architecture registry: ``get_config(arch)`` + ``input_specs(cfg, shape)``.

Each assigned architecture lives in its own module defining ``CONFIG`` (the
exact published configuration) and ``smoke_config()`` (a reduced same-family
variant for CPU smoke tests)."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from ..models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ARCHS = [
    "xlstm-1.3b",
    "olmo-1b",
    "qwen2-7b",
    "qwen1.5-32b",
    "qwen2.5-32b",
    "phi3.5-moe-42b-a6.6b",
    "mixtral-8x7b",
    "llava-next-34b",
    "jamba-1.5-large-398b",
    "whisper-medium",
]


def _module(arch: str):
    return importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def get_trace_config(arch: str) -> ModelConfig:
    """A scaled-down config sized for the graph tracer (``repro.graph``):
    one layer, dense-block dims small enough for the NumPy oracle, and a
    power-of-4 head_dim so the attention score scale is exact (see
    ``repro.graph.trace``)."""
    return get_config(arch).scaled(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, n_experts=0, remat=False)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                for_train: bool | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B,), i32)}
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
    if cfg.family == "vlm":
        n_patches = cfg.frontend_tokens or 576
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patches, cfg.d_model), f)
    if cfg.family == "audio":
        n_frames = cfg.frontend_tokens or 1500
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (B, n_frames, cfg.d_model), f)
    return specs


def cell_applicable(arch: str, shape_name: str) -> bool:
    return shape_applicable(arch, shape_name)


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]
