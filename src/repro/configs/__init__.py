from .registry import ARCHS, all_cells, cell_applicable, get_config, \
    get_smoke_config, input_specs

__all__ = ["ARCHS", "all_cells", "cell_applicable", "get_config",
           "get_smoke_config", "input_specs"]
