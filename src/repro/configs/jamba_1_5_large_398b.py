"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_period=2,
    attn_period=8, mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=96, vocab_size=128, n_experts=4, top_k=2, capacity_factor=8.0, 
                         attn_period=2, remat=False)
