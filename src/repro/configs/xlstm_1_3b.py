"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks, xLSTM[7:1] interleave [arXiv:2405.04517; unverified]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_period=8, mlstm_proj_factor=2.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                         vocab_size=128, slstm_period=2, remat=False)
