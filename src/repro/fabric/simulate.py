"""Event-driven distributed schedule simulator.

    PYTHONPATH=src python -m repro.fabric.simulate \\
        --shape 5124x700x2048 --chips 4 --topology ring

Takes a partition choice (``partition.py``), runs the *existing* static
scheduler on every per-chip subprogram, lowers the implied collectives to
COPY streams (``collectives.py``), and replays everything on one global
event timeline: each chip's compute/DMA resources plus every fabric link
get their own FIFO timeline, and tasks carry explicit dependencies —

  * per-chip ops depend on region availability exactly as
    ``scheduler.cost_model`` models it (the per-chip replay with no fabric
    reproduces ``cost_model`` makespans op for op);
  * a gathered operand's region at its home HBM becomes available only
    when the covering collective chunks *arrive*, so compute overlaps the
    tail of an operand all-gather;
  * a reduce/gather send becomes ready only when the sending chip's local
    partial for that chunk is complete (tracked per output chunk from the
    schedule's writebacks), so output collectives overlap the compute
    front.

The reported makespan is directly comparable to the single-chip
``scheduler.cost_model()`` number — same compute/DMA durations, same
semantics, one extra resource class (fabric links).

``FabricEvaluator`` scores a joint (partition axis, collective algorithm,
per-chip tiles) config for ``repro.search``.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass

from ..compile import CompileError, compile_selection
from ..core.scheduler import Region, Schedule, ScheduleError, compute_time
from ..core.sysgraph import SystemGraph
from ..search.space import Config, ParamApproach
from .collectives import (ALGORITHMS, CollectiveStep, lower_all_gather,
                          lower_all_reduce, lower_reduce_scatter)
from .partition import (CollectiveSpec, PartitionedProgram, partition,
                        partition_axes, replay_bitexact, split_extent)
from .topology import Topology, make_topology

#: Oracle-validation proxies cap each axis (full DeepBench shapes would
#: materialize intractable NumPy temporaries — same policy as repro.search).
VALIDATE_DIM_CAP = 192


# --------------------------------------------------------------------------- #
# The event timeline
# --------------------------------------------------------------------------- #


@dataclass
class _Task:
    tid: str
    resource: str | None
    duration: float
    deps: tuple[str, ...]
    ready: float


class EventSim:
    """Deterministic discrete-event timeline: tasks on FIFO resources.

    Tasks are added in a valid topological order (asserted) and each
    resource executes its tasks in insertion order — exactly the
    in-stream-order semantics of ``scheduler.cost_model``, extended with
    explicit cross-chip dependencies.  ``run`` is then a single relaxation
    pass: ``start = max(ready, deps' ends, resource free)``.
    """

    def __init__(self):
        self._tasks: list[_Task] = []
        self._known: set[str] = set()

    @property
    def tasks(self) -> list[tuple[str, tuple[str, ...]]]:
        """(tid, deps) pairs in insertion order — the auditable dependency
        graph ``repro.verify.fabric.verify_task_graph`` checks."""
        return [(t.tid, t.deps) for t in self._tasks]

    def add(self, tid: str, resource: str | None = None,
            duration: float = 0.0, deps=(), ready: float = 0.0) -> str:
        if tid in self._known:
            raise ValueError(f"duplicate task id {tid}")
        for d in deps:
            if d not in self._known:
                raise ValueError(f"task {tid} depends on unknown {d}")
        self._known.add(tid)
        self._tasks.append(_Task(tid, resource, duration, tuple(deps), ready))
        return tid

    def run(self) -> dict[str, tuple[float, float]]:
        free: dict[str, float] = {}
        times: dict[str, tuple[float, float]] = {}
        for t in self._tasks:
            start = t.ready
            for d in t.deps:
                start = max(start, times[d][1])
            if t.resource is not None:
                start = max(start, free.get(t.resource, 0.0))
            end = start + t.duration
            times[t.tid] = (start, end)
            if t.resource is not None:
                free[t.resource] = end
        return times


# --------------------------------------------------------------------------- #
# Per-chip schedule replay
# --------------------------------------------------------------------------- #


def _bounds_rows_overlap(bounds: tuple, axis: int, off: int, ln: int) -> bool:
    if axis >= len(bounds):
        return True
    s, n = bounds[axis]
    return s < off + ln and off < s + n


def _add_chip_schedule(sim: EventSim, chip: int, sched: Schedule,
                       initial_dep=None,
                       out_chunks: list[tuple[int, int, int]] | None = None,
                       out_buffer: str = "", out_axis: int = 0,
                       ) -> dict[int, str]:
    """Feed one chip's scheduled op stream into the timeline.

    ``initial_dep(region, node) -> [tids]`` supplies arrival dependencies
    for data that is *not* resident at t=0 (gathered operands).
    ``out_chunks`` = [(chunk_id, off, len)] along ``out_axis`` of
    ``out_buffer``; returns a zero-duration *done marker* per chunk whose
    end time is when the chunk is complete in the chip's home memory.
    """
    g = sched.graph
    pre = f"c{chip}:"
    avail: dict[tuple, str] = {}     # ((buffer, bounds), node) -> producer tid

    def _initial(region: Region, node: str) -> list[str]:
        return initial_dep(region, node) if initial_dep else []

    for op in sched.ops:
        tid = f"{pre}op{op.uid}"
        if op.kind in ("copy", "writeback"):
            k = (op.region.buffer, op.region.bounds)
            deps = ([avail[(k, op.src)]] if (k, op.src) in avail
                    else _initial(op.region, op.src))
            e = g.edge(op.src, op.dst)
            dur = e.latency + sched.region_nbytes(op.region) / e.bandwidth
            sim.add(tid, resource=f"{pre}dma:{op.src}->{op.dst}",
                    duration=dur, deps=deps)
            avail[(k, op.dst)] = tid
        else:
            dev = g.computes[op.device]
            mem = dev.memory
            deps = []
            for _, region, r, _ in op.tile.operands:
                if not r:
                    continue
                key = ((region.buffer, region.bounds), mem)
                if key in avail:
                    deps.append(avail[key])
                else:
                    deps.extend(_initial(region, mem))
            sim.add(tid, resource=f"{pre}{op.device}",
                    duration=compute_time(dev, op.tile), deps=deps)
            for _, region, _, w in op.tile.operands:
                if w:
                    avail[((region.buffer, region.bounds), mem)] = tid

    done: dict[int, str] = {}
    if out_chunks:
        home = sched.homes.get(out_buffer, "")
        for chunk_id, off, ln in out_chunks:
            deps = [tid for (k, node), tid in avail.items()
                    if k[0] == out_buffer and node == home
                    and _bounds_rows_overlap(k[1], out_axis, off, ln)]
            done[chunk_id] = sim.add(f"{pre}done:{out_buffer}:{chunk_id}",
                                     deps=sorted(set(deps)))
    return done


class _StaggeredUnroll:
    """Per-chip unroll rotation for compute/communication overlap.

    With every chip walking its output rows in the same ascending order,
    the ring chain for the *last* chunk cannot start before compute ends —
    zero overlap.  Chip *i* instead computes its own chunk first, then
    alternates outward (i, i-1, i+1, i-2, ...), so the clockwise and
    counter-clockwise chains both find their early hops ready while later
    chunks are still computing.  This is a pure reordering across output
    regions — reduction offsets stay ascending within each region, so the
    bit-exactness contract is untouched.  Everything except
    ``unroll_order`` delegates to the wrapped Approach.
    """

    def __init__(self, inner, chip: int, n_chips: int,
                 chunks: tuple[tuple[int, int], ...], axis: int):
        self._inner = inner
        self._chip = chip
        self._p = n_chips
        self._chunks = chunks
        self._axis = axis

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _rank(self, tile) -> int:
        region = tile.output_region()
        if region is None or self._axis >= len(region.bounds):
            return 0
        start = region.bounds[self._axis][0]
        c = 0
        for j, (off, ln) in enumerate(self._chunks):
            if off <= start < off + ln:
                c = j
                break
        if c == self._chip:
            return 0
        back = (self._chip - c) % self._p
        fwd = (c - self._chip) % self._p
        return 2 * back - 1 if back <= fwd else 2 * fwd

    def unroll_order(self, tiles):
        ordered = self._inner.unroll_order(tiles)
        return sorted(ordered, key=self._rank)      # stable: inner order kept


# --------------------------------------------------------------------------- #
# Collective phases on link timelines
# --------------------------------------------------------------------------- #


def _add_collective(sim: EventSim, topo: Topology, steps: list[CollectiveStep],
                    prefix: str,
                    done: dict[tuple[int, int], str] | None = None,
                    ) -> dict[tuple[int, int], str]:
    """Replay lowered collective steps over the fabric's link resources.

    Step chips/chunks are *positions in topo.ring_order*; this resolves
    them to chip ids and routes each logical hop over ``topo.path`` (one
    task per physical link — a host-tree hop is two PCIe tasks).  Returns
    ``(chip, chunk) -> tid`` arrival markers.
    """
    order = topo.ring_order
    last: dict[tuple[int, int], str] = {}        # (dir, chunk pos) -> tid
    arrivals: dict[tuple[int, int], str] = {}
    for st in steps:
        src, dst = order[st.src], order[st.dst]
        chunk = order[st.chunk]
        deps = []
        chain = (st.direction, st.chunk)
        if chain in last:
            deps.append(last[chain])
        if done:
            mark = done.get((src, chunk))
            if mark:
                deps.append(mark)
        tid = ""
        for hop, link in enumerate(topo.path(src, dst)):
            tid = sim.add(
                f"{prefix}:d{st.direction}:s{st.step}:c{chunk}"
                f":{src}->{dst}:h{hop}",
                resource=f"link:{link.src}->{link.dst}",
                duration=link.latency + st.nbytes / link.bandwidth,
                deps=deps)
            deps = [tid]
        last[chain] = tid
        arrivals[(dst, chunk)] = tid
    return arrivals


def _lower(spec: CollectiveSpec, pp: PartitionedProgram, topo: Topology,
           algorithm: str) -> list[CollectiveStep]:
    nbytes = spec.chunk_nbytes(pp.base)
    # lowering speaks ring positions: re-index chunk bytes by position
    by_pos = [nbytes[topo.ring_order[q]] for q in range(topo.n_chips)]
    lowerer = {"all_gather": lower_all_gather,
               "reduce_scatter": lower_reduce_scatter,
               "all_reduce": lower_all_reduce}[spec.kind]
    return lowerer(topo.n_chips, by_pos, algorithm,
                   phase=f"{spec.kind}:{spec.buffer}")


# --------------------------------------------------------------------------- #
# The simulator proper
# --------------------------------------------------------------------------- #


@dataclass
class FabricResult:
    axis: str
    algorithm: str
    makespan: float
    chip_spans: list[float]             # per-chip last local-op end
    comm_end: float                     # last collective task end (0 if none)
    n_tasks: int
    n_collective_steps: int

    @property
    def comm_bound(self) -> bool:
        return self.comm_end >= self.makespan * (1 - 1e-9) and self.comm_end > 0


def simulate_partition(pp: PartitionedProgram, topo: Topology,
                       approach=None, algorithm: str = "ring",
                       chip_graph: SystemGraph | None = None,
                       sim_out: list | None = None) -> FabricResult:
    """Distributed makespan of one partition choice on one fabric.

    ``sim_out``, when given, receives the assembled ``EventSim`` so callers
    (``repro verify``) can audit the task graph without re-building it."""
    if topo.n_chips != len(pp.shards):
        raise ValueError(
            f"partition has {len(pp.shards)} shards but the topology has "
            f"{topo.n_chips} chips — repartition for this fabric")
    chip_graph = chip_graph or Topology.chip_graph()
    pre = [c for c in pp.collectives if c.when == "pre"]
    post = [c for c in pp.collectives if c.when == "post"]

    # With a collective in play, each chip gets its own staggered unroll
    # (own chunk first) so ring chains overlap the compute front; without
    # one, chips are symmetric and a single schedule is shared.
    stagger = (post or pre) and topo.n_chips > 1
    stagger_spec = (post or pre)[0] if stagger else None
    scheds: dict[tuple, Schedule] = {}
    for shard in pp.shards:
        key = (shard.program.signature(), shard.chip if stagger else -1)
        if key not in scheds:
            app = approach
            if stagger:
                from ..core.approach import GreedyApproach
                app = _StaggeredUnroll(approach or GreedyApproach(),
                                       shard.chip, topo.n_chips,
                                       stagger_spec.chunks, stagger_spec.axis)
            # per-chip compile through the repro.compile driver
            scheds[key] = compile_selection(pp.shard_selection(shard),
                                            chip_graph, app).schedule

    sim = EventSim()

    # 1. operand collectives (data is shard-resident at t=0)
    arrivals: dict[str, dict[tuple[int, int], str]] = {}
    steps_total = 0
    for spec in pre:
        steps = _lower(spec, pp, topo, algorithm)
        steps_total += len(steps)
        arrivals[spec.buffer] = _add_collective(
            sim, topo, steps, prefix=f"pre:{spec.kind}:{spec.buffer}")

    # 2. per-chip schedules, gated on operand arrivals
    out_buffer = pp.output
    done_all: dict[tuple[int, int], str] = {}
    chip_tids: dict[int, list[str]] = {}
    for shard in pp.shards:
        sched = scheds[(shard.program.signature(),
                        shard.chip if stagger else -1)]
        chip = shard.chip

        def initial_dep(region: Region, node: str, _chip=chip,
                        _sched=sched) -> list[str]:
            deps = []
            for spec in pre:
                if region.buffer != spec.buffer:
                    continue
                if node != _sched.homes.get(spec.buffer):
                    continue
                arr = arrivals[spec.buffer]
                for j, (off, ln) in enumerate(spec.chunks):
                    if j == _chip:
                        continue         # own shard: resident at t=0
                    if not _bounds_rows_overlap(region.bounds, spec.axis,
                                                off, ln):
                        continue
                    tid = arr.get((_chip, j))
                    if tid:
                        deps.append(tid)
            return deps

        # Done markers for the output collective.  In chain_sum mode (k)
        # every chip holds a full-size partial, so local coordinates equal
        # the global chunk bounds; in concat mode the subprogram is the
        # shard itself, so the chip's own chunk spans its whole local dim.
        chunks = None
        out_axis = 0
        for spec in post:
            if spec.buffer != out_buffer:
                continue
            out_axis = spec.axis
            if pp.out_mode == "chain_sum":
                chunks = [(j, off, ln)
                          for j, (off, ln) in enumerate(spec.chunks)]
            else:
                local = shard.program.buffer(out_buffer).shape[spec.axis]
                chunks = [(chip, 0, local)]
        before = len(sim._tasks)
        done = _add_chip_schedule(sim, chip, sched,
                                  initial_dep=initial_dep if pre else None,
                                  out_chunks=chunks, out_buffer=out_buffer,
                                  out_axis=out_axis)
        chip_tids[chip] = [t.tid for t in sim._tasks[before:]]
        for j, tid in done.items():
            done_all[(chip, j)] = tid

    # 3. output collectives, gated on per-chunk completion
    comm_tids: list[str] = []
    for spec in post:
        steps = _lower(spec, pp, topo, algorithm)
        steps_total += len(steps)
        before = len(sim._tasks)
        _add_collective(sim, topo, steps,
                        prefix=f"post:{spec.kind}:{spec.buffer}",
                        done=done_all)
        comm_tids.extend(t.tid for t in sim._tasks[before:])
    for arr in arrivals.values():
        comm_tids.extend(arr.values())

    if sim_out is not None:
        sim_out.append(sim)
    times = sim.run()
    makespan = max((end for _, end in times.values()), default=0.0)
    chip_spans = [max((times[t][1] for t in chip_tids.get(c, [])), default=0.0)
                  for c in range(len(pp.shards))]
    comm_end = max((times[t][1] for t in comm_tids), default=0.0)
    return FabricResult(pp.axis, algorithm, makespan, chip_spans, comm_end,
                        len(sim._tasks), steps_total)


def single_chip_makespan(pp: PartitionedProgram,
                         chip_graph: SystemGraph | None = None,
                         approach=None) -> float:
    """The 1-chip reference: the full program compiled through the driver on
    one chip — the exact ``scheduler.cost_model()`` number."""
    chip_graph = chip_graph or Topology.chip_graph()
    one = partition(pp.kernel, _shape_of(pp), partition_axes(pp.kernel)[0], 1)
    sel = one.shard_selection(one.shards[0])
    return compile_selection(sel, chip_graph, approach).cost


def _shape_of(pp: PartitionedProgram) -> tuple[int, ...]:
    base = pp.base
    if pp.kernel == "gemm":
        return (base.buffer("A").shape[0], base.buffer("B").shape[1],
                base.buffer("A").shape[1])
    return (base.buffer("X").shape[0], base.buffer("H").shape[1])


def replicate_output(pp: PartitionedProgram) -> PartitionedProgram:
    """Upgrade the output contract from *sharded* to *replicated*: the k
    reduce-scatter becomes a full all-reduce and concat axes gain a post
    all-gather of the output."""
    out = pp.output
    dim = pp.base.buffer(out).shape[pp.out_axis]
    chunks = tuple(split_extent(dim, pp.n_chips))
    collectives = []
    has_post = False
    for c in pp.collectives:
        if c.when == "post" and c.kind == "reduce_scatter":
            c = CollectiveSpec("all_reduce", c.buffer, "post", c.axis,
                               c.chunks)
        if c.when == "post":
            has_post = True
        collectives.append(c)
    if not has_post and pp.n_chips > 1:
        collectives.append(CollectiveSpec("all_gather", out, "post",
                                          pp.out_axis, chunks))
    return PartitionedProgram(pp.base, pp.kernel, pp.axis, pp.n_chips,
                              pp.shards, collectives, pp.out_mode,
                              pp.out_axis)


# --------------------------------------------------------------------------- #
# Graph-level replay (repro.graph)
# --------------------------------------------------------------------------- #


def simulate_kernel_graph(kgraph, node_costs: dict, residency: dict,
                          graph: SystemGraph | None = None, *,
                          double_buffer: bool = True) -> dict:
    """Replay a compiled ``repro.graph.KernelGraph`` on one chip's event
    timeline: every node is a compute task (duration = its kernel's modeled
    makespan), and inter-kernel tensors turn into DMA tasks on the HBM→VMEM
    edge according to ``residency``:

      * graph inputs stream in once over the DMA edge (weight-stationary:
        they stay resident for every consumer);
      * an intermediate placed ``"vmem"`` is handed to consumers directly —
        the compute task dependency alone, no traffic;
      * an intermediate placed ``"hbm"`` (spilled by ``plan_placement``) is
        stored once after its producer and re-loaded per consumer;
      * graph outputs are written back to HBM.

    With ``double_buffer`` (the default) a node's loads overlap its compute
    instead of fully serializing before it: every HBM→VMEM transfer feeding
    a node is two half-size chunk tasks (total DMA occupancy unchanged —
    the edge latency rides on the first chunk) and every node is two
    half-cost phases on the core; phase 1 may start once each streamed
    operand's *first* chunk has landed, phase 2 needs the full operands.
    Dependencies only weaken, so no task finishes later than in the
    serialized schedule — and whenever a load gates a compute the critical
    path strictly shortens.  Stores stay whole: a writeback cannot start
    before its producer finishes.

    ``node_costs`` maps node name → seconds.  Returns the makespan, the
    modeled HBM traffic in bytes, and the auditable ``(tid, deps)`` task
    pairs (``repro.verify.fabric.verify_task_graph`` checks them — the
    same acyclicity/unknown-dep rules the collective timelines obey).
    """
    from ..core.sysgraph import tpu_v5e
    g = graph if graph is not None else tpu_v5e(1)
    vmem = max(g.memories.values(), key=lambda m: m.level)
    feed = next(e for e in g.edges
                if e.dst == vmem.name
                and g.memories[e.src].level == vmem.level - 1)
    core = next(c for c in g.computes.values() if c.memory == vmem.name)
    dma = f"{feed.src}->{feed.dst}"

    def xfer(nbytes: int) -> float:
        return feed.latency + nbytes / feed.bandwidth

    sim = EventSim()
    produced_by: dict[str, str] = {}          # tensor -> producing task id
    first_chunk: dict[str, str] = {}          # transfer tid -> chunk-1 tid
    hbm_bytes = 0
    spilled = {t for t, loc in residency.items() if loc == "hbm"}

    def add_load(tid: str, nbytes: int, deps=()) -> None:
        nonlocal hbm_bytes
        hbm_bytes += nbytes
        if double_buffer:
            half = nbytes / (2 * feed.bandwidth)
            a = sim.add(f"{tid}:a", resource=dma,
                        duration=feed.latency + half, deps=deps)
            sim.add(tid, resource=dma, duration=half, deps=(a,))
            first_chunk[tid] = a
        else:
            sim.add(tid, resource=dma, duration=xfer(nbytes), deps=deps)

    for t in kgraph.inputs:
        add_load(f"load:{t}", kgraph.tensors[t].nbytes)
        produced_by[t] = f"load:{t}"
    for node in kgraph.nodes:
        deps = []
        for t in node.consumed():
            if t in spilled:
                tid = f"load:{t}:{node.name}"
                add_load(tid, kgraph.tensors[t].nbytes,
                         deps=(f"store:{t}",))
                deps.append(tid)
            else:
                deps.append(produced_by[t])
        cost = float(node_costs[node.name])
        if double_buffer:
            early = tuple(first_chunk.get(d, d) for d in deps)
            p1 = sim.add(f"{node.name}:p1", resource=core.name,
                         duration=cost / 2, deps=early)
            sim.add(node.name, resource=core.name, duration=cost / 2,
                    deps=(p1, *deps))
        else:
            sim.add(node.name, resource=core.name,
                    duration=cost, deps=tuple(deps))
        for t in node.produced():
            produced_by[t] = node.name
            if t in spilled:
                sim.add(f"store:{t}", resource=dma,
                        duration=xfer(kgraph.tensors[t].nbytes),
                        deps=(node.name,))
                hbm_bytes += kgraph.tensors[t].nbytes
    for t in kgraph.outputs:
        sim.add(f"store:out:{t}", resource=dma,
                duration=xfer(kgraph.tensors[t].nbytes),
                deps=(produced_by[t],))
        hbm_bytes += kgraph.tensors[t].nbytes
    times = sim.run()
    makespan = max((end for _, end in times.values()), default=0.0)
    return {"makespan": makespan, "hbm_bytes": hbm_bytes,
            "n_tasks": len(sim._tasks), "tasks": sim.tasks, "times": times}


# --------------------------------------------------------------------------- #
# Search integration
# --------------------------------------------------------------------------- #


class FabricEvaluator:
    """Score a joint (partition axis, collective algorithm, per-chip tile)
    config by the simulated distributed makespan.  Plugs straight into the
    ``repro.search`` strategies; use with ``SearchSpace.for_fabric`` so the
    baseline point (axis=m, ring, greedy tiles) anchors the search."""

    def __init__(self, kernel: str, shape: tuple[int, ...], topo: Topology,
                 max_tiles: int = 4096, replicate_out: bool = False):
        self.kernel = kernel
        self.shape = shape
        self.topo = topo
        self.max_tiles = max_tiles
        self.replicate_out = replicate_out
        self.chip_graph = Topology.chip_graph()
        self._pps: dict[str, PartitionedProgram] = {}

    def pp(self, axis: str) -> PartitionedProgram:
        if axis not in self._pps:
            p = partition(self.kernel, self.shape, axis, self.topo.n_chips)
            if self.replicate_out:
                p = replicate_output(p)
            self._pps[axis] = p
        return self._pps[axis]

    def __call__(self, config: Config) -> float:
        from ..search.evaluate import CostModelEvaluator
        axis = config.get("part_axis", partition_axes(self.kernel)[0])
        algorithm = config.get("collective", "ring")
        if axis not in partition_axes(self.kernel) \
                or algorithm not in ALGORITHMS:
            return float("inf")
        approach = ParamApproach(config)
        pp = self.pp(axis)
        try:
            seen = set()
            for shard in pp.shards:
                sig = shard.program.signature()
                if sig in seen:
                    continue
                seen.add(sig)
                guard = CostModelEvaluator(pp.shard_selection(shard),
                                           self.chip_graph,
                                           max_tiles=self.max_tiles)
                if guard.estimated_tiles(approach) > self.max_tiles:
                    return float("inf")
            return simulate_partition(pp, self.topo, approach, algorithm,
                                      self.chip_graph).makespan
        except (CompileError, ScheduleError, ValueError):
            return float("inf")


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #


def _parse_shape(text: str, kernel: str) -> tuple[int, ...]:
    dims = tuple(int(x) for x in text.lower().split("x"))
    want = 3 if kernel == "gemm" else 2
    if len(dims) != want:
        raise argparse.ArgumentTypeError(
            f"{kernel} shape needs {want} dims (got {text!r})")
    return dims


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fabric.simulate",
        description="Event-driven multi-chip schedule simulator: partition "
                    "a GEMM/GRU, lower the implied collectives, replay "
                    "per-chip schedules + fabric phases, report makespans "
                    "vs the 1-chip schedule.")
    ap.add_argument("--shape", default="5124x700x2048",
                    help="MxNxK for gemm, BATCHxHIDDEN for gru")
    ap.add_argument("--kernel", choices=["gemm", "gru"], default="gemm")
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--topology", choices=["ring", "torus", "host"],
                    default="ring")
    ap.add_argument("--axis", default="all",
                    help="partition axis (m|n|k|batch) or 'all'")
    ap.add_argument("--algorithm", choices=("best",) + ALGORITHMS,
                    default="best",
                    help="collective algorithm ('best' tries all and "
                         "reports the winner per axis)")
    ap.add_argument("--replicate-out", action="store_true",
                    help="require the output replicated on every chip "
                         "(k: all-reduce; m/n/batch: output all-gather) "
                         "instead of the default sharded-output contract")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip the proxy-sized bit-exact oracle replay")
    ap.add_argument("--proxy-cap", type=int, default=VALIDATE_DIM_CAP,
                    help="per-axis size cap for the oracle proxy")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    shape = _parse_shape(args.shape, args.kernel)
    topo = make_topology(args.topology, args.chips)
    axes = partition_axes(args.kernel) if args.axis == "all" else (args.axis,)
    algorithms = ALGORITHMS if args.algorithm == "best" else (args.algorithm,)
    chip_graph = Topology.chip_graph()

    base_pp = partition(args.kernel, shape, axes[0], 1)
    one_chip = single_chip_makespan(base_pp, chip_graph)
    print(f"# fabric simulate: kernel={args.kernel} shape={args.shape} "
          f"chips={args.chips} topology={topo.name} "
          f"contract={'replicated' if args.replicate_out else 'sharded'}-out")
    print(f"# 1-chip modeled makespan: {one_chip:.3e} s")

    rows = []
    failures = 0
    best_row = None
    for axis in axes:
        pp = partition(args.kernel, shape, axis, args.chips)
        if args.replicate_out:
            pp = replicate_output(pp)
        results = [simulate_partition(pp, topo, None, alg, chip_graph)
                   for alg in algorithms]
        res = min(results, key=lambda r: r.makespan)
        exact = None
        if not args.no_validate:
            proxy_shape = tuple(max(args.chips, min(d, args.proxy_cap))
                                for d in shape)
            proxy = partition(args.kernel, proxy_shape, axis, args.chips)
            if args.replicate_out:
                proxy = replicate_output(proxy)
            report = replay_bitexact(proxy, chip_graph)
            exact = report.exact
            if not exact:
                failures += 1
        speedup = one_chip / res.makespan if res.makespan else float("inf")
        row = {"axis": axis, "algorithm": res.algorithm,
               "makespan_s": res.makespan, "one_chip_s": one_chip,
               "speedup": speedup, "comm_end_s": res.comm_end,
               "comm_bound": res.comm_bound,
               "collective_steps": res.n_collective_steps,
               "tasks": res.n_tasks, "oracle_exact": exact}
        rows.append(row)
        if best_row is None or row["makespan_s"] < best_row["makespan_s"]:
            best_row = row
        vtxt = "-" if exact is None else ("exact" if exact else "MISMATCH")
        mark = "<" if speedup > 1.0 else ">="
        print(f"axis={axis:<5} alg={res.algorithm:<5} "
              f"makespan={res.makespan:.3e}s ({mark} 1-chip, "
              f"speedup={speedup:.2f}x) comm_end={res.comm_end:.3e}s "
              f"oracle={vtxt}")
    if best_row:
        print(f"# best: axis={best_row['axis']} alg={best_row['algorithm']} "
              f"speedup={best_row['speedup']:.2f}x")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "kernel": args.kernel,
                       "shape": list(shape), "chips": args.chips,
                       "topology": topo.name,
                       "replicate_out": bool(args.replicate_out),
                       "one_chip_s": one_chip, "failures": failures,
                       "rows": rows}, f, indent=2)
        print(f"# report: {args.json}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
