"""First-class fabric descriptions (ICI ring, 2D torus, PCIe host tree).

A ``Topology`` names the chips of a multi-chip system and the directed
*fabric links* between them.  It is the single source of truth for
inter-chip wiring: ``build_graph()`` produces the multi-chip
``SystemGraph`` the scheduler/simulator dry-runs against (replacing the
ad-hoc ring wiring ``sysgraph.tpu_v5e`` used to hard-code), and
``path()``/``ring_order`` feed the collective lowering in
``collectives.py``.

Bandwidth model: a v5e chip has ``ICI_PORTS_PER_CHIP`` ICI ports of
``V5E_ICI_BW`` each (per direction).  A topology splits the ports evenly
across its distinct neighbours and *bonds* them, so a 1D ring (2
neighbours) gets 2x the per-port bandwidth on each link, a 2-chip ring
(1 neighbour) bonds all 4 ports, and a full 2D torus (4 neighbours) runs
one port per link.  The host tree has no ICI at all — chips talk through
host memory over PCIe, which is exactly why it loses the scaling sweeps.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.sysgraph import V5E_ICI_BW, SystemGraph, add_v5e_chip

#: ICI ports per chip (v5e: 4), each V5E_ICI_BW per direction.
ICI_PORTS_PER_CHIP = 4

#: Default per-hop ICI issue latency (sec).
ICI_LATENCY = 1e-6

#: PCIe bandwidth / latency for host-tree fabrics (matches sysgraph's
#: host<->HBM edges).
PCIE_BW = 32e9
PCIE_LATENCY = 2e-6


@dataclass(frozen=True)
class Link:
    """One directed fabric link.  Endpoints are ``"chip<i>"`` or ``"host"``."""

    src: str
    dst: str
    bandwidth: float               # bytes / sec
    latency: float                 # sec per transfer issue


def _chip(i: int) -> str:
    return f"chip{i}"


@dataclass(frozen=True)
class Topology:
    """A named fabric over ``n_chips`` v5e chips.

    ``ring_order`` is a communication cycle over the chips used by the
    ring-based collective algorithms; consecutive chips are adjacent in
    the fabric whenever the topology admits it (ring: trivially; torus:
    a snake cycle), otherwise ``path()`` routes each logical hop over
    multiple physical links (host tree: every hop goes through host).
    """

    name: str
    n_chips: int
    links: tuple[Link, ...]
    ring_order: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.ring_order:
            object.__setattr__(self, "ring_order", tuple(range(self.n_chips)))

    # -- queries --------------------------------------------------------------
    def link(self, src: str, dst: str) -> Link:
        for l in self.links:
            if l.src == src and l.dst == dst:
                return l
        raise KeyError(f"no fabric link {src} -> {dst}")

    def neighbors(self, node: str) -> list[str]:
        return [l.dst for l in self.links if l.src == node]

    def path(self, src_chip: int, dst_chip: int) -> list[Link]:
        """Fewest-hops route between two chips (BFS over fabric links)."""
        src, dst = _chip(src_chip), _chip(dst_chip)
        if src == dst:
            return []
        prev: dict[str, Link] = {}
        frontier, seen = [src], {src}
        while frontier and dst not in prev:
            nxt = []
            for u in frontier:
                for l in self.links:
                    if l.src == u and l.dst not in seen:
                        seen.add(l.dst)
                        prev[l.dst] = l
                        nxt.append(l.dst)
            frontier = nxt
        if dst not in prev:
            raise KeyError(f"no fabric path {src} -> {dst}")
        path, cur = [], dst
        while cur != src:
            l = prev[cur]
            path.append(l)
            cur = l.src
        return list(reversed(path))

    def min_link_bandwidth(self) -> float:
        chip_links = [l for l in self.links
                      if l.src != "host" and l.dst != "host"]
        return min((l.bandwidth for l in chip_links), default=PCIE_BW)

    # -- SystemGraph construction ---------------------------------------------
    def wire_ici(self, g: SystemGraph) -> None:
        """Add this topology's chip-to-chip links to an existing multi-chip
        graph as HBM<->HBM movement edges.  Each directed copy is issued by
        the *receiving* chip's core (pull-style ICI DMA) — the per-direction
        issuer the old ad-hoc wiring got wrong.  Host links are skipped
        (``add_v5e_chip`` already wires PCIe)."""
        for l in self.links:
            if l.src == "host" or l.dst == "host":
                continue
            a, b = int(l.src[4:]), int(l.dst[4:])
            g.add_edge(f"hbm{a}", f"hbm{b}", bandwidth=l.bandwidth,
                       latency=l.latency, issuer=f"core{b}",
                       bidirectional=False)

    def build_graph(self, host_mem: int = 512 << 30) -> SystemGraph:
        """The multi-chip SystemGraph: one v5e chip per fabric chip plus
        this topology's ICI edges."""
        g = SystemGraph(f"tpu_v5e_{self.name}")
        g.add_memory("host", host_mem, level=0)
        for c in range(self.n_chips):
            add_v5e_chip(g, c)
        self.wire_ici(g)
        return g

    @staticmethod
    def chip_graph() -> SystemGraph:
        """A single-chip graph for the per-chip static scheduler."""
        from ..core.sysgraph import tpu_v5e
        return tpu_v5e(1)


def _bond(n_neighbors: int) -> int:
    return max(1, ICI_PORTS_PER_CHIP // max(1, n_neighbors))


def ring(n_chips: int, ici_bw: float = V5E_ICI_BW,
         latency: float = ICI_LATENCY) -> Topology:
    """1D bidirectional ICI ring.  With 2 distinct neighbours per chip the
    4 ports bond pairwise (2x per-port bandwidth per link); the degenerate
    2-chip ring bonds all 4 ports onto its single neighbour."""
    if n_chips < 1:
        raise ValueError("ring needs at least 1 chip")
    links: list[Link] = []
    if n_chips > 1:
        n_nb = 1 if n_chips == 2 else 2
        bw = _bond(n_nb) * ici_bw
        for i in range(n_chips):
            j = (i + 1) % n_chips
            links.append(Link(_chip(i), _chip(j), bw, latency))
            links.append(Link(_chip(j), _chip(i), bw, latency))
            if n_chips == 2:
                break                      # one bonded pair, both directions
    return Topology(f"ring{n_chips}", n_chips, tuple(links))


def torus(rows: int, cols: int, ici_bw: float = V5E_ICI_BW,
          latency: float = ICI_LATENCY) -> Topology:
    """2D torus (row-major chip ids).  Degenerate 1-wide dims collapse to a
    ring; 2-wide dims fold their wraparound onto the direct link (bonded).
    ``ring_order`` is the row-major snake cycle the ring collectives run
    over."""
    n = rows * cols
    if n < 1:
        raise ValueError("torus needs at least 1 chip")
    if rows == 1 or cols == 1:
        t = ring(n, ici_bw, latency)
        return Topology(f"torus{rows}x{cols}", n, t.links, t.ring_order)
    # Every chip fields 4 link endpoints (2 per dim, wraps included); 2-wide
    # dims fold both endpoints onto the same neighbour pair, which then
    # bonds the ports of both parallel cables.
    per_pair: dict[tuple[int, int], int] = {}
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for j in (r * cols + (c + 1) % cols, ((r + 1) % rows) * cols + c):
                if i == j:
                    continue
                pair = (min(i, j), max(i, j))
                per_pair[pair] = per_pair.get(pair, 0) + 1
    unit = ICI_PORTS_PER_CHIP / 4 * ici_bw     # ports spread over 4 endpoints
    links: list[Link] = []
    for (i, j), mult in sorted(per_pair.items()):
        bw = mult * unit
        links.append(Link(_chip(i), _chip(j), bw, latency))
        links.append(Link(_chip(j), _chip(i), bw, latency))
    # snake cycle: row-major, odd rows reversed; consecutive cells adjacent
    order: list[int] = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend(r * cols + c for c in cs)
    return Topology(f"torus{rows}x{cols}", n, tuple(links), tuple(order))


def host_tree(n_chips: int, pcie_bw: float = PCIE_BW,
              latency: float = PCIE_LATENCY) -> Topology:
    """No ICI: every chip hangs off the host over PCIe.  Collectives route
    every hop through host memory — the fabric that shows why direct
    interconnect matters."""
    links: list[Link] = []
    for i in range(n_chips):
        links.append(Link(_chip(i), "host", pcie_bw, latency))
        links.append(Link("host", _chip(i), pcie_bw, latency))
    return Topology(f"host{n_chips}", n_chips, tuple(links))


def make_topology(name: str, n_chips: int) -> Topology:
    """CLI dispatcher: ``ring`` | ``torus`` (squarest rows x cols factoring)
    | ``host``."""
    if name == "ring":
        return ring(n_chips)
    if name == "torus":
        rows = 1
        for r in range(int(n_chips ** 0.5), 0, -1):
            if n_chips % r == 0:
                rows = r
                break
        return torus(rows, n_chips // rows)
    if name == "host":
        return host_tree(n_chips)
    raise ValueError(f"unknown topology {name!r} (ring|torus|host)")
