"""Shard an ISAMIR program across chips + the collectives each choice implies.

The contract is SPMD with *sharded outputs* (the same contract
``repro.dist`` uses between layers: activations stay distributed, a
collective is inserted only where the math demands one):

  GEMM ``C[m,n] += A[m,k] * B[k,n]`` (A arrives m-sharded — the natural
  activation layout coming out of a previous data-parallel layer; B is the
  weight):

    * ``m``-sharding — chip *i* gets A's row block and full B, computes
      C's row block.  Purely data-parallel: **no collective**.
    * ``n``-sharding — column-parallel B; every chip needs *all* of A, so
      the m-sharded operand is **all-gathered** first.  C ends n-sharded.
    * ``k``-sharding — A column-/B row-sharded; every chip computes a
      full-size *partial* C which must be summed: a **reduce-scatter**
      leaves C m-sharded (``--replicate-out`` upgrades it to the full
      all-reduce).

  GRU (batch-sharding) — weights replicated, X/H row-sharded: pure data
  parallelism, no collective.

Bit-exact re-materialization: the sharded outputs must replay **bit-exact**
against the single-chip ISAMIR oracle.  Concatenation axes (m/n/batch) are
exact trivially; the k reduction is exact because the collective's numeric
semantics are defined as *ordered* accumulation (chip 0 first — the same
deterministic-reduction contract XLA offers), which ``replay_bitexact``
realizes by chaining the running C through the chips: a left fold over
chip partials extends the oracle's ascending-k left fold exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..compile import compile_selection, select_program
from ..core import instructions as I
from ..core import kernels_ir as K
from ..core.dtypes import dtype_bytes
from ..core.executor import Machine
from ..core.ir import Program, interpret, random_inputs
from ..core.isel import Selection
from ..core.scheduler import Schedule
from ..core.sysgraph import SystemGraph

GEMM_AXES = ("m", "n", "k")
GRU_AXES = ("batch",)


def split_extent(size: int, n: int) -> list[tuple[int, int]]:
    """(offset, length) per shard: balanced blocks — the first ``size % n``
    shards take one extra element, so every shard stays non-empty."""
    if n > size:
        raise ValueError(f"cannot split extent {size} into {n} shards")
    base, rem = divmod(size, n)
    out, off = [], 0
    for i in range(n):
        ln = base + (1 if i < rem else 0)
        out.append((off, ln))
        off += ln
    return out


@dataclass(frozen=True)
class CollectiveSpec:
    """One collective the partition choice implies.

    ``chunks`` are (offset, length) blocks of ``buffer`` along ``axis`` —
    chunk *i* is owned by (pre) / reduced onto (post) chip *i*.
    """

    kind: str                  # 'all_gather' | 'reduce_scatter' | 'all_reduce'
    buffer: str
    when: str                  # 'pre' (operand) | 'post' (output)
    axis: int
    chunks: tuple[tuple[int, int], ...]

    def chunk_nbytes(self, base: Program) -> list[int]:
        """Bytes of each chunk, from the global buffer's shape/dtype."""
        buf = base.buffer(self.buffer)
        per_unit = dtype_bytes(buf.dtype)
        for d, s in enumerate(buf.shape):
            if d != self.axis:
                per_unit *= s
        return [length * per_unit for _, length in self.chunks]


@dataclass(frozen=True)
class Shard:
    """One chip's subprogram + how to slice the global inputs for it."""

    chip: int
    program: Program
    slices: dict[str, tuple[slice, ...]] = field(default_factory=dict)


@dataclass
class PartitionedProgram:
    base: Program                       # the full single-chip program
    kernel: str                         # 'gemm' | 'gru'
    axis: str
    n_chips: int
    shards: list[Shard]
    collectives: list[CollectiveSpec]
    out_mode: str                       # 'concat' | 'chain_sum'
    out_axis: int = 0

    @property
    def output(self) -> str:
        return self.base.outputs[0]

    def shard_selection(self, shard: Shard) -> Selection:
        """Instruction selection for one shard, through the ``repro.compile``
        Map/Select passes (memoized per shape)."""
        key = shard.program.signature()
        memo = getattr(self, "_sel_memo", None)
        if memo is None:
            memo = {}
            self._sel_memo = memo
        if key not in memo:
            if self.kernel == "gemm":
                memo[key] = select_program(
                    shard.program, [I.mxu_matmul()], allow_transforms=False)
            else:
                memo[key] = select_program(shard.program, I.tpu_isa())
        return memo[key]


def _full(nd: int) -> tuple[slice, ...]:
    return tuple(slice(None) for _ in range(nd))


def _slc(nd: int, dim: int, off: int, ln: int) -> tuple[slice, ...]:
    out = [slice(None)] * nd
    out[dim] = slice(off, off + ln)
    return tuple(out)


def partition_gemm(m: int, n: int, k: int, axis: str,
                   n_chips: int) -> PartitionedProgram:
    if axis not in GEMM_AXES:
        raise ValueError(f"GEMM partition axis must be one of {GEMM_AXES}")
    base = K.matmul(m, n, k)
    if n_chips == 1:
        return PartitionedProgram(base, "gemm", axis, 1,
                                  [Shard(0, base, {"A": _full(2),
                                                   "B": _full(2),
                                                   "C": _full(2)})],
                                  [], "concat", 0)
    size = {"m": m, "n": n, "k": k}[axis]
    blocks = split_extent(size, n_chips)
    shards: list[Shard] = []
    for i, (off, ln) in enumerate(blocks):
        if axis == "m":
            prog = K.matmul(ln, n, k)
            slices = {"A": _slc(2, 0, off, ln), "B": _full(2),
                      "C": _slc(2, 0, off, ln)}
        elif axis == "n":
            prog = K.matmul(m, ln, k)
            slices = {"A": _full(2), "B": _slc(2, 1, off, ln),
                      "C": _slc(2, 1, off, ln)}
        else:  # k
            prog = K.matmul(m, n, ln)
            slices = {"A": _slc(2, 1, off, ln), "B": _slc(2, 0, off, ln),
                      "C": _full(2)}
        shards.append(Shard(i, prog, slices))
    collectives: list[CollectiveSpec] = []
    if axis == "n":
        # A arrives m-sharded; every chip needs all of it.
        collectives.append(CollectiveSpec(
            "all_gather", "A", "pre", 0, tuple(split_extent(m, n_chips))))
    elif axis == "k":
        # Partial Cs must be summed; the output contract leaves C m-sharded.
        collectives.append(CollectiveSpec(
            "reduce_scatter", "C", "post", 0, tuple(split_extent(m, n_chips))))
    out_mode = "chain_sum" if axis == "k" else "concat"
    out_axis = {"m": 0, "n": 1, "k": 0}[axis]
    return PartitionedProgram(base, "gemm", axis, n_chips, shards,
                              collectives, out_mode, out_axis)


def partition_gru(batch: int, hidden: int, inp: int | None = None,
                  axis: str = "batch",
                  n_chips: int = 1) -> PartitionedProgram:
    if axis not in GRU_AXES:
        raise ValueError(f"GRU partition axis must be one of {GRU_AXES}")
    inp = hidden if inp is None else inp
    base = K.gru_cell(batch, hidden, inp)
    sharded_rank2 = {"X", "H"}           # batch-major activations
    blocks = split_extent(batch, n_chips)
    shards: list[Shard] = []
    for i, (off, ln) in enumerate(blocks):
        prog = K.gru_cell(ln, hidden, inp)
        slices: dict[str, tuple[slice, ...]] = {}
        for b in base.buffers:
            if b.temp:
                continue
            if b.name in sharded_rank2:
                slices[b.name] = _slc(2, 0, off, ln)
            elif b.name != base.outputs[0]:
                slices[b.name] = _full(b.rank)
        shards.append(Shard(i, prog, slices))
    # Weights are replicated and the hidden state stays batch-sharded:
    # pure data parallelism, no collective.
    return PartitionedProgram(base, "gru", axis, n_chips, shards, [],
                              "concat", 0)


def partition(kernel: str, shape: tuple[int, ...], axis: str,
              n_chips: int) -> PartitionedProgram:
    if kernel == "gemm":
        m, n, k = shape
        return partition_gemm(m, n, k, axis, n_chips)
    if kernel == "gru":
        batch, hidden = shape[0], shape[1]
        return partition_gru(batch, hidden, axis=axis, n_chips=n_chips)
    raise ValueError(f"unknown kernel {kernel!r} (gemm|gru)")


def partition_axes(kernel: str) -> tuple[str, ...]:
    return GEMM_AXES if kernel == "gemm" else GRU_AXES


# --------------------------------------------------------------------------- #
# Bit-exact re-materialization against the single-chip oracle
# --------------------------------------------------------------------------- #


def _execute_f64(sched: Schedule, selection: Selection,
                 inputs: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """core.executor replay that keeps the f64 home arrays (the public
    ``execute`` casts to f32 per call; chained shards must fold in f64 and
    cast exactly once, like the oracle)."""
    machine = Machine(sched, inputs)
    for op in sched.ops:
        machine.run_op(op, selection)
    return {name: machine.home_data[name].copy()
            for name in sched.program.outputs}


def replay_sharded(pp: PartitionedProgram, graph: SystemGraph,
                   approach=None,
                   inputs: dict[str, np.ndarray] | None = None,
                   rng_seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Execute every shard through the scheduled-stream executor and
    re-materialize the global output.  Returns ``(sharded, oracle)`` as the
    output dtype — equality between them is the bit-exactness contract."""
    rng = np.random.default_rng(rng_seed)
    ins = dict(inputs) if inputs is not None else random_inputs(pp.base, rng)
    oracle = interpret(pp.base, ins)[pp.output]

    out_name = pp.output
    if pp.out_mode == "chain_sum":
        running = np.array(ins.get(out_name,
                                   np.zeros(pp.base.buffer(out_name).shape)),
                           dtype=np.float64)
        for shard in pp.shards:               # ordered accumulation: chip 0 first
            sins = {name: np.asarray(ins[name], np.float64)[sl]
                    for name, sl in shard.slices.items()
                    if name != out_name and name in ins}
            sins[out_name] = running
            sel = pp.shard_selection(shard)
            sched = compile_selection(sel, graph, approach).schedule
            running = _execute_f64(sched, sel, sins)[out_name]
        final = running
    else:
        parts = []
        for shard in pp.shards:
            sins = {name: np.asarray(ins[name], np.float64)[sl]
                    for name, sl in shard.slices.items() if name in ins}
            sel = pp.shard_selection(shard)
            sched = compile_selection(sel, graph, approach).schedule
            parts.append(_execute_f64(sched, sel, sins)[out_name])
        final = np.concatenate(parts, axis=pp.out_axis)
    return final.astype(oracle.dtype), oracle


def replay_bitexact(pp: PartitionedProgram, graph: SystemGraph,
                    approach=None, rng_seed: int = 0):
    """``ValidationReport``-shaped check of the re-materialization contract."""
    from ..search.evaluate import ValidationReport
    got, ref = replay_sharded(pp, graph, approach, rng_seed=rng_seed)
    exact = bool(np.array_equal(got, ref))
    diff = np.abs(np.asarray(got, np.float64) - np.asarray(ref, np.float64))
    return ValidationReport(exact=exact,
                            max_abs_err=float(diff.max()) if diff.size else 0.0,
                            outputs=(pp.output,))
