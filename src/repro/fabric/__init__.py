"""repro.fabric — multi-chip fabric modeling, collective lowering, and an
event-driven distributed schedule simulator.

The single-chip stack (mapper → instruction selection → static scheduler)
stops at one chip's memory hierarchy; this package adds the communication
layer the paper names alongside "instruction sets ... and memory
architectures":

  * ``topology``    — first-class fabric descriptions (1D ICI ring, 2D
                      torus, PCIe host tree) that generate multi-chip
                      ``SystemGraph``s and expose per-link bandwidth/latency;
  * ``partition``   — shard a GEMM/GRU ISAMIR program along m/n/k (or batch)
                      into per-chip subprograms plus the collectives each
                      choice implies, with a bit-exact re-materialization
                      contract against the single-chip oracle;
  * ``collectives`` — ring / bidirectional-ring all-gather, reduce-scatter
                      and all-reduce lowered to COPY streams over fabric
                      links, with closed-form cost models;
  * ``simulate``    — an event-driven simulator replaying per-chip static
                      schedules plus collective phases on per-link/per-core
                      timelines (``python -m repro.fabric.simulate``).

``simulate`` is imported lazily (it pulls in ``repro.search``); the other
modules are dependency-light and safe to import from ``core``.
"""
from .collectives import (ALGORITHMS, CollectiveStep, all_gather_time,
                          all_reduce_time, reduce_scatter_time)
from .partition import PartitionedProgram, partition_gemm, partition_gru
from .topology import Link, Topology, host_tree, make_topology, ring, torus

__all__ = [
    "ALGORITHMS", "CollectiveStep", "Link", "PartitionedProgram", "Topology",
    "all_gather_time", "all_reduce_time", "host_tree", "make_topology",
    "partition_gemm", "partition_gru", "reduce_scatter_time", "ring", "torus",
]
