"""Ring collectives lowered to COPY streams over fabric links.

The lowering is algorithmic, not magical: an all-gather / reduce-scatter /
all-reduce over *p* chips becomes an explicit list of ``CollectiveStep``
sends — (step, src chip, dst chip, chunk, bytes) — that the fabric
simulator replays on per-link timelines with real dependencies (a chip can
only forward a chunk it has received; a reduce hop also waits for the
receiver's local partial).  Two algorithms:

  * ``ring``  — the classic unidirectional ring: p-1 serialized steps, each
                link carrying one chunk per step.
  * ``bidir`` — both ring directions at once.  All-gather halves the *step
                count* (a chunk only travels ceil((p-1)/2) hops); reduce-
                scatter halves the *per-step bytes* (each chunk splits into
                a clockwise and a counter-clockwise half).

Closed-form cost models (the textbook alpha-beta terms) are provided for
sanity checks and quick what-ifs; the simulator is the ground truth because
it sees link contention and compute/communication overlap.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

ALGORITHMS = ("ring", "bidir")

CW, CCW = 0, 1


@dataclass(frozen=True)
class CollectiveStep:
    """One chunk-send between ring-adjacent chips.

    ``src``/``dst`` are *positions in the ring order* resolved by the
    caller; ``direction`` separates the clockwise and counter-clockwise
    streams (distinct physical links); ``reduce`` marks hops that fold the
    arriving data into the receiver's local partial (reduce-scatter), which
    adds a dependency on that partial being computed.
    """

    phase: str
    step: int
    src: int
    dst: int
    chunk: int
    nbytes: int
    direction: int = CW
    reduce: bool = False


def _send(p: int, i: int, direction: int) -> int:
    return (i + 1) % p if direction == CW else (i - 1) % p


def lower_all_gather(p: int, chunk_nbytes: list[int], algorithm: str = "ring",
                     phase: str = "ag") -> list[CollectiveStep]:
    """Chunk *c* starts on chip *c* and must reach every chip."""
    if p <= 1:
        return []
    steps: list[CollectiveStep] = []
    if algorithm == "bidir":
        cw_hops = math.ceil((p - 1) / 2)
        ccw_hops = (p - 1) // 2
        for s in range(cw_hops):
            for i in range(p):
                c = (i - s) % p
                steps.append(CollectiveStep(phase, s, i, _send(p, i, CW), c,
                                            chunk_nbytes[c], CW))
        for s in range(ccw_hops):
            for i in range(p):
                c = (i + s) % p
                steps.append(CollectiveStep(phase, s, i, _send(p, i, CCW), c,
                                            chunk_nbytes[c], CCW))
    else:
        for s in range(p - 1):
            for i in range(p):
                c = (i - s) % p
                steps.append(CollectiveStep(phase, s, i, _send(p, i, CW), c,
                                            chunk_nbytes[c], CW))
    return steps


def lower_reduce_scatter(p: int, chunk_nbytes: list[int],
                         algorithm: str = "ring",
                         phase: str = "rs") -> list[CollectiveStep]:
    """Every chip holds a partial of every chunk; after the exchange chip
    *i* owns the fully reduced chunk ``(i+1) % p`` (cw half).  ``bidir``
    splits each chunk into a cw and a ccw half reduced simultaneously."""
    if p <= 1:
        return []
    steps: list[CollectiveStep] = []
    directions = ((CW, 1.0),) if algorithm != "bidir" \
        else ((CW, 0.5), (CCW, 0.5))
    for direction, frac in directions:
        for s in range(p - 1):
            for i in range(p):
                c = (i - s) % p if direction == CW else (i + s) % p
                nb = max(1, int(chunk_nbytes[c] * frac))
                steps.append(CollectiveStep(phase, s, i,
                                            _send(p, i, direction), c, nb,
                                            direction, reduce=True))
    return steps


def lower_all_reduce(p: int, chunk_nbytes: list[int],
                     algorithm: str = "ring",
                     phase: str = "ar") -> list[CollectiveStep]:
    """Reduce-scatter then all-gather of the reduced chunks.  The gather
    steps continue the per-(chunk, direction) chains started by the
    reduce — chip *i* owns cw-chunk ``(i+1) % p`` when the reduce ends, so
    the gather rotation starts there."""
    if p <= 1:
        return []
    steps = lower_reduce_scatter(p, chunk_nbytes, algorithm, phase)
    directions = {st.direction for st in steps} or {CW}
    for direction in sorted(directions):
        frac = 0.5 if len(directions) > 1 else 1.0
        for s in range(p - 1):
            for i in range(p):
                if direction == CW:
                    c = (i + 1 - s) % p
                else:
                    c = (i - 1 + s) % p
                nb = max(1, int(chunk_nbytes[c] * frac))
                steps.append(CollectiveStep(phase, (p - 1) + s, i,
                                            _send(p, i, direction), c, nb,
                                            direction, reduce=False))
    return steps


# --------------------------------------------------------------------------- #
# Closed-form alpha-beta cost models
# --------------------------------------------------------------------------- #


def all_gather_time(p: int, nbytes: int, bandwidth: float,
                    latency: float = 1e-6, algorithm: str = "ring") -> float:
    """Serialized ring steps of one chunk (= nbytes / p) each."""
    if p <= 1:
        return 0.0
    chunk = nbytes / p
    hops = math.ceil((p - 1) / 2) if algorithm == "bidir" else p - 1
    return hops * (latency + chunk / bandwidth)


def reduce_scatter_time(p: int, nbytes: int, bandwidth: float,
                        latency: float = 1e-6,
                        algorithm: str = "ring") -> float:
    if p <= 1:
        return 0.0
    chunk = nbytes / p
    if algorithm == "bidir":
        return (p - 1) * (latency + chunk / (2 * bandwidth))
    return (p - 1) * (latency + chunk / bandwidth)


def all_reduce_time(p: int, nbytes: int, bandwidth: float,
                    latency: float = 1e-6, algorithm: str = "ring") -> float:
    if p <= 1:
        return 0.0
    chunk = nbytes / p
    if algorithm == "bidir":
        return 2 * (p - 1) * (latency + chunk / (2 * bandwidth))
    return 2 * (p - 1) * (latency + chunk / bandwidth)
