"""The ``CompiledKernel`` artifact — what the compilation driver produces.

One artifact captures everything the pipeline decided for a (program, system
graph, approach) triple:

  * the per-instruction **tile plan**, keyed by *mapped axis roles*: each
    selected instruction records its needle→haystack ``axis_map`` and the
    tile size the scheduler settled on per *needle* axis.  Consumers ask for
    roles (``i``/``j``/``k`` of ``mxu.matmul``) instead of guessing haystack
    axis names, so conv-extraction programs with fused axis names resolve
    exactly like plain GEMMs;
  * the **lowering config** — for matmul-shaped programs, the Pallas
    BlockSpec block + grid the kernels use; otherwise the generic
    instruction-stream marker;
  * the modeled **cost** (static-scheduler makespan) plus op counts and
    bytes moved;
  * for multi-chip compiles, the **fabric plan**: partition axis, collective
    specs, algorithm, per-chip tiles and the simulated distributed makespan.

Artifacts serialize to plain JSON dicts (``to_dict``/``from_dict``) so the
persistent artifact cache can replay a compile across processes.  Live
compiles additionally attach the in-memory ``selection``/``schedule``;
cache-hydrated artifacts rebuild them on demand via ``ensure_schedule()``
(deterministic: same program, graph and approach ⇒ the same schedule).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

ARTIFACT_SCHEMA = 1


class CompileError(RuntimeError):
    """A pipeline pass could not produce its required result."""


@dataclass(frozen=True)
class InstrPlan:
    """The tile decision for one selected instruction, keyed by axis role.

    ``axis_map`` maps needle (role) axes to haystack axes; ``tile`` holds the
    scheduler's chosen tile extent per *needle* axis.  ``outer_axes`` are the
    unmapped haystack axes the instruction is re-invoked over.
    """

    needle: str
    axis_map: tuple[tuple[str, str], ...]      # (needle axis, haystack axis)
    tile: tuple[tuple[str, int], ...]          # (needle axis, tile size)
    outer_axes: tuple[str, ...]
    calls: int

    def tile_for(self, role: str) -> int:
        for axis, size in self.tile:
            if axis == role:
                return size
        raise CompileError(
            f"instruction {self.needle} has no mapped axis for role "
            f"{role!r} (mapped roles: {[a for a, _ in self.tile]})")

    def to_dict(self) -> dict:
        return {"needle": self.needle,
                "axis_map": [list(p) for p in self.axis_map],
                "tile": [list(p) for p in self.tile],
                "outer_axes": list(self.outer_axes),
                "calls": self.calls}

    @classmethod
    def from_dict(cls, d: dict) -> "InstrPlan":
        return cls(needle=d["needle"],
                   axis_map=tuple((a, h) for a, h in d.get("axis_map", [])),
                   tile=tuple((a, int(s)) for a, s in d.get("tile", [])),
                   outer_axes=tuple(d.get("outer_axes", [])),
                   calls=int(d.get("calls", 1)))


@dataclass
class CompiledKernel:
    """Serializable result of one trip through the compilation pipeline."""

    key: str                          # artifact-cache key
    program_name: str
    program_fp: str
    graph_name: str
    graph_fp: str
    approach_fp: str
    backend: str
    cost: float                       # modeled makespan (seconds)
    instrs: tuple[InstrPlan, ...]
    counts: dict = field(default_factory=dict)
    bytes_moved: int = 0
    lowering: dict = field(default_factory=dict)
    fabric: dict | None = None
    meta: dict = field(default_factory=dict)
    from_cache: bool = False

    # live (non-serialized) attachments — present on fresh compiles, rebuilt
    # lazily on cache hits
    program: Any = field(default=None, repr=False, compare=False)
    graph: Any = field(default=None, repr=False, compare=False)
    approach: Any = field(default=None, repr=False, compare=False)
    isa: Any = field(default=None, repr=False, compare=False)
    selection: Any = field(default=None, repr=False, compare=False)
    schedule: Any = field(default=None, repr=False, compare=False)

    # -- the role-keyed tile plan -------------------------------------------
    def instr_plan(self, needle_prefix: str) -> InstrPlan:
        for p in self.instrs:
            if p.needle.startswith(needle_prefix):
                return p
        raise CompileError(
            f"no selected instruction matches {needle_prefix!r} "
            f"(have: {[p.needle for p in self.instrs]})")

    def gemm_tile(self) -> tuple[int, int, int]:
        """The (bm, bn, bk) tile of the matmul instruction, derived from the
        mapping's axis roles — raises ``CompileError`` on programs with no
        matmul-mapped instruction or with an incomplete role map."""
        plan = self.instr_plan("mxu.matmul")
        return (plan.tile_for("i"), plan.tile_for("j"), plan.tile_for("k"))

    # -- lazy schedule rebuild ----------------------------------------------
    def ensure_schedule(self):
        """Materialize the selection/schedule for this artifact.  Fresh
        compiles carry them already; cache-hydrated artifacts re-run the
        (deterministic) pipeline from the attached program/graph/approach."""
        if self.schedule is not None:
            return self.schedule
        if self.program is None or self.graph is None:
            raise CompileError(
                "cache-hydrated artifact has no program/graph attached; "
                "re-compile through the driver to replay its schedule")
        from .driver import recompile_schedule
        recompile_schedule(self)
        return self.schedule

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        d = {"schema": ARTIFACT_SCHEMA, "key": self.key,
             "program_name": self.program_name, "program_fp": self.program_fp,
             "graph_name": self.graph_name, "graph_fp": self.graph_fp,
             "approach_fp": self.approach_fp, "backend": self.backend,
             "cost": self.cost,
             "instrs": [p.to_dict() for p in self.instrs],
             "counts": dict(self.counts), "bytes_moved": self.bytes_moved,
             "lowering": dict(self.lowering), "meta": dict(self.meta)}
        if self.fabric is not None:
            d["fabric"] = self.fabric
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompiledKernel":
        return cls(key=d["key"], program_name=d.get("program_name", ""),
                   program_fp=d.get("program_fp", ""),
                   graph_name=d.get("graph_name", ""),
                   graph_fp=d.get("graph_fp", ""),
                   approach_fp=d.get("approach_fp", ""),
                   backend=d.get("backend", "cost"),
                   cost=float(d.get("cost", 0.0)),
                   instrs=tuple(InstrPlan.from_dict(p)
                                for p in d.get("instrs", [])),
                   counts=dict(d.get("counts", {})),
                   bytes_moved=int(d.get("bytes_moved", 0)),
                   lowering=dict(d.get("lowering", {})),
                   fabric=d.get("fabric"),
                   meta=dict(d.get("meta", {})),
                   from_cache=True)

    def summary(self) -> str:
        tile = ""
        try:
            tile = f" tile={self.gemm_tile()}"
        except CompileError:
            pass
        src = "cache" if self.from_cache else "fresh"
        fab = (f" fabric(axis={self.fabric.get('axis')},"
               f"alg={self.fabric.get('algorithm')},"
               f"chips={self.fabric.get('chips')})" if self.fabric else "")
        return (f"{self.program_name} on {self.graph_name}: "
                f"cost={self.cost:.3e}s{tile}"
                f" lowering={self.lowering.get('kind', '-')}{fab} [{src}]")
