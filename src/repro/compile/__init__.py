"""repro.compile — the pass-based compilation driver.

One pipeline behind every entry point (the paper's single frontend → IR
passes → backend story, VTA/DL-compiler-survey style):

    Program ──Map──▶ candidates ──Select──▶ Selection ──Schedule──▶
        Schedule ──Lower──▶ CompiledKernel

  * ``pipeline``  — ``Pipeline`` + the Map / Select / Schedule / Lower
                    passes over a ``CompileContext``;
  * ``artifact``  — the serializable ``CompiledKernel``: role-keyed tile
                    plan (derived from each mapping's ``axis_map``), Pallas
                    lowering config, modeled cost, fabric plan;
  * ``cache``     — persistent artifact cache keyed by (program fp, sysgraph
                    fp, approach fp, backend, jax version), layered on the
                    ``repro.search`` fingerprinting;
  * ``driver``    — ``compile_program`` / ``compile_gemm`` / ``compile_gru``
                    / ``compile_conv`` / ``compile_selection`` /
                    ``compile_fabric`` and the workload frontends shared by
                    ``repro.kernels``, ``repro.search`` and ``repro.fabric``;
  * ``features``  — engineered feature vectors over (config, program,
                    graph) triples + ``CompiledKernel`` descriptors, the
                    input representation of the learned cost model
                    (``repro.search.model``).

CLI: ``python -m repro.compile --kernel gemm --shape 1024x1024x1024``.
"""
from .artifact import CompiledKernel, CompileError, InstrPlan
from .cache import (ArtifactCache, artifact_key, default_artifact_cache_path,
                    get_default_artifact_cache, set_default_artifact_cache)
from .driver import (compile_conv, compile_fabric, compile_gemm, compile_gru,
                     compile_program, compile_selection, conv_selection,
                     gemm_selection, gru_selection, resolve_approach,
                     select_program)
from .features import (artifact_features, feature_dict, feature_names,
                       feature_vector, program_family)
from .pipeline import (CompileContext, LowerPass, MapPass, Pipeline,
                       SchedulePass, SelectPass)

__all__ = [
    "ArtifactCache", "CompileContext", "CompiledKernel", "CompileError",
    "InstrPlan", "LowerPass", "MapPass", "Pipeline", "SchedulePass",
    "SelectPass", "artifact_features", "artifact_key", "compile_conv",
    "compile_fabric", "compile_gemm", "compile_gru", "compile_program",
    "compile_selection", "conv_selection", "default_artifact_cache_path",
    "feature_dict", "feature_names", "feature_vector", "gemm_selection",
    "get_default_artifact_cache", "gru_selection", "program_family",
    "resolve_approach", "select_program", "set_default_artifact_cache",
]
