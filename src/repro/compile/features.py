"""Engineered feature vectors for the learned cost model (``repro.search.model``).

One numeric view of a (config, program, system graph) triple, built from the
same quantities the analytical cost model consumes:

  * **config features** — the ParamApproach decision vector: tile caps as
    log2 multiples of the hardware matmul tile (with explicit "uncapped"
    flags, since ``None`` means "let the scheduler grow the tile"), the
    reduction-streaming flag, VMEM fraction, and one-hot unroll/device/source
    policies;
  * **program features** — log-scale FLOPs and footprint bytes, arithmetic
    intensity, statement/axis counts and the largest axis extents (so one
    model generalizes across shapes of a program family);
  * **graph features** — peak compute rate, VMEM/top-level capacities, and
    bandwidth/latency summaries of the movement edges.

Everything is computed from static structure (no scheduling, no jax), so a
prediction costs microseconds while a ``CostModelEvaluator`` call costs a
full schedule.  The feature *names* are part of the model artifact: a stored
model refuses to score vectors whose schema drifted.
"""
from __future__ import annotations

import math
import re

import numpy as np

from ..core.approach import (DEVICE_POLICIES, SOURCE_POLICIES,
                             UNROLL_POLICIES)
from ..core.dtypes import dtype_bytes
from ..core.ir import Program
from ..core.sysgraph import SystemGraph

#: Bumped whenever the feature definition changes — stored models carry it
#: and are ignored (graceful fallback) on mismatch.
FEATURE_SCHEMA = 1

#: How many of the largest axis extents become individual features.
_TOP_AXES = 4

_UNROLLS = tuple(sorted(UNROLL_POLICIES))
_DEVICES = tuple(DEVICE_POLICIES)
_SOURCES = tuple(SOURCE_POLICIES)


def _log10(x: float) -> float:
    return math.log10(max(float(x), 1.0))


def _log2ratio(cap, hw: int) -> float:
    """log2(cap / hw) for a tile cap, 0.0 when uncapped/degenerate."""
    try:
        cap = float(cap)
    except (TypeError, ValueError):
        return 0.0
    if cap <= 0 or hw <= 0:
        return 0.0
    return math.log2(cap / hw)


def program_family(prog: Program | str) -> str:
    """The shape-independent family name of a program: ``matmul_64x64x64``
    -> ``matmul``, ``gru_cell_16x256`` -> ``gru_cell``.  Model artifacts are
    keyed per family so one regression covers a whole suite of shapes."""
    name = prog if isinstance(prog, str) else prog.name
    return re.sub(r"_\d+(x\d+)*$", "", name) or name


def program_features(prog: Program) -> dict[str, float]:
    """Static workload descriptors: log FLOPs (statement work), log bytes
    (non-temp buffer footprint), intensity, and the largest axis extents."""
    flops = 0.0
    for stmt in prog.statements:
        used = set()
        for acc in (stmt.lhs, stmt.rhs):
            used |= acc.axes_used(prog.axis_names)
        work = 1.0
        for a in used:
            work *= max(1, prog.axis(a).size)
        flops += work
    nbytes = 0
    for buf in prog.buffers:
        if buf.temp:
            continue
        n = 1
        for d in buf.shape:
            n *= max(1, d)
        nbytes += n * dtype_bytes(buf.dtype)
    sizes = sorted((prog.axis(a).size for a in prog.axis_names),
                   reverse=True)
    feats = {
        "log_flops": _log10(flops),
        "log_bytes": _log10(nbytes),
        "log_intensity": _log10(flops) - _log10(nbytes),
        "n_stmts": float(len(prog.statements)),
        "n_axes": float(len(prog.axis_names)),
    }
    for i in range(_TOP_AXES):
        feats[f"log_axis_{i}"] = _log10(sizes[i]) if i < len(sizes) else 0.0
    return feats


def graph_features(graph: SystemGraph) -> dict[str, float]:
    """Machine descriptors from the system-graph structure (the same node
    and edge attributes ``sysgraph_fingerprint`` hashes)."""
    flops = [c.flops_per_sec for c in graph.computes.values()]
    caps = [m.capacity for m in graph.memories.values()]
    levels = [m.level for m in graph.memories.values()]
    bws = [e.bandwidth for e in graph.edges]
    lats = [e.latency for e in graph.edges]
    top = [m.capacity for m in graph.memories.values()
           if m.level == max(levels, default=0)]
    return {
        "log_peak_flops": _log10(max(flops, default=1.0)),
        "n_computes": float(len(graph.computes)),
        "log_min_mem": _log10(min(caps, default=1)),
        "log_top_mem": _log10(max(top, default=1)),
        "log_min_bw": _log10(min(bws, default=1.0)),
        "log_max_bw": _log10(max(bws, default=1.0)),
        "log_mean_latency": _log10(1e12 * (sum(lats) / len(lats)
                                           if lats else 0.0)),
        "n_edges": float(len(graph.edges)),
    }


def role_extents(selection) -> dict[str, int]:
    """The (i, j, k) *role* extents of a Selection: for the first
    matmul-mapped instruction, each needle axis's haystack extent.  This is
    what makes tile-cap features meaningful on conv-extraction programs,
    whose haystack axes carry fused names (``y``/``co``/``ci``...) — the
    mapping's ``axis_map`` says which of them the MXU roles land on."""
    prog = selection.program
    for si in selection.instrs:
        if "matmul" not in si.needle.name:
            continue
        return {na: prog.axis(ha).size for na, ha in si.mapping.axis_map}
    return {}


def _default_roles(prog: Program) -> dict[str, int]:
    """Role extents when no Selection is in hand: axes literally named
    i/j/k (the canonical matmul program), else the largest extents in
    descending order — approximate, but deterministic and shape-monotone."""
    names = set(prog.axis_names)
    if {"i", "j", "k"} <= names:
        return {r: prog.axis(r).size for r in ("i", "j", "k")}
    sizes = sorted((prog.axis(a).size for a in prog.axis_names),
                   reverse=True)
    return {r: sizes[x] if x < len(sizes) else 1
            for x, r in enumerate(("i", "j", "k"))}


def config_features(config: dict,
                    hw_tile: tuple[int, int, int] = (128, 128, 128),
                    roles: dict[str, int] | None = None
                    ) -> dict[str, float]:
    """The ParamApproach decision vector, numerically encoded.  Unknown
    policy names degrade exactly as ``ParamApproach`` does (to the greedy
    defaults), so features always describe the schedule actually built.

    The load-bearing terms are the per-role **cap excess** features:
    ``tile_<d>_excess`` = log2 of the extra passes a tile cap forces along
    role ``d`` (0 when the cap doesn't bind or the dim is uncapped), and
    ``tile_<d>_binds`` — whether the cap changes anything at all.  These
    let one linear model learn "capping j on a 64-wide GEMM is free, capping
    i on a 5124-row one costs passes", which raw cap values cannot express.
    """
    from ..search.space import ParamApproach
    pa = ParamApproach(config)
    roles = roles or {}
    feats: dict[str, float] = {}
    for x, d in enumerate(("i", "j", "k")):
        cap = pa.tile_caps[x]
        size = max(1, int(roles.get(d, 0)))
        feats[f"tile_{d}_capped"] = 0.0 if cap is None else 1.0
        feats[f"tile_{d}_log2"] = _log2ratio(cap, hw_tile[x])
        if cap is None or size <= 1:
            excess = 0.0
            binds = 0.0
        else:
            eff = max(1, min(int(cap), size))
            excess = math.log2(math.ceil(size / eff))
            binds = 1.0 if eff < size else 0.0
        feats[f"tile_{d}_excess"] = excess
        feats[f"tile_{d}_binds"] = binds
    feats["stream_k"] = 1.0 if pa.stream_k else 0.0
    feats["vmem_frac"] = float(pa.vmem_frac)
    feats["grow_j"] = 1.0 if pa.grow_j else 0.0
    for name in _UNROLLS:
        feats[f"unroll={name}"] = 1.0 if pa.unroll_policy == name else 0.0
    for name in _DEVICES:
        feats[f"device={name}"] = 1.0 if pa.device_policy == name else 0.0
    for name in _SOURCES:
        feats[f"source={name}"] = 1.0 if pa.source_policy == name else 0.0
    return feats


def _interactions(cfg: dict[str, float], prog: dict[str, float],
                  roles: dict[str, float]) -> dict[str, float]:
    """Second-order terms the linear model needs: a tile cap's cost impact
    scales with the extent of the role it binds against."""
    out = {}
    for dim in ("i", "j", "k"):
        out[f"tile_{dim}_x_role"] = (cfg[f"tile_{dim}_log2"]
                                     * roles[f"log_role_{dim}"])
        out[f"tile_{dim}_binds_x_flops"] = (cfg[f"tile_{dim}_binds"]
                                            * prog["log_flops"])
    out["vmem_x_bytes"] = cfg["vmem_frac"] * prog["log_bytes"]
    out["stream_k_x_flops"] = cfg["stream_k"] * prog["log_flops"]
    return out


def feature_dict(config: dict, prog: Program, graph: SystemGraph,
                 roles: dict[str, int] | None = None) -> dict[str, float]:
    """The full named feature map for one (config, program, graph) triple.
    ``roles`` are the matmul role extents (``role_extents(selection)``);
    derived from axis names/sizes when no selection is available."""
    hw = graph.min_matmul_tile()
    roles = roles or _default_roles(prog)
    cfg = config_features(config, hw, roles)
    pf = program_features(prog)
    gf = graph_features(graph)
    rf = {f"log_role_{d}": _log10(roles.get(d, 1)) for d in ("i", "j", "k")}
    return {**cfg, **pf, **gf, **rf, **_interactions(cfg, pf, rf)}


def feature_names(prog: Program, graph: SystemGraph) -> tuple[str, ...]:
    """Deterministic feature ordering (dict insertion order of
    ``feature_dict``) — stored in the model artifact as its schema."""
    return tuple(feature_dict({}, prog, graph))


def feature_vector(config: dict, prog: Program, graph: SystemGraph,
                   names: tuple[str, ...] | None = None,
                   roles: dict[str, int] | None = None) -> np.ndarray:
    """Feature map flattened to a float64 vector in ``names`` order.  A
    model trained elsewhere passes its stored names; unknown names raise
    ``KeyError`` (schema drift must not silently mis-score)."""
    d = feature_dict(config, prog, graph, roles)
    if names is None:
        names = tuple(d)
    return np.array([d[n] for n in names], dtype=np.float64)


def artifact_features(art) -> dict[str, float]:
    """Descriptors of an already-compiled ``CompiledKernel`` — the resolved
    tile plan plus the schedule's measured op counts and bytes.  Used for
    model diagnostics (what did the schedule actually do) rather than
    candidate scoring, which must not pay for a compile."""
    feats: dict[str, float] = {
        "log_cost": _log10(1e12 * max(art.cost, 0.0)),
        "log_bytes_moved": _log10(art.bytes_moved),
        "n_instrs": float(len(art.instrs)),
    }
    for kind, n in sorted(art.counts.items()):
        feats[f"count={kind}"] = float(n)
    for plan in art.instrs:
        for axis, size in plan.tile:
            feats.setdefault(f"tile[{plan.needle}:{axis}]", float(size))
        feats.setdefault(f"calls[{plan.needle}]", float(plan.calls))
    return feats
