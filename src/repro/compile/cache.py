"""Persistent ``CompiledKernel`` artifact cache.

Layered on the ``repro.search`` fingerprinting: the key is (program
fingerprint, sysgraph fingerprint, *approach* fingerprint, backend, jax
version), so an artifact is reused only when the whole compile is
reproducible — a different machine description, a different config vector or
a toolchain bump all miss.  One JSON file, atomic writes, warn-once on a
corrupt file (same contract as the tuning cache).

The process-wide default cache is *opt-in* (``set_default_artifact_cache``):
library entry points like ``plan_gemm`` stay purely in-memory-memoized
unless a launch (``--tuned``), the CLI, or a test activates a cache.
"""
from __future__ import annotations

import json
import os
import tempfile

from ..core.sysgraph import SystemGraph
from ..search import space as _space
from ..search.cache import CACHE_ERRORS, file_lock, warn_corrupt_cache
from .artifact import ARTIFACT_SCHEMA, CompiledKernel

#: Override the default artifact-cache location (e.g. in CI).
CACHE_ENV_VAR = "REPRO_COMPILE_CACHE"


def default_artifact_cache_path() -> str:
    env = os.environ.get(CACHE_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "compiled.json")


def approach_fingerprint(approach) -> str:
    """Stable identity of an Approach for artifact keying.

    ``ParamApproach``-style approaches expose their config vector; the
    stateless heuristic approaches reduce to their class name.  Approaches
    with hidden state (wrappers, RNG-driven) get a non-reusable fingerprint
    so they are never served a cached artifact."""
    cfg = getattr(approach, "config", None)
    if isinstance(cfg, dict):
        return "cfg:" + json.dumps(
            {k: cfg[k] for k in sorted(cfg)}, sort_keys=True)
    name = type(approach).__name__ if approach is not None else "GreedyApproach"
    if name in ("GreedyApproach", "Approach"):
        return "greedy"
    if name == "CostModelApproach":
        return f"costmodel:{getattr(approach, 'samples', 0)}" \
               f":{getattr(approach, 'seed', 0)}"
    return f"opaque:{name}:{id(approach)}"


def cacheable_approach(approach) -> bool:
    return not approach_fingerprint(approach).startswith("opaque:")


def isa_fingerprint(isa) -> str:
    """Structural hash of the needle set in play — two compiles of the same
    program under different ISAs must never share an artifact."""
    if not isa:
        return "-"
    import hashlib
    parts = sorted(f"{n.name}@{_space.program_fingerprint(n)}" for n in isa)
    return hashlib.sha256(";".join(parts).encode()).hexdigest()[:12]


def artifact_key_from_parts(prog_name: str, prog_fp: str, graph_name: str,
                            graph_fp: str, approach_fp: str, backend: str,
                            isa_fp: str = "-",
                            allow_transforms: bool = True) -> str:
    return (f"{prog_name}@{prog_fp}|{graph_name}@{graph_fp}"
            f"|{approach_fp}|{backend}|isa={isa_fp}"
            f"|xf={int(bool(allow_transforms))}|jax={_space.jax_version()}")


def artifact_key(prog, graph: SystemGraph | str, approach,
                 backend: str = "cost", isa=None,
                 allow_transforms: bool = True) -> str:
    """(program fp, sysgraph fp, approach fp, backend, isa fp, transform
    policy, jax version)."""
    if isinstance(graph, SystemGraph):
        gname, gfp = graph.name, _space.sysgraph_fingerprint(graph)
    else:
        gname, _, gfp = graph.partition("@")
    return artifact_key_from_parts(prog.name,
                                   _space.program_fingerprint(prog),
                                   gname, gfp,
                                   approach_fingerprint(approach), backend,
                                   isa_fingerprint(isa), allow_transforms)


class ArtifactCache:
    """Dict of ``CompiledKernel`` dicts with JSON persistence."""

    def __init__(self, path: str | None = None):
        self.path = path or default_artifact_cache_path()
        self._entries: dict[str, dict] | None = None

    # -- persistence ---------------------------------------------------------
    def load(self) -> dict[str, dict]:
        if self._entries is None:
            entries: dict[str, dict] = {}
            raw = None
            try:
                with open(self.path) as f:
                    raw = json.load(f)
            except OSError:
                pass                          # missing file = empty cache
            except ValueError as e:           # json.JSONDecodeError
                warn_corrupt_cache(self.path, e)
            if isinstance(raw, dict):
                for d in raw.get("artifacts", []):
                    if isinstance(d, dict) and "key" in d:
                        entries[d["key"]] = d
            self._entries = entries
        return self._entries

    def save(self) -> None:
        # Merge-on-save under the same advisory file lock as the tuning
        # cache: concurrent savers serialize, so parallel tuner workers
        # cannot drop each other's artifacts.
        with file_lock(self.path):
            self._save_locked()

    def _save_locked(self) -> None:
        ours = dict(self.load())
        entries = ArtifactCache(self.path).load()
        entries.update(ours)
        self._entries = entries
        payload = {"schema": ARTIFACT_SCHEMA,
                   "artifacts": list(entries.values())}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- access --------------------------------------------------------------
    def lookup(self, key: str) -> CompiledKernel | None:
        d = self.load().get(key)
        if d is None:
            return None
        from ..verify import verify_artifact_dict
        diags = verify_artifact_dict(d)
        if diags:
            warn_corrupt_cache(
                self.path,
                ValueError(f"artifact {key!r} failed payload verification: "
                           + "; ".join(str(x) for x in diags[:3])))
            return None
        try:
            return CompiledKernel.from_dict(d)
        except CACHE_ERRORS as e:
            warn_corrupt_cache(self.path, e)
            return None

    def store(self, artifact: CompiledKernel, save: bool = True) -> None:
        self.load()[artifact.key] = artifact.to_dict()
        if save:
            self.save()

    def keys(self):
        return self.load().keys()

    def __len__(self) -> int:
        return len(self.load())

    def __contains__(self, key: str) -> bool:
        return key in self.load()


# --------------------------------------------------------------------------- #
# Process-wide default cache (opt-in)
# --------------------------------------------------------------------------- #

_default_cache: ArtifactCache | None = None


def get_default_artifact_cache() -> ArtifactCache | None:
    """The active artifact cache, or None when none has been activated."""
    return _default_cache


def set_default_artifact_cache(cache: ArtifactCache | None) -> None:
    """Activate (or deactivate) the process-wide artifact cache — used by
    ``--tuned`` launches, the CLI, and tests."""
    global _default_cache
    _default_cache = cache
