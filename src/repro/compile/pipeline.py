"""The pass-based compilation pipeline.

``Pipeline(passes=(MapPass(), SelectPass(), SchedulePass(), LowerPass()))``
drives one ``CompileContext`` — an ISAMIR ``Program`` + ``SystemGraph`` +
``Approach`` — through the paper's stages:

    Program ──Map──▶ candidates ──Select──▶ Selection ──Schedule──▶
        Schedule ──Verify──▶ (statically checked) ──Lower──▶
        tile/grid plan + lowering config

and assembles the result into a ``CompiledKernel`` artifact.  Each pass is a
small object with ``run(ctx)``; custom pipelines can drop, replace or extend
passes (the driver uses a truncated Schedule+Lower pipeline when a selection
is already in hand; multi-chip compiles are composed in
``driver.compile_fabric``, which runs this pipeline per chip and attaches
the fabric partition + collective plan to the artifact).

Passes reuse the existing subsystem entry points (``core.isel``,
``core.scheduler``) — the pipeline adds *structure*, not a parallel
implementation, so a pipeline compile is bit-identical to the historical
ad-hoc call chains.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..core.approach import Approach, GreedyApproach
from ..core.ir import Program
from ..core.isel import (Selection, candidate_instructions,
                         select_from_candidates)
from ..core.scheduler import Schedule, ScheduleError, schedule
from ..core.sysgraph import SystemGraph
from .artifact import CompiledKernel, CompileError, InstrPlan
from .cache import (approach_fingerprint, artifact_key_from_parts,
                    isa_fingerprint)
from ..search import space as _space


@dataclass
class CompileContext:
    """Mutable state threaded through the passes."""

    program: Program
    graph: SystemGraph
    approach: Approach | None = None
    isa: list = field(default_factory=list)
    allow_transforms: bool = True
    backend: str = "cost"
    verify: bool = True
    meta: dict = field(default_factory=dict)

    # produced by passes
    candidates: list | None = None
    selection: Selection | None = None
    schedule: Schedule | None = None
    instr_plans: tuple[InstrPlan, ...] | None = None
    lowering: dict | None = None


class Pass:
    """One pipeline stage.  ``run`` mutates the context in place."""

    name = "pass"

    def run(self, ctx: CompileContext) -> None:  # pragma: no cover - abstract
        raise NotImplementedError


class MapPass(Pass):
    """Instruction mapping (paper Section 2.2): find every way each ISA
    needle identifies inside the program."""

    name = "map"

    def run(self, ctx: CompileContext) -> None:
        if not ctx.isa:
            raise CompileError("MapPass needs a non-empty ISA")
        ctx.candidates = candidate_instructions(ctx.program, ctx.isa)


class SelectPass(Pass):
    """Instruction selection (Section 2.4): cover the program from the
    mapping candidates, consulting the transform search when allowed."""

    name = "select"

    def run(self, ctx: CompileContext) -> None:
        if ctx.candidates is None:
            raise CompileError("SelectPass requires MapPass output")
        sel = select_from_candidates(ctx.program, ctx.candidates, ctx.isa,
                                     allow_transforms=ctx.allow_transforms,
                                     approach=ctx.approach)
        if not sel.complete:
            raise CompileError(
                f"program {ctx.program.name} not fully mappable: statements "
                f"{sel.uncovered} uncovered by {[n.name for n in ctx.isa]}")
        ctx.selection = sel


class SchedulePass(Pass):
    """Static dry-run scheduling (Section 3): unroll, allocate, move."""

    name = "schedule"

    def run(self, ctx: CompileContext) -> None:
        if ctx.selection is None:
            raise CompileError("SchedulePass requires a Selection")
        ctx.schedule = schedule(ctx.selection, ctx.graph, ctx.approach)


class VerifyPass(Pass):
    """Static analysis gate (``repro.verify``): program legality, selection
    coverage/role consistency, and a symbolic hazard replay of the schedule.
    Strict by default — any error-severity diagnostic aborts the compile
    with a ``CompileError``; set ``ctx.verify = False`` (the ``--no-verify``
    escape hatch) to skip."""

    name = "verify"

    def run(self, ctx: CompileContext) -> None:
        if not ctx.verify:
            return
        from ..verify import verify_compile
        report = verify_compile(selection=ctx.selection,
                                schedule=ctx.schedule,
                                approach=ctx.approach)
        if not report.ok:
            raise CompileError(
                f"static verification of {ctx.program.name} failed "
                f"({len(report.errors)} error(s)):\n{report.render()}")


class LowerPass(Pass):
    """Extract the role-keyed tile plan and the backend lowering config.

    Tile sizes are resolved through each mapping's ``axis_map`` (needle axis
    → haystack axis), *not* by guessing haystack axis names — the fix for
    the historical ``_tile_from_schedule`` i/j/k assumption.  Programs whose
    mapped axes don't appear in any compute tile raise ``CompileError``.
    """

    name = "lower"

    def run(self, ctx: CompileContext) -> None:
        sel, sched = ctx.selection, ctx.schedule
        if sel is None or sched is None:
            raise CompileError("LowerPass requires selection + schedule")
        prog = sel.program
        plans: list[InstrPlan] = []
        first_tile: dict[int, dict] = {}
        for op in sched.ops:
            if op.kind == "compute" and op.tile.instr_idx not in first_tile:
                first_tile[op.tile.instr_idx] = op.tile.sizes
        for idx, si in enumerate(sel.instrs):
            sizes = first_tile.get(idx)
            if sizes is None:
                raise CompileError(
                    f"schedule contains no compute tile for instruction "
                    f"{idx} ({si.needle.name})")
            tile = []
            for na, ha in si.mapping.axis_map:
                if ha not in sizes:
                    raise CompileError(
                        f"mapped axis {na}->{ha} of {si.needle.name} absent "
                        f"from its compute tiles (axes: {sorted(sizes)})")
                tile.append((na, int(sizes[ha])))
            plans.append(InstrPlan(
                needle=si.needle.name,
                axis_map=tuple(si.mapping.axis_map),
                tile=tuple(tile),
                outer_axes=tuple(si.mapping.outer_axes),
                calls=si.mapping.calls(prog)))
        ctx.instr_plans = tuple(plans)
        ctx.lowering = self._lowering(ctx, plans)

    @staticmethod
    def _lowering(ctx: CompileContext, plans: list[InstrPlan]) -> dict:
        """Backend config: a single full-cover matmul lowers to a blocked
        Pallas GEMM BlockSpec — ``pallas_gemm`` (TPU/paper: block sized for
        VMEM) or ``pallas_gpu_gemm`` (GPU family: block sized for the
        cluster's shared memory, with the staged panel bytes recorded so
        the artifact checker can audit the fit).  Everything else stays an
        executor-backed instruction stream."""
        sel = ctx.selection
        mm = [p for p in plans if p.needle.startswith("mxu.matmul")]
        if len(plans) == 1 and mm and not sel.steps:
            plan = mm[0]
            tiles = dict(plan.tile)
            amap = dict(plan.axis_map)
            try:
                extents = {na: sel.program.axis(amap[na]).size
                           for na in ("i", "j", "k")}
                block = tuple(min(tiles[na], extents[na])
                              for na in ("i", "j", "k"))
            except KeyError:
                return {"kind": "stream"}
            grid = tuple(math.ceil(extents[na] / b)
                         for na, b in zip(("i", "j", "k"), block))
            if getattr(ctx.graph, "family", "") == "gpu":
                # A (bm, bk) + B (bk, bn) panels plus the C (bm, bn)
                # accumulator tile staged in shared memory, f32 elements.
                bm, bn, bk = block[0], block[1], block[2]
                smem = 4 * (bm * bk + bk * bn + bm * bn)
                return {"kind": "pallas_gpu_gemm", "block": list(block),
                        "grid": list(grid), "smem_bytes": smem}
            return {"kind": "pallas_gemm", "block": list(block),
                    "grid": list(grid)}
        return {"kind": "stream"}


DEFAULT_PASSES = (MapPass(), SelectPass(), SchedulePass(), VerifyPass(),
                  LowerPass())


@dataclass
class Pipeline:
    """An ordered pass list + artifact assembly."""

    passes: tuple = DEFAULT_PASSES

    def run(self, ctx: CompileContext) -> CompiledKernel:
        approach = ctx.approach if ctx.approach is not None else GreedyApproach()
        ctx.approach = approach
        try:
            for p in self.passes:
                p.run(ctx)
        except ScheduleError as e:
            raise CompileError(str(e)) from e
        return self.assemble(ctx)

    @staticmethod
    def assemble(ctx: CompileContext) -> CompiledKernel:
        sched = ctx.schedule
        cost = sched.makespan if sched is not None else float("inf")
        prog_fp = _space.program_fingerprint(ctx.program)
        graph_fp = _space.sysgraph_fingerprint(ctx.graph)
        approach_fp = approach_fingerprint(ctx.approach)
        return CompiledKernel(
            key=artifact_key_from_parts(ctx.program.name, prog_fp,
                                        ctx.graph.name, graph_fp,
                                        approach_fp, ctx.backend,
                                        isa_fingerprint(ctx.isa),
                                        ctx.allow_transforms),
            program_name=ctx.program.name,
            program_fp=prog_fp,
            graph_name=ctx.graph.name,
            graph_fp=graph_fp,
            approach_fp=approach_fp,
            backend=ctx.backend,
            cost=cost,
            instrs=ctx.instr_plans or (),
            counts=sched.counts() if sched is not None else {},
            bytes_moved=sched.bytes_moved() if sched is not None else 0,
            lowering=ctx.lowering or {"kind": "stream"},
            meta=dict(ctx.meta),
            program=ctx.program, graph=ctx.graph, approach=ctx.approach,
            isa=list(ctx.isa), selection=ctx.selection, schedule=sched)
