"""Compilation-driver CLI.

    PYTHONPATH=src python -m repro.compile --kernel gemm --shape 1024x1024x1024
    PYTHONPATH=src python -m repro.compile --suite smoke --validate \\
        --cache artifacts/compile_cache.json --json artifacts/compile.json

Compiles workloads through the full pipeline (Map → Select → Schedule →
Lower) and prints one ``CompiledKernel`` summary per case: the role-derived
tile, the lowering config, the modeled cost, and whether the artifact came
from the persistent cache.  ``--validate`` replays each schedule through
``core.executor`` against the ``ir.interpret`` oracle on a proxy-capped
shape and requires bit-exactness.  ``--expect-cached`` fails unless every
case hit the cache (CI uses it to prove artifact reuse).

Multi-chip: ``--chips N --topology ring|torus|host`` compiles the fabric
partition + collective plan instead of a single-chip schedule.

Exit status: 0 iff every case compiled (and validated / hit the cache when
asked).
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core.sysgraph import TARGET_ALIASES, TARGETS, resolve_target
from .artifact import CompileError
from .cache import ArtifactCache, set_default_artifact_cache
from .driver import (compile_conv, compile_fabric, compile_gemm, compile_gru,
                     resolve_approach)

#: Oracle proxies cap each axis (same policy as repro.search / repro.fabric).
VALIDATE_DIM_CAP = 192

SMOKE_CASES = [
    ("gemm", {"m": 512, "n": 256, "k": 1024}),
    ("gru", {"batch": 16, "hidden": 64}),
    ("conv", {"batch": 2, "h": 6, "w": 6, "kh": 1, "kw": 1,
              "cin": 8, "cout": 8}),
]


#: Default --shape per kernel (conv extents come from --conv-args).
DEFAULT_SHAPES = {"gemm": "1024x1024x1024", "gru": "32x512"}


def _parse_shape(text: str, kernel: str) -> dict:
    """Shape dict for one kernel; raises ``ValueError`` on malformed input
    (main() turns it into an argparse usage error)."""
    dims = [int(x) for x in text.lower().split("x")]
    if kernel == "gemm":
        if len(dims) != 3:
            raise ValueError("gemm shape is MxNxK")
        return {"m": dims[0], "n": dims[1], "k": dims[2]}
    if len(dims) != 2:
        raise ValueError("gru shape is BATCHxHIDDEN")
    return {"batch": dims[0], "hidden": dims[1]}


def _compile_case(kernel: str, kw: dict, approach, args, graph):
    if args.chips > 1:
        from ..fabric.topology import make_topology
        topo = make_topology(args.topology, args.chips)
        if kernel == "gemm":
            shape = (kw["m"], kw["n"], kw["k"])
        elif kernel == "gru":
            shape = (kw["batch"], kw["hidden"])
        else:
            raise CompileError("multi-chip compile supports gemm/gru")
        return compile_fabric(kernel, shape, topo, axis=args.axis,
                              approach=approach)
    fn = {"gemm": compile_gemm, "gru": compile_gru,
          "conv": compile_conv}[kernel]
    return fn(approach=approach, graph=graph, verify=not args.no_verify,
              **kw)


def _proxy_args(kernel: str, kw: dict) -> dict:
    cap = VALIDATE_DIM_CAP
    if kernel == "gemm":
        return {k: min(v, cap) for k, v in kw.items()}
    if kernel == "gru":
        return {"batch": min(kw["batch"], 4), "hidden": min(kw["hidden"], 16)}
    return dict(kw, batch=min(kw["batch"], 2), h=min(kw["h"], 6),
                w=min(kw["w"], 6), cin=min(kw["cin"], 8),
                cout=min(kw["cout"], 8))


def _validate(kernel: str, kw: dict, approach, graph):
    """Bit-exact executor-vs-oracle replay of a proxy-sized compile on the
    same target graph the full-size artifact was compiled for."""
    from ..search.evaluate import validate_schedule
    from .driver import _FRONTENDS, compile_selection
    pkw = _proxy_args(kernel, kw)
    orig, sel = _FRONTENDS[kernel](**pkw)
    art = compile_selection(sel, graph, approach, program=orig)
    return validate_schedule(orig, sel, art.ensure_schedule())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compile",
        description="Pass-based compilation driver: compile a workload to a "
                    "CompiledKernel artifact (tile plan, lowering config, "
                    "modeled cost) and exercise the artifact cache.")
    ap.add_argument("--kernel", choices=["gemm", "gru", "conv"],
                    default="gemm")
    ap.add_argument("--shape", default=None,
                    help="MxNxK (gemm) or BATCHxHIDDEN (gru); default "
                         f"{DEFAULT_SHAPES}")
    ap.add_argument("--conv-args", default="4,14,14,3,3,32,64",
                    metavar="B,H,W,KH,KW,CIN,COUT",
                    help="conv2d extents (kernel=conv)")
    ap.add_argument("--suite", choices=["smoke"], default=None,
                    help="compile a fixed case list instead of one kernel")
    ap.add_argument("--approach", choices=["greedy", "costmodel"],
                    default="greedy")
    ap.add_argument("--target",
                    choices=sorted(set(TARGETS) | set(TARGET_ALIASES)),
                    default="tpu_v5e",
                    help="modeled hardware target (core.sysgraph factory); "
                         "single-chip compiles and --validate replays run "
                         "against this graph")
    ap.add_argument("--chips", type=int, default=1,
                    help=">1 compiles the fabric partition for the topology")
    ap.add_argument("--topology", choices=["ring", "torus", "host"],
                    default="ring")
    ap.add_argument("--axis", default=None,
                    help="fabric partition axis (default: the kernel's first)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent artifact cache (activated process-wide)")
    ap.add_argument("--no-cache", action="store_true",
                    help="compile fresh, ignoring any cache")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the static verifier pass (escape hatch)")
    ap.add_argument("--validate", action="store_true",
                    help="bit-exact oracle replay on a proxy-capped shape")
    ap.add_argument("--expect-cached", action="store_true",
                    help="fail unless every artifact came from the cache")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    if args.cache and not args.no_cache:
        set_default_artifact_cache(ArtifactCache(args.cache))
    approach = resolve_approach(args.approach)
    graph = resolve_target(args.target)
    if args.chips > 1 and graph.family != "tpu":
        ap.error("--chips > 1 (fabric compile) currently supports the "
                 "tpu_v5e target only")

    if args.suite == "smoke":
        cases = SMOKE_CASES
    else:
        try:
            if args.kernel == "conv":
                b, h, w, kh, kw_, cin, cout = (
                    int(x) for x in args.conv_args.split(","))
                kw = {"batch": b, "h": h, "w": w, "kh": kh, "kw": kw_,
                      "cin": cin, "cout": cout}
            else:
                shape = args.shape or DEFAULT_SHAPES[args.kernel]
                kw = _parse_shape(shape, args.kernel)
        except ValueError as e:
            ap.error(str(e))
        cases = [(args.kernel, kw)]

    rows = []
    failures = 0
    for kernel, kw in cases:
        try:
            art = _compile_case(kernel, kw, approach, args, graph)
        except CompileError as e:
            print(f"[FAIL] {kernel} {kw}: {e}", file=sys.stderr)
            failures += 1
            continue
        row = {"kernel": kernel, "args": kw, "program": art.program_name,
               "graph": art.graph_name, "cost_s": art.cost,
               "lowering": art.lowering, "cached": art.from_cache,
               "counts": art.counts, "bytes_moved": art.bytes_moved,
               "key": art.key}
        try:
            row["tile"] = list(art.gemm_tile())
        except CompileError:
            row["tile"] = None
        if art.fabric:
            row["fabric"] = {k: art.fabric[k]
                             for k in ("axis", "algorithm", "chips",
                                       "topology", "makespan")}
        status = "ok"
        if args.expect_cached and not art.from_cache:
            status = "MISS"
            failures += 1
        if args.validate and args.chips == 1:
            rep = _validate(kernel, kw, approach, graph)
            row["oracle_exact"] = rep.exact
            if not rep.exact:
                status = "MISMATCH"
                failures += 1
        rows.append(row)
        print(f"[{status}] {art.summary()}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "approach": args.approach,
                       "target": args.target, "failures": failures,
                       "rows": rows}, f, indent=2)
        print(f"# report: {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
