"""The compilation driver: one entry point in front of the whole pipeline.

``compile_program`` is the general entry (any ISAMIR program, any system
graph, any Approach); ``compile_gemm`` / ``compile_gru`` / ``compile_conv``
are the workload frontends the kernels, the tuner and the benchmarks share;
``compile_selection`` runs the back half of the pipeline when an instruction
selection is already in hand (the search evaluators and per-chip fabric
compiles); ``compile_fabric`` partitions a workload across a multi-chip
topology and returns an artifact carrying the distributed plan.

Every entry produces (or replays) a ``CompiledKernel``.  Fresh compiles are
memoized in-process per artifact key; the persistent artifact cache is
consulted when one is passed explicitly or activated process-wide
(``repro.compile.cache.set_default_artifact_cache`` — the ``--tuned``
launches and the CLI do this).
"""
from __future__ import annotations

import copy

from ..core import instructions as I
from ..core import kernels_ir as K
from ..core.approach import Approach, CostModelApproach, GreedyApproach
from ..core.ir import Program
from ..core.isel import Selection
from ..core.sysgraph import SystemGraph, tpu_v5e
from .artifact import CompiledKernel, CompileError
from .cache import (ArtifactCache, artifact_key, cacheable_approach,
                    get_default_artifact_cache)
from .pipeline import (CompileContext, LowerPass, MapPass, Pipeline,
                       SchedulePass, SelectPass, VerifyPass)

#: In-process artifact memo (the successor of ``plan_gemm``'s lru_cache):
#: fresh compiles with a reproducible approach are reused by key.
_MEMO: dict[str, CompiledKernel] = {}
_MEMO_CAP = 512


def clear_memo() -> None:
    _MEMO.clear()


def resolve_approach(approach) -> Approach:
    """Accept an Approach instance, ``None`` (greedy), or the historical
    string names (``'greedy'`` / ``'costmodel'``)."""
    if approach is None:
        return GreedyApproach()
    if isinstance(approach, str):
        if approach == "greedy":
            return GreedyApproach()
        if approach == "costmodel":
            return CostModelApproach(samples=4)
        raise ValueError(f"unknown approach name {approach!r}")
    return approach


# --------------------------------------------------------------------------- #
# Workload frontends (program + selection builders shared across the repo)
# --------------------------------------------------------------------------- #


def select_program(program: Program, isa=None, allow_transforms: bool = True,
                   approach=None) -> Selection:
    """Map + Select through the pipeline passes; raises ``CompileError`` if
    the program cannot be fully covered."""
    ctx = CompileContext(program=program, graph=tpu_v5e(1),
                         approach=approach,
                         isa=list(isa) if isa else I.tpu_isa(),
                         allow_transforms=allow_transforms)
    MapPass().run(ctx)
    SelectPass().run(ctx)
    return ctx.selection


def gemm_selection(m: int, n: int, k: int) -> tuple[Program, Selection]:
    """The canonical (m, n, k) GEMM against the MXU matmul needle."""
    prog = K.matmul(m, n, k)
    return prog, select_program(prog, [I.mxu_matmul()],
                                allow_transforms=False)


def gru_selection(batch: int, hidden: int,
                  inp: int | None = None) -> tuple[Program, Selection]:
    """The GRU cell against the full TPU ISA (fused instructions in play)."""
    prog = K.gru_cell(batch, hidden, hidden if inp is None else inp)
    return prog, select_program(prog, I.tpu_isa())


def conv_selection(**kw) -> tuple[Program, Selection]:
    """conv2d through the ISAM-TVM axis-fusion extraction onto the MXU.
    Returns (original program, selection over the transformed program)."""
    from ..core.transforms import fuse_axes_for_calls
    isa = [I.mxu_matmul()]
    orig = K.conv2d(**kw)
    prog, sel, steps = fuse_axes_for_calls(orig, isa)
    sel = Selection(sel.program, tuple(steps), sel.instrs, sel.uncovered)
    return orig, sel


_FRONTENDS = {
    "gemm": lambda **kw: gemm_selection(**kw),
    "gru": lambda **kw: gru_selection(**kw),
    "conv": lambda **kw: conv_selection(**kw),
}


# --------------------------------------------------------------------------- #
# Core compiles
# --------------------------------------------------------------------------- #


def _resolve_cache(cache, use_cache: bool) -> ArtifactCache | None:
    if not use_cache:
        return None
    return cache if cache is not None else get_default_artifact_cache()


def _strip(art: CompiledKernel) -> CompiledKernel:
    """A detached copy holding only the serializable payload — what the memo
    keeps (and hands back) so it never pins live schedules/selections; a
    consumer that needs the schedule calls ``ensure_schedule()``."""
    s = copy.copy(art)
    s.program = s.graph = s.approach = s.isa = None
    s.selection = s.schedule = None
    s.meta = dict(art.meta)
    s.from_cache = True
    return s


def _store(art: CompiledKernel, cache: ArtifactCache | None,
           memoize: bool) -> CompiledKernel:
    """The one store/memo policy for every compile entry."""
    if cacheable_approach(art.approach):
        if cache is not None:
            cache.store(art)
        if memoize:
            if len(_MEMO) >= _MEMO_CAP:
                _MEMO.clear()
            _MEMO[art.key] = _strip(art)
    return art


def _finish(ctx: CompileContext, cache: ArtifactCache | None,
            memoize: bool) -> CompiledKernel:
    return _store(Pipeline(passes=(SchedulePass(), VerifyPass(),
                                   LowerPass())).run(ctx),
                  cache, memoize)


def _lookup(program: Program, graph: SystemGraph, approach, backend: str,
            cache: ArtifactCache | None, memoize: bool, isa=None,
            allow_transforms: bool = True):
    """(key, hit) — the memo is consulted first, then the persistent cache."""
    if not cacheable_approach(approach):
        return None, None
    key = artifact_key(program, graph, approach, backend, isa,
                       allow_transforms)
    if memoize and key in _MEMO:
        return key, _strip(_MEMO[key])
    if cache is not None:
        hit = cache.lookup(key)
        if hit is not None:
            return key, hit
    return key, None


def compile_program(program: Program, graph: SystemGraph | None = None,
                    approach=None, isa=None, *,
                    allow_transforms: bool = True, backend: str = "cost",
                    cache: ArtifactCache | None = None, use_cache: bool = True,
                    verify: bool = True,
                    meta: dict | None = None) -> CompiledKernel:
    """Program + SystemGraph + Approach -> CompiledKernel, through the full
    Map -> Select -> Schedule -> Verify -> Lower pipeline.  ``verify=False``
    is the ``--no-verify`` escape hatch."""
    graph = graph if graph is not None else tpu_v5e(1)
    approach = resolve_approach(approach)
    isa = list(isa) if isa else I.tpu_isa()
    cache = _resolve_cache(cache, use_cache)
    key, hit = _lookup(program, graph, approach, backend, cache, use_cache,
                       isa, allow_transforms)
    if hit is not None:
        _attach(hit, program, graph, approach, isa, allow_transforms)
        return hit
    ctx = CompileContext(program=program, graph=graph, approach=approach,
                         isa=isa, allow_transforms=allow_transforms,
                         backend=backend, verify=verify,
                         meta=dict(meta or {}))
    ctx.meta.setdefault("allow_transforms", allow_transforms)
    MapPass().run(ctx)
    SelectPass().run(ctx)
    return _finish(ctx, cache, memoize=use_cache)


def compile_selection(selection: Selection, graph: SystemGraph,
                      approach=None, *, backend: str = "cost",
                      program: Program | None = None,
                      verify: bool = False,
                      meta: dict | None = None) -> CompiledKernel:
    """Schedule + Lower an existing Selection (no caching: this is the hot
    inner entry the search evaluators and per-chip fabric compiles use, so
    the static verifier is opt-in here — pass ``verify=True`` to gate)."""
    approach = resolve_approach(approach)
    ctx = CompileContext(program=program or selection.program, graph=graph,
                         approach=approach, backend=backend,
                         meta=dict(meta or {}))
    ctx.selection = selection
    passes = ((SchedulePass(), VerifyPass(), LowerPass()) if verify
              else (SchedulePass(), LowerPass()))
    return Pipeline(passes=passes).run(ctx)


def _compile_frontend(frontend: str, fe_args: dict, graph, approach, backend,
                      cache, use_cache, verify: bool = True) -> CompiledKernel:
    graph = graph if graph is not None else tpu_v5e(1)
    approach = resolve_approach(approach)
    cache = _resolve_cache(cache, use_cache)
    # Frontend programs are cheap to rebuild; selections are not — key off
    # the program (+ the frontend's ISA/transform policy), select on a miss.
    program, isa, allow_transforms, _sel_builder = \
        _frontend_program(frontend, fe_args)
    key, hit = _lookup(program, graph, approach, backend, cache, use_cache,
                       isa, allow_transforms)
    if hit is not None:
        _attach(hit, program, graph, approach, isa, allow_transforms)
        hit.meta.setdefault("frontend", frontend)
        hit.meta.setdefault("frontend_args", dict(fe_args))
        return hit
    ctx = CompileContext(program=program, graph=graph, approach=approach,
                         isa=isa, allow_transforms=allow_transforms,
                         backend=backend, verify=verify,
                         meta={"frontend": frontend,
                               "frontend_args": dict(fe_args)})
    ctx.selection = _sel_builder()
    return _finish(ctx, cache, memoize=use_cache)


def _frontend_program(frontend: str, fe_args: dict):
    """(program, isa, allow_transforms, lazy selection builder) for one
    workload frontend — lets a cache hit skip the (expensive) mapping +
    selection entirely while keying on the exact compile inputs."""
    if frontend == "gemm":
        prog = K.matmul(fe_args["m"], fe_args["n"], fe_args["k"])
        isa = [I.mxu_matmul()]
        return prog, isa, False, lambda: select_program(
            prog, isa, allow_transforms=False)
    if frontend == "gru":
        inp = fe_args.get("inp")
        prog = K.gru_cell(fe_args["batch"], fe_args["hidden"],
                          fe_args["hidden"] if inp is None else inp)
        isa = I.tpu_isa()
        return prog, isa, True, lambda: select_program(prog, isa)
    if frontend == "conv":
        orig = K.conv2d(**fe_args)

        def build():
            _, sel = conv_selection(**fe_args)
            return sel
        return orig, [I.mxu_matmul()], True, build
    raise CompileError(f"unknown frontend {frontend!r}")


def compile_gemm(m: int, n: int, k: int, approach=None,
                 graph: SystemGraph | None = None, *,
                 backend: str = "cost", cache: ArtifactCache | None = None,
                 use_cache: bool = True, verify: bool = True) -> CompiledKernel:
    return _compile_frontend("gemm", {"m": m, "n": n, "k": k}, graph,
                             approach, backend, cache, use_cache, verify)


def compile_gru(batch: int, hidden: int, inp: int | None = None,
                approach=None, graph: SystemGraph | None = None, *,
                backend: str = "cost", cache: ArtifactCache | None = None,
                use_cache: bool = True, verify: bool = True) -> CompiledKernel:
    fe_args = {"batch": batch, "hidden": hidden}
    if inp is not None:
        fe_args["inp"] = inp
    return _compile_frontend("gru", fe_args, graph, approach, backend,
                             cache, use_cache, verify)


def compile_conv(approach=None, graph: SystemGraph | None = None, *,
                 backend: str = "cost", cache: ArtifactCache | None = None,
                 use_cache: bool = True, verify: bool = True,
                 **kw) -> CompiledKernel:
    return _compile_frontend("conv", kw, graph, approach, backend, cache,
                             use_cache, verify)


# --------------------------------------------------------------------------- #
# Multi-chip (fabric) compiles
# --------------------------------------------------------------------------- #


def compile_fabric(kernel: str, shape: tuple[int, ...], topo,
                   axis: str | None = None, approach=None,
                   algorithm: str = "ring", replicate_out: bool = False, *,
                   cache: ArtifactCache | None = None,
                   use_cache: bool = True) -> CompiledKernel:
    """Partition ``kernel``/``shape`` across ``topo`` and compile: per-chip
    schedules come from ``compile_selection`` and the distributed makespan
    from the ``repro.fabric`` event simulator.  The artifact's tile plan is
    chip 0's; ``artifact.fabric`` carries the partition + collective plan."""
    from ..fabric.partition import partition, partition_axes
    from ..fabric.simulate import replicate_output, simulate_partition
    from ..fabric.topology import Topology

    approach = resolve_approach(approach)
    axis = axis or partition_axes(kernel)[0]
    backend = (f"fabric-{topo.name}-{axis}-{algorithm}"
               + ("-repl" if replicate_out else ""))
    cache = _resolve_cache(cache, use_cache)
    chip_graph = Topology.chip_graph()
    fabric_graph = topo.build_graph()
    pp = partition(kernel, shape, axis, topo.n_chips)
    if replicate_out:
        pp = replicate_output(pp)

    key, hit = _lookup(pp.base, fabric_graph, approach, backend, cache,
                       use_cache)
    if hit is not None:
        _attach(hit, pp.base, fabric_graph, approach, None, True)
        return hit
    if key is None:                        # opaque approach: key is informational
        key = artifact_key(pp.base, fabric_graph, approach, backend)

    res = simulate_partition(pp, topo, approach, algorithm, chip_graph)
    shard0 = compile_selection(pp.shard_selection(pp.shards[0]), chip_graph,
                               approach, program=pp.shards[0].program)
    art = CompiledKernel(
        key=key,
        program_name=pp.base.name,
        program_fp=_program_fp(pp.base),
        graph_name=fabric_graph.name,
        graph_fp=_graph_fp(fabric_graph),
        approach_fp=shard0.approach_fp,
        backend=backend,
        cost=res.makespan,
        instrs=shard0.instrs,
        counts=shard0.counts,
        bytes_moved=shard0.bytes_moved,
        lowering=shard0.lowering,
        fabric={"axis": pp.axis, "algorithm": res.algorithm,
                "chips": topo.n_chips, "topology": topo.name,
                "makespan": res.makespan, "comm_end": res.comm_end,
                "comm_bound": res.comm_bound,
                "collective_steps": res.n_collective_steps,
                "chip_spans": list(res.chip_spans),
                "out_mode": pp.out_mode,
                "collectives": [{"kind": c.kind, "buffer": c.buffer,
                                 "when": c.when, "axis": c.axis}
                                for c in pp.collectives],
                "per_chip_cost": shard0.cost},
        meta={"kernel": kernel, "shape": list(shape)},
        program=pp.base, graph=fabric_graph, approach=approach,
        selection=shard0.selection, schedule=shard0.schedule)
    return _store(art, cache, memoize=use_cache)


def _program_fp(prog: Program) -> str:
    from ..search.space import program_fingerprint
    return program_fingerprint(prog)


def _graph_fp(graph: SystemGraph) -> str:
    from ..search.space import sysgraph_fingerprint
    return sysgraph_fingerprint(graph)


# --------------------------------------------------------------------------- #
# Cache-hit replay
# --------------------------------------------------------------------------- #


def _attach(art: CompiledKernel, program, graph, approach, isa,
            allow_transforms: bool) -> None:
    art.program = program
    art.graph = graph
    art.approach = approach
    art.isa = list(isa) if isa else None
    art.meta.setdefault("allow_transforms", allow_transforms)


def recompile_schedule(art: CompiledKernel) -> None:
    """Rebuild selection + schedule for a cache-hydrated artifact (used by
    ``CompiledKernel.ensure_schedule``).  Deterministic: the same program,
    graph and approach reproduce the cached decisions exactly.

    Fabric artifacts carry chip 0's *per-chip* schedule (what a fresh
    ``compile_fabric`` attaches), so the rebuild re-partitions and
    schedules shard 0 on the single-chip graph — not the unsharded program
    on the fabric graph."""
    if art.fabric is not None:
        from ..fabric.partition import partition
        from ..fabric.topology import Topology
        pp = partition(art.meta["kernel"], tuple(art.meta["shape"]),
                       art.fabric["axis"], art.fabric["chips"])
        shard0 = compile_selection(pp.shard_selection(pp.shards[0]),
                                   Topology.chip_graph(), art.approach,
                                   program=pp.shards[0].program)
        art.selection = shard0.selection
        art.schedule = shard0.schedule
        return
    if art.selection is None:
        fe = art.meta.get("frontend")
        if fe in _FRONTENDS:
            _, art.selection = _FRONTENDS[fe](**art.meta.get(
                "frontend_args", {}))
        else:
            art.selection = select_program(
                art.program, art.isa,
                allow_transforms=bool(art.meta.get("allow_transforms", True)))
    ctx = CompileContext(program=art.program, graph=art.graph,
                         approach=art.approach, backend=art.backend)
    ctx.selection = art.selection
    SchedulePass().run(ctx)
    art.schedule = ctx.schedule


# --------------------------------------------------------------------------- #
# Incremental re-scheduling across a config population
# --------------------------------------------------------------------------- #


class DeltaScheduler:
    """Schedules many Approach variants of one fixed Selection, reusing the
    unchanged per-instruction prefix of previously scheduled *anchors*.

    An anchor is a fully scheduled config kept with its per-instruction
    resume points (``core.scheduler.schedule_with_segments``).  A new key
    whose policy triple matches an anchor and whose per-instr tiles share a
    non-empty prefix resumes from the deepest snapshot before the first
    changed instruction (``schedule_incremental``) — verified bit-equal to
    the from-scratch schedule (``tests/test_search_batch.py`` and the
    ``sch.*`` mutation classes).  Keys come from
    ``repro.search.batch.BatchPlan.analyze``.
    """

    def __init__(self, selection: Selection, graph: SystemGraph,
                 max_anchors: int = 8):
        from ..core.scheduler import schedule_incremental, \
            schedule_with_segments
        self.sel = selection
        self.graph = graph
        self.max_anchors = max_anchors
        self._full = schedule_with_segments
        self._inc = schedule_incremental
        #: (key, schedule, segments) of fresh runs, FIFO-trimmed
        self.anchors: list[tuple] = []
        self.stats = {"fresh": 0, "delta": 0}

    def schedule_for(self, approach: Approach, key: tuple):
        """The schedule for ``approach`` (whose BatchPlan key is ``key``),
        via the deepest-prefix anchor when one applies."""
        tiles, pol = key[0], key[1:]
        best = None                     # (first_changed, schedule, segments)
        for a_key, a_sched, a_segs in self.anchors:
            if a_key[1:] != pol:
                continue
            n = 0
            for ta, tb in zip(a_key[0], tiles):
                if ta != tb:
                    break
                n += 1
            # a resume needs the snapshot taken after instr n-1
            if n >= 1 and (n - 1) in a_segs \
                    and (best is None or n > best[0]):
                best = (n, a_sched, a_segs)
        if best is not None and best[0] < len(tiles):
            sched, _ = self._inc(self.sel, self.graph, approach,
                                 best[1], best[2], best[0])
            self.stats["delta"] += 1
            return sched
        sched, segs = self._full(self.sel, self.graph, approach)
        self.stats["fresh"] += 1
        self.anchors.append((key, sched, segs))
        if len(self.anchors) > self.max_anchors:
            self.anchors.pop(0)
        return sched
