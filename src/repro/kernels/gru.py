"""Fused GRU cell — the kernel ISAM's GRU schedule corresponds to (Fig. 4).

One ``pl.pallas_call`` computes all three gates and the state update for a
(batch-block x hidden-block) tile: six matmuls on the MXU with the gate
arithmetic fused as the VPU epilogue, hidden state kept VMEM-resident.  This
is the hand-written equivalent of the instruction stream ISAM derives
automatically (fused.matmul_bias_sigmoid + vpu ops) — the benchmark compares
the ISAM schedule's modeled cycles against a kernel-library-style unfused
op-by-op execution.

The hidden state ``h`` is passed twice: once full-width (for the U-matmul
reductions) and once as the elementwise (bb, bh) block — the two views let
BlockSpec express both access patterns of the same array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import _cdiv, default_interpret

PARAM_NAMES = ("Wr", "Ur", "Wz", "Uz", "Wn", "Un", "br", "bz", "bnx", "bnh")


def _gru_kernel(x_ref, hfull_ref, h_ref,
                wr_ref, ur_ref, wz_ref, uz_ref, wn_ref, un_ref,
                br_ref, bz_ref, bnx_ref, bnh_ref,
                out_ref):
    f32 = jnp.float32
    x = x_ref[...].astype(f32)
    hf = hfull_ref[...].astype(f32)
    h = h_ref[...].astype(f32)
    r = jax.nn.sigmoid(jnp.dot(x, wr_ref[...].astype(f32),
                               preferred_element_type=f32)
                       + jnp.dot(hf, ur_ref[...].astype(f32),
                                 preferred_element_type=f32)
                       + br_ref[...])
    z = jax.nn.sigmoid(jnp.dot(x, wz_ref[...].astype(f32),
                               preferred_element_type=f32)
                       + jnp.dot(hf, uz_ref[...].astype(f32),
                                 preferred_element_type=f32)
                       + bz_ref[...])
    n = jnp.tanh(jnp.dot(x, wn_ref[...].astype(f32),
                         preferred_element_type=f32)
                 + r * (jnp.dot(hf, un_ref[...].astype(f32),
                                preferred_element_type=f32) + bnh_ref[...])
                 + bnx_ref[...])
    out_ref[...] = ((1 - z) * n + z * h).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gru_cell(x: jax.Array, h: jax.Array, params: dict,
             block: tuple[int, int] = (128, 128),
             interpret: bool | None = None) -> jax.Array:
    """One fused GRU step: x (B, E), h (B, H) -> h' (B, H)."""
    if interpret is None:
        interpret = default_interpret()
    B, E = x.shape
    _, H = h.shape
    bb, bh = min(block[0], B), min(block[1], H)
    Bp, Hp = _cdiv(B, bb) * bb, _cdiv(H, bh) * bh

    x_p = jnp.pad(x, ((0, Bp - B), (0, 0))) if Bp != B else x
    h_p = jnp.pad(h, ((0, Bp - B), (0, Hp - H))) if (Bp, Hp) != (B, H) else h

    def padw(w):  # (E or H, H) -> pad output dim
        return jnp.pad(w, ((0, 0), (0, Hp - H))) if Hp != H else w

    def padu(u):  # (H, H) -> pad both
        return jnp.pad(u, ((0, Hp - H), (0, Hp - H))) if Hp != H else u

    def padb(b):
        return jnp.pad(b, (0, Hp - H)) if Hp != H else b

    grid = (Bp // bb, Hp // bh)
    w_spec = pl.BlockSpec((E, bh), lambda i, j: (0, j))
    u_spec = pl.BlockSpec((Hp, bh), lambda i, j: (0, j))
    b_spec = pl.BlockSpec((bh,), lambda i, j: (j,))

    out = pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, E), lambda i, j: (i, 0)),    # x
            pl.BlockSpec((bb, Hp), lambda i, j: (i, 0)),   # h (full width)
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),   # h (ew block)
            w_spec, u_spec, w_spec, u_spec, w_spec, u_spec,
            b_spec, b_spec, b_spec, b_spec,
        ],
        out_specs=pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Hp), x.dtype),
        interpret=interpret,
    )(x_p, h_p, h_p,
      padw(params["Wr"]), padu(params["Ur"]),
      padw(params["Wz"]), padu(params["Uz"]),
      padw(params["Wn"]), padu(params["Un"]),
      padb(params["br"]), padb(params["bz"]),
      padb(params["bnx"]), padb(params["bnh"]))
    return out[:B, :H]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gru_seq(xs: jax.Array, h0: jax.Array, params: dict,
            block: tuple[int, int] = (128, 128),
            interpret: bool | None = None) -> jax.Array:
    """GRU over [T, B, E] — the 128-step RNN of the paper's Figure 4.
    Weights stay device-resident across steps (the recursive iteration)."""
    def step(h, x):
        return gru_cell(x, h, params, block=block, interpret=interpret), None
    h, _ = jax.lax.scan(step, h0, xs)
    return h
