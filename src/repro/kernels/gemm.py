"""Pallas TPU GEMM kernel — the MXU "matmul instruction" ISAM maps onto.

The kernel is a classic blocked matmul: grid (M/bm, N/bn, K/bk) with the
reduction dimension innermost; each grid step loads (bm, bk) and (bk, bn)
VMEM tiles via BlockSpec and accumulates into the revisited (bm, bn) output
block.  Block shapes are *parameters*: the ISAM scheduler's compute-tile
choice (scheduler.py) is forwarded here as the BlockSpec tiling — this is the
TPU-native realisation of the paper's "emit instruction stream + memory
movement": the BlockSpec pipeline IS the HBM->VMEM copy schedule.

Targeted at TPU (MXU-aligned 128x128x128 default tile); validated on CPU via
``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _matmul_kernel(a_ref, b_ref, c_ref, *, k_steps: int):
    """One (i, j, k) grid step: c[i, j] (+)= a[i, k] @ b[k, j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=c_ref.dtype)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def tuned_block(m: int, n: int, k: int,
                default: tuple[int, int, int] = (128, 128, 128)
                ) -> tuple[int, int, int]:
    """Block shape for an (m, n, k) GEMM from the persistent tuning cache
    (``repro.search``), falling back to ``default`` on a cache miss.

    Tune once (``python -m repro.search.tune --suite gemm``) and every later
    process picks the winning BlockSpec up here — keyed by program
    fingerprint, system graph, backend, and jax version.

    Shapes that were *never* tuned ask the learned cost model next — when a
    process-wide model store is active (``--tuned --tuning-model``,
    ``repro.search.model.set_default_store``), the matmul-family ridge model
    ranks the tile sub-space by predicted cost and its winner becomes the
    BlockSpec.  No store / no model / any cache error keeps ``default``.
    """
    from ..search.cache import CACHE_ERRORS, clamp_tile, lookup_gemm
    try:
        rec = lookup_gemm(m, n, k)
    except CACHE_ERRORS:
        rec = None
    if rec is not None and rec.tile:
        return clamp_tile(rec.tile, m, n, k)
    try:
        from ..search.model import predict_gemm_block
        blk = predict_gemm_block(m, n, k)
    except CACHE_ERRORS:
        blk = None
    if blk is not None:
        return clamp_tile(blk, m, n, k)
    return default


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gemm(a: jax.Array, b: jax.Array,
         block: tuple[int, int, int] | None = None,
         interpret: bool | None = None) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.

    ``block=(bm, bn, bk)`` is the VMEM tile shape — normally chosen by the
    ISAM scheduler (see ops.scheduled_gemm).  ``block=None`` consults the
    persistent tuning cache (``tuned_block``; resolved at trace time, so a
    cache update needs a fresh process or jit cache).  Inputs whose
    dimensions don't divide the block are padded up and the result cropped;
    zero padding is exact for the contraction.
    """
    if interpret is None:
        interpret = default_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if block is None:
        block = tuned_block(m, n, k)
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))

    acc_dtype = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float32) else a.dtype
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), acc_dtype),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n].astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "fn"))
def gemm_bias_act(a: jax.Array, b: jax.Array, bias: jax.Array,
                  fn: str = "",
                  block: tuple[int, int, int] | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """The paper's fused instruction: act(A @ B + bias) in one kernel —
    the epilogue runs on the VPU while the block is still VMEM-resident.
    ``block=None`` consults the tuning cache, as in ``gemm``."""
    if interpret is None:
        interpret = default_interpret()
    m, k = a.shape
    _, n = b.shape
    if block is None:
        block = tuned_block(m, n, k)
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    bias_p = jnp.pad(bias, (0, np_ - n)) if np_ != n else bias
    grid = (mp // bm, np_ // bn, kp // bk)

    def kernel(a_ref, b_ref, bias_ref, c_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            c_ref[...] = jnp.zeros_like(c_ref)

        c_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                              preferred_element_type=c_ref.dtype)

        @pl.when(pl.program_id(2) == grid[2] - 1)
        def _epilogue():
            acc = c_ref[...] + bias_ref[...]
            if fn == "sigmoid":
                acc = jax.nn.sigmoid(acc)
            elif fn == "tanh":
                acc = jnp.tanh(acc)
            elif fn == "relu":
                acc = jnp.maximum(acc, 0)
            c_ref[...] = acc

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p, bias_p)
    return out[:m, :n].astype(a.dtype)
