"""Jit'd wrappers + the ISAM -> Pallas bridge.

``scheduled_gemm`` is the end-to-end TPU story: the compilation driver
(``repro.compile``: map -> select -> schedule -> lower against the v5e
system graph) decides the compute-tile shape, and that decision becomes the
Pallas BlockSpec tiling.  The compiler output *is* the kernel configuration
— no hand-written lowering rule.  ``plan_gemm`` / ``plan_gru`` are thin
wrappers over ``compile_gemm`` / ``compile_gru``; tiles come from the
``CompiledKernel``'s role-keyed tile plan (derived from each mapping's
``axis_map``), never from guessed haystack axis names.
"""
from __future__ import annotations

import jax

from ..compile import CompileError, compile_gemm, compile_gru
from .gemm import gemm, gemm_bias_act, tuned_block
from .gru import gru_cell, gru_seq


def plan_gemm(m: int, n: int, k: int, approach: str = "greedy",
              use_cache: bool = True) -> tuple[tuple[int, int, int], float]:
    """Compile an (m, n, k) GEMM against the v5e graph through
    ``repro.compile``; return (chosen tile (bm, bn, bk), modeled seconds).

    With ``use_cache`` (default), a winning config from the persistent
    tuning cache (``repro.search``) short-circuits planning entirely — the
    tuned tile and its modeled cost are returned as recorded.  The lookup
    happens on every call (only the compile itself is memoized), so
    activating a cache mid-process takes effect immediately."""
    if use_cache:
        from ..search.cache import (CACHE_ERRORS, clamp_tile, lookup_gemm)
        try:
            rec = lookup_gemm(m, n, k)
        except CACHE_ERRORS:
            rec = None
        if rec is not None and rec.tile:
            return clamp_tile(rec.tile, m, n, k), rec.cost
    art = compile_gemm(m, n, k, approach=approach, use_cache=use_cache)
    return art.gemm_tile(), art.cost


def plan_gru(batch: int, hidden: int, inp: int | None = None,
             approach: str = "greedy",
             use_cache: bool = True) -> tuple[tuple[int, int], float]:
    """Compile the GRU cell through ``repro.compile``; return the (bb, bh)
    batch/hidden tile of its matmul stage + the modeled seconds.  Raises
    ``CompileError`` if no matmul-shaped instruction was selected."""
    art = compile_gru(batch, hidden, inp, approach=approach,
                      use_cache=use_cache)
    for prefix in ("fused.matmul", "mxu.matmul"):
        try:
            plan = art.instr_plan(prefix)
            return (plan.tile_for("i"), plan.tile_for("j")), art.cost
        except CompileError:
            continue
    raise CompileError(
        f"GRU selection contains no matmul-shaped instruction "
        f"(have: {[p.needle for p in art.instrs]})")


def scheduled_gemm(a: jax.Array, b: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """GEMM whose BlockSpec tiling was chosen by the compilation driver."""
    m, k = a.shape
    _, n = b.shape
    tile, _ = plan_gemm(m, n, k)
    return gemm(a, b, block=tile, interpret=interpret)


__all__ = [
    "gemm", "gemm_bias_act", "gru_cell", "gru_seq",
    "plan_gemm", "plan_gru", "scheduled_gemm", "tuned_block",
]
