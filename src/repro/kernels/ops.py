"""Jit'd wrappers + the ISAM -> Pallas bridge.

``scheduled_gemm`` is the end-to-end TPU story: the ISAM pipeline (map ->
select -> schedule against the v5e system graph) decides the compute-tile
shape, and that decision becomes the Pallas BlockSpec tiling.  The compiler
output *is* the kernel configuration — no hand-written lowering rule.
"""
from __future__ import annotations

import functools

import jax

from ..core import instructions as I
from ..core import kernels_ir as K
from ..core.approach import Approach, GreedyApproach
from ..core.isel import select_instructions
from ..core.scheduler import Schedule, schedule
from ..core.sysgraph import SystemGraph, tpu_v5e
from . import gemm as gemm_kernel
from . import gru as gru_kernel
from .gemm import gemm, gemm_bias_act, tuned_block
from .gru import gru_cell, gru_seq


def plan_gemm(m: int, n: int, k: int, approach: str = "greedy",
              use_cache: bool = True) -> tuple[tuple[int, int, int], float]:
    """Run the ISAM pipeline on an (m, n, k) GEMM against the v5e graph;
    return (chosen tile (bm, bn, bk), modeled seconds).

    With ``use_cache`` (default), a winning config from the persistent
    tuning cache (``repro.search``) short-circuits planning entirely — the
    tuned tile and its modeled cost are returned as recorded.  The lookup
    happens on every call (only the pure planning below is memoized), so
    activating a cache mid-process takes effect immediately."""
    if use_cache:
        try:
            from ..search.cache import clamp_tile, lookup_gemm
            rec = lookup_gemm(m, n, k)
        except Exception:
            rec = None
        if rec is not None and rec.tile:
            return clamp_tile(rec.tile, m, n, k), rec.cost
    return _plan_gemm_uncached(m, n, k, approach)


@functools.lru_cache(maxsize=256)
def _plan_gemm_uncached(m: int, n: int, k: int,
                        approach: str) -> tuple[tuple[int, int, int], float]:
    prog = K.matmul(m, n, k)
    sel = select_instructions(prog, [I.mxu_matmul()], allow_transforms=False)
    app: Approach = GreedyApproach()
    if approach == "costmodel":
        from ..core.approach import CostModelApproach
        app = CostModelApproach(samples=4)
    sched = schedule(sel, tpu_v5e(1), app)
    tile = _tile_from_schedule(sched)
    return tile, sched.makespan


def _tile_from_schedule(sched: Schedule) -> tuple[int, int, int]:
    """Extract the (bm, bn, bk) compute-tile shape the scheduler settled on."""
    for op in sched.ops:
        if op.kind != "compute":
            continue
        sizes = op.tile.sizes
        # haystack axes are named i/j/k for K.matmul programs
        return (sizes.get("i", 128), sizes.get("j", 128), sizes.get("k", 128))
    raise ValueError("schedule contains no compute tiles")


def scheduled_gemm(a: jax.Array, b: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """GEMM whose BlockSpec tiling was chosen by the ISAM scheduler."""
    m, k = a.shape
    _, n = b.shape
    tile, _ = plan_gemm(m, n, k)
    return gemm(a, b, block=tile, interpret=interpret)


__all__ = [
    "gemm", "gemm_bias_act", "gru_cell", "gru_seq",
    "plan_gemm", "scheduled_gemm", "tuned_block",
]
