"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def gemm_bias_act_ref(a, b, bias, fn: str = "") -> jax.Array:
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)) + bias
    if fn == "sigmoid":
        out = jax.nn.sigmoid(out)
    elif fn == "tanh":
        out = jnp.tanh(out)
    elif fn == "relu":
        out = jnp.maximum(out, 0)
    return out.astype(a.dtype)


def gru_cell_ref(x, h, params) -> jax.Array:
    """r/z/n-gate GRU step (same convention as core.kernels_ir.gru_cell)."""
    f32 = jnp.float32
    x, h = x.astype(f32), h.astype(f32)
    r = jax.nn.sigmoid(x @ params["Wr"] + h @ params["Ur"] + params["br"])
    z = jax.nn.sigmoid(x @ params["Wz"] + h @ params["Uz"] + params["bz"])
    n = jnp.tanh(x @ params["Wn"] + r * (h @ params["Un"] + params["bnh"])
                 + params["bnx"])
    return ((1 - z) * n + z * h).astype(x.dtype)


def gru_seq_ref(xs, h0, params) -> jax.Array:
    """GRU over a [T, B, E] sequence; returns final hidden state."""
    def step(h, x):
        return gru_cell_ref(x, h, params), None
    h, _ = jax.lax.scan(step, h0, xs)
    return h
