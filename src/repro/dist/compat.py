"""Compatibility helpers for jax API drift in the mesh/sharding surface.

The placement layer targets two generations of the jax sharding API:

* jax >= 0.5: ``AbstractMesh(axis_sizes, axis_names)`` and
  ``jax.sharding.AxisType`` exist; ``jax.make_mesh`` accepts ``axis_types``.
* jax 0.4.3x: ``AbstractMesh`` takes a single ``((name, size), ...)`` tuple
  and there is no public ``AxisType``.

Everything in ``repro.dist.sharding`` only reads ``mesh.axis_names`` and
``mesh.shape`` (a name->size mapping), which both generations provide, so
the rules themselves are version-agnostic.  These helpers normalise the
construction side.
"""
from __future__ import annotations

import jax


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Construct a ``jax.sharding.AbstractMesh`` on either jax generation."""
    from jax.sharding import AbstractMesh  # may raise ImportError on old jax
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def supports_new_abstract_mesh() -> bool:
    """True if ``AbstractMesh(axis_sizes, axis_names)`` works as spelled."""
    try:
        from jax.sharding import AbstractMesh
        AbstractMesh((1,), ("_probe",))
        return True
    except (ImportError, TypeError):
        return False


def install_abstract_mesh_compat() -> bool:
    """Patch ``jax.sharding.AbstractMesh`` so the modern
    ``AbstractMesh(axis_sizes, axis_names)`` spelling works on old jax.

    Returns True if the modern spelling works after the call.  Only the
    public alias is rebound — jax internals keep using
    ``jax._src.mesh.AbstractMesh``, and the factory returns genuine
    instances of it, so ``NamedSharding`` etc. accept the result.
    """
    import jax.sharding as jsh
    if supports_new_abstract_mesh():
        return True
    try:
        legacy = jsh.AbstractMesh
    except AttributeError:
        return False

    class _AbstractMeshCompat(legacy):
        """Legacy AbstractMesh accepting the modern (sizes, names) spelling.

        A subclass (not a factory function) so the public alias stays a
        type: ``isinstance(x, jax.sharding.AbstractMesh)`` keeps working.
        """

        def __init__(self, axis_sizes, axis_names=None, axis_types=None):
            if axis_names is None:      # legacy caller: pass through
                shape_tuple = axis_sizes
            else:
                shape_tuple = tuple(zip(axis_names, axis_sizes))
            if axis_types is None:
                legacy.__init__(self, shape_tuple)
            else:
                legacy.__init__(self, shape_tuple, axis_types)

    jsh.AbstractMesh = _AbstractMeshCompat
    return True


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    except TypeError:
        return jax.make_mesh(shape, axes)
