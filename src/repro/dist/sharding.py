"""Partition rules: pure ``PartitionSpec`` logic, no devices required.

The production mesh is (data=16, model=16) per pod with an optional leading
``pod`` axis; ``dp_axes`` treats every axis except the tensor-parallel
``model`` axis as data-parallel.  All assignment is divisibility-aware:
an axis is only used when its mesh extent divides the tensor dimension,
otherwise the rule falls back (next candidate axis) or replicates — that is
what keeps one rule set valid across all ten architectures (28-head qwen2,
8-expert mixtral, 40-head qwen1.5, ...) without per-model spec tables.

Parameter placement follows the Megatron/GSPMD conventions:

* column-parallel (wq/wk/wv, w_gate/w_up, generic projections): FSDP over
  the data axes on the input dim, TP over ``model`` on the output dim;
* row-parallel (wo, w_down, w_out): TP on the input dim, FSDP on output;
* embed/lm_head: vocab on ``model``, d_model on data;
* MoE experts: expert-parallel over ``model`` when the expert count
  divides it, else TP inside each expert (mixtral's 8 experts on a 16-way
  axis);
* sLSTM recurrent weights (``r_*``): replicated — the sequential
  recurrence must run without per-step collectives;
* norms / biases / gates: replicated.

``tests/test_sharding.py`` is the executable spec for this module.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: the tensor-parallel mesh axis; everything else is data-parallel
MODEL_AXIS = "model"

#: leaf names whose last-but-one dim is contracted (input) by the matmul
_ROW_PARALLEL = {"wo", "w_down", "w_out"}

#: MoE expert-weight leaves (expert dim at shape[-3])
_EXPERT_LEAVES = {"w_gate", "w_up", "w_down"}


# --------------------------------------------------------------------------- #
# axis helpers
# --------------------------------------------------------------------------- #


def _axes_tuple(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in _axes_tuple(axes):
        n *= mesh.shape[a]
    return n


def _one(axes):
    """Collapse a single-axis tuple to its bare name (P('data') is not
    P(('data',)) under PartitionSpec equality)."""
    t = _axes_tuple(axes)
    if not t:
        return None
    return t[0] if len(t) == 1 else t


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: every mesh axis except ``model``."""
    return tuple(n for n in mesh.axis_names if n != MODEL_AXIS)


def shard_dim(mesh, size: int, axes, fallback=None):
    """First of (``axes``, ``fallback``) whose combined mesh extent divides
    ``size``; None when neither does (replicate the dim)."""
    for cand in (axes, fallback):
        t = _axes_tuple(cand)
        if not t or any(a not in mesh.shape for a in t):
            continue
        if size % _axes_size(mesh, t) == 0:
            return cand
    return None


def _spec(entries) -> P:
    """PartitionSpec from per-dim entries; all-replicated collapses to P()."""
    if all(e is None for e in entries):
        return P()
    return P(*entries)


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #


def _is_vector_leaf(leaf: str) -> bool:
    return (leaf.startswith("norm") or leaf.startswith("b_")
            or leaf in {"bq", "bk", "bv", "conv_b", "dt_bias", "D_skip",
                        "scale"})


def param_spec(name: str, shape: tuple[int, ...], mesh, cfg) -> P:
    """Placement for one named parameter.

    ``name`` is the '/'-joined pytree path (e.g. ``layers/attn/wq``); any
    leading dims beyond the matmul's trailing (in, out) pair are stacked
    scan/layer dims and stay replicated.
    """
    leaf = name.split("/")[-1]
    ndim = len(shape)
    dp = dp_axes(mesh)
    entries = [None] * ndim

    if _is_vector_leaf(leaf) or ndim < 2:
        return P()

    # sLSTM recurrent weights: replicated so the time scan stays local
    if leaf.startswith("r_"):
        return P()

    if leaf == "embed":
        entries[-2] = _one(shard_dim(mesh, shape[-2], MODEL_AXIS))
        entries[-1] = _one(shard_dim(mesh, shape[-1], dp))
        return _spec(entries)

    # MoE expert weights: (..., E, in, out)
    if (leaf in _EXPERT_LEAVES and ndim >= 3 and getattr(cfg, "n_experts", 0)
            and shape[-3] == cfg.n_experts and "ffn" in name.split("/")):
        ep = shard_dim(mesh, cfg.n_experts, MODEL_AXIS)
        if ep is not None:              # expert-parallel over the model axis
            entries[-3] = _one(ep)
            entries[-2] = _one(shard_dim(mesh, shape[-2], dp))
            return _spec(entries)
        # TP fallback inside each expert (expert count doesn't divide)
        if leaf in _ROW_PARALLEL:
            entries[-2] = _one(shard_dim(mesh, shape[-2], MODEL_AXIS))
            entries[-1] = _one(shard_dim(mesh, shape[-1], dp))
        else:
            entries[-2] = _one(shard_dim(mesh, shape[-2], dp))
            entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return _spec(entries)

    if leaf in _ROW_PARALLEL:
        entries[-2] = _one(shard_dim(mesh, shape[-2], MODEL_AXIS))
        entries[-1] = _one(shard_dim(mesh, shape[-1], dp))
        return _spec(entries)

    # generic column-parallel projection (lm_head included)
    entries[-2] = _one(shard_dim(mesh, shape[-2], dp))
    entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
    return _spec(entries)


# --------------------------------------------------------------------------- #
# batches
# --------------------------------------------------------------------------- #


def _batch_entries(mesh, shape) -> list:
    """Per-dim entries with the batch dim (dim0, else dim1 when batch=1
    long-context doesn't divide) over the data axes."""
    dp = dp_axes(mesh)
    entries = [None] * len(shape)
    ax = shard_dim(mesh, shape[0], dp)
    if ax is not None:
        entries[0] = _one(ax)
    elif len(shape) >= 2:
        ax = shard_dim(mesh, shape[1], dp)
        if ax is not None:
            entries[1] = _one(ax)
    return entries


def batch_spec(name: str, shape: tuple[int, ...], mesh) -> P:
    """Inputs shard their batch dim over the data axes; when the batch
    doesn't divide (batch=1 long-context decode) the sequence dim takes the
    data axes instead."""
    del name  # one rule for every input kind today
    return P(*_batch_entries(mesh, shape))


# --------------------------------------------------------------------------- #
# serving caches / recurrent state
# --------------------------------------------------------------------------- #


def cache_spec(name: str, shape: tuple[int, ...], mesh, cfg) -> P:
    """Placement for decode-state leaves.

    * ``kv/{k,v}`` (..., B, S, KV, hd): batch over data; KV heads over
      ``model`` when they divide, else the head_dim takes ``model`` (GQA
      archs like qwen2.5's kv=8 on a 16-way axis);
    * mamba ``h``/``conv``: batch over data, d_inner over ``model``;
    * mLSTM/sLSTM recurrent state: batch over data, trailing feature dim
      over ``model`` when divisible.
    """
    parts = name.split("/")
    leaf = parts[-1]
    ndim = len(shape)
    dp = dp_axes(mesh)
    entries = [None] * ndim

    if "mlstm" in parts or "slstm" in parts:
        b = 2 if "mlstm" in parts else 1    # (nb[, nm], B, ...)
        if b < ndim:
            entries[b] = _one(shard_dim(mesh, shape[b], dp))
        if ndim > b + 1:
            entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return _spec(entries)

    if leaf in ("k", "v") and ndim >= 4:    # KV cache
        entries[ndim - 4] = _one(shard_dim(mesh, shape[ndim - 4], dp))
        heads = shard_dim(mesh, shape[-2], MODEL_AXIS)
        if heads is not None:
            entries[-2] = _one(heads)
        else:
            entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return _spec(entries)

    if leaf == "h" and ndim >= 3:           # mamba SSM state (..., B, di, ds)
        entries[ndim - 3] = _one(shard_dim(mesh, shape[ndim - 3], dp))
        entries[-2] = _one(shard_dim(mesh, shape[-2], MODEL_AXIS))
        return _spec(entries)

    if leaf == "conv" and ndim >= 3:        # conv tail (..., B, dc-1, di)
        entries[ndim - 3] = _one(shard_dim(mesh, shape[ndim - 3], dp))
        entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return _spec(entries)

    return P()                              # unknown state: replicate


# --------------------------------------------------------------------------- #
# activations
# --------------------------------------------------------------------------- #


def make_activation_rules(mesh, cfg):
    """Build the ``rules(name, shape) -> NamedSharding | None`` callable the
    models consume through ``ctx.constrain``.

    Unknown names return None (constrain no-ops), which is what keeps the
    rule vocabulary open — a model may constrain names the launch layer has
    no opinion about on this mesh.
    """
    dp = dp_axes(mesh)

    def _batchish(shape):
        return _batch_entries(mesh, shape)

    def _heads(shape):
        # (B, T, H, hd): heads over model; 28-head archs fall back to
        # sequence sharding over the model axis (sequence parallelism)
        entries = _batchish(shape)
        h = shard_dim(mesh, shape[2], MODEL_AXIS)
        if h is not None:
            entries[2] = _one(h)
        elif entries[1] is None:
            entries[1] = _one(shard_dim(mesh, shape[1], MODEL_AXIS))
        return entries

    def _last_model(shape):
        # (B, T, F|V|D): batch over data, trailing feature dim over model
        entries = _batchish(shape)
        entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return entries

    def _scores(shape):
        # (B, H, T, S): batch over data, heads over model.  The layout
        # differs from the (B, T, ...) rules — dim 1 is heads, so the
        # batch=1 long-context fallback shards the query-time dim instead.
        entries = [None] * len(shape)
        ax = shard_dim(mesh, shape[0], dp)
        if ax is not None:
            entries[0] = _one(ax)
        elif len(shape) >= 3:
            ax = shard_dim(mesh, shape[2], dp)
            if ax is not None:
                entries[2] = _one(ax)
        if len(shape) >= 2:
            entries[1] = _one(shard_dim(mesh, shape[1], MODEL_AXIS))
        return entries

    def _expert_tokens(shape):
        # (E, G, C, D): expert-parallel over model when E divides
        entries = [None] * len(shape)
        entries[0] = _one(shard_dim(mesh, shape[0], MODEL_AXIS))
        if len(shape) >= 2:
            entries[1] = _one(shard_dim(mesh, shape[1], dp))
        return entries

    def _expert_hidden(shape):
        # (E, G, C, F): EP on E, else TP on the expert-hidden dim
        entries = _expert_tokens(shape)
        if entries[0] is None:
            entries[-1] = _one(shard_dim(mesh, shape[-1], MODEL_AXIS))
        return entries

    builders = {
        "residual": _batchish,
        "tokens": _batchish,
        "heads": _heads,
        "scores": _scores,
        "ffn_hidden": _last_model,
        "logits": _last_model,
        "expert_tokens4": _expert_tokens,
        "expert_hidden4": _expert_hidden,
    }

    def rules(name: str, shape):
        shape = tuple(shape)
        if name.startswith("kv/"):
            spec = cache_spec(name, shape, mesh, cfg)
        elif name in builders:
            spec = _spec(builders[name](shape))
        else:
            return None
        return NamedSharding(mesh, spec)

    return rules


# --------------------------------------------------------------------------- #
# tree-level wrappers
# --------------------------------------------------------------------------- #


def _path_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def param_shardings(tree, mesh, cfg):
    """NamedSharding tree mirroring a parameter (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, param_spec(_path_name(p), tuple(leaf.shape), mesh, cfg)),
        tree)


def batch_shardings(tree, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, batch_spec(_path_name(p), tuple(leaf.shape), mesh)),
        tree)


def cache_shardings(tree, mesh, cfg):
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, cache_spec(_path_name(p), tuple(leaf.shape), mesh, cfg)),
        tree)
