"""repro.dist — explicit placement layer (mesh + partition rules).

The models stay mesh-agnostic: they call ``ctx.constrain(x, name)`` with a
small rule-name vocabulary (``residual``, ``heads``, ``tokens``,
``ffn_hidden``, ``logits``, ``scores``, ``expert_*``, ``kv/*``) and the
launch layer decides what those names mean for the mesh at hand by entering
``ctx.activation_sharding_ctx(sharding.make_activation_rules(mesh, cfg))``.
Outside the context every constraint is a transparent no-op, so kernels and
models import nothing mesh-specific.

``sharding`` holds the pure PartitionSpec logic (no devices required — it
works on ``jax.sharding.AbstractMesh``); ``compat`` papers over jax API
drift so the same rules run on every supported jax version.
"""
from . import compat, ctx, sharding

__all__ = ["compat", "ctx", "sharding"]
