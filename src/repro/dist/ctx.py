"""Context-scoped activation sharding.

``constrain(x, name)`` is the only placement hook the models use.  Inside
an ``activation_sharding_ctx(rules)`` block it applies
``jax.lax.with_sharding_constraint`` with whatever sharding the active
rules assign to ``name``; outside any context — unit tests, single-device
scripts, kernels reused standalone — it is a transparent no-op, so model
code never imports a mesh.

The active rules live in a ``contextvars.ContextVar``: tracing under
``jax.jit`` happens on the caller's thread inside the ``with`` block, and
context-vars propagate correctly across the async dispatch helpers jax
uses internally (unlike a bare module global with threads).
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Callable, Optional

import jax

_RULES: ContextVar[Optional[Callable]] = ContextVar(
    "activation_sharding_rules", default=None)


@contextlib.contextmanager
def activation_sharding_ctx(rules: Callable):
    """Activate ``rules(name, shape) -> sharding | None`` for the block.

    Nestable: an inner context shadows the outer one and the outer rules
    are restored on exit.
    """
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[Callable]:
    """The active rule set, or None when no context is entered."""
    return _RULES.get()


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Constrain ``x`` to the active sharding for ``name``.

    Identity when no ``activation_sharding_ctx`` is active or when the
    active rules have no opinion about ``name`` (they return None) — so an
    unknown rule name is never an error, just an unconstrained tensor.
    """
    rules = _RULES.get()
    if rules is None:
        return x
    sharding = rules(name, tuple(x.shape))
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
