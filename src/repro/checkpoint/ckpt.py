"""Sharded, atomic, async-capable checkpointing with reshard-on-restore.

Layout::

    <dir>/step_<N>/
        manifest.json            # tree structure, shapes, dtypes, step
        shard_<host>.npz         # this host's param/opt leaves (flattened)
    <dir>/step_<N>.COMMITTED     # atomic commit marker (written last)

Restore rebuilds the pytree and ``jax.device_put``s each leaf with the
*target* shardings — which may describe a different mesh than the one that
wrote the checkpoint (elastic re-meshing: the runtime re-shards on restart).
Writes happen on a background thread (async checkpointing); ``wait()`` joins
before the next save or at shutdown.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def tree_paths(tree) -> list[str]:
    paths = []
    def one(kp, _):
        parts = []
        for k in kp:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        paths.append("/".join(parts))
    jax.tree_util.tree_map_with_path(one, tree)
    return paths


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True):
        """Snapshot to host memory synchronously, write to disk (optionally
        on a background thread), commit atomically."""
        self.wait()
        leaves, _ = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host now
        paths = tree_paths(tree)
        manifest = {
            "step": step,
            "leaves": [{"path": p, "shape": list(a.shape),
                        "dtype": str(a.dtype)} for p, a in
                       zip(paths, host_leaves)],
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(final + ".COMMITTED", "w") as f:
                f.write(str(step))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, f"step_{s}.COMMITTED"))
            except OSError:
                pass

    # -- restore ----------------------------------------------------------------
    def committed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.endswith(".COMMITTED"):
                try:
                    out.append(int(name[len("step_"):-len(".COMMITTED")]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Rebuild ``target_tree``-structured state; apply ``shardings``
        (possibly for a different mesh: elastic restore)."""
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(final, "shard_0.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
        _, treedef = _flatten(target_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest["step"]
