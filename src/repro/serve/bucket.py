"""Shape-bucketed warmup: the bucket lattice of pre-compiled block graphs.

Online serving cannot compile per request — it pads every prompt up to the
next bucket in a small seq-len lattice and replays that bucket's
pre-compiled whole-block ``CompiledGraph`` (PR 7).  ``ServingPool.warmup``
pre-traces and pre-compiles the full (arch × bucket) lattice through the
existing ``ArtifactCache``, so

  * identical kernel shapes dedupe *across* buckets and archs (every
    ``get_trace_config`` arch traces to the same block dims, so a second
    model family warms for free), and
  * a restart against the same cache file performs **zero** fresh compiles
    (``--expect-cached`` in the CLI / CI lane).

Every artifact is re-verified at admission time — ``verify_graph`` +
``verify_placement`` on the compiled graph — before it may serve traffic;
a corrupt artifact is evicted and recompiled fresh (warn-once, never a
crash).  When a learned-model store is active (``repro.search.model``,
the PR 5 path), the tuned kernels inside ``compile_program`` consult it
for never-tuned shapes; the pool itself stays policy-free.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

#: default seq-len bucket lattice (powers of two keep padding waste <= 2x).
DEFAULT_BUCKETS = (4, 8, 16)

#: KV-cache element size: the trace configs are exact-f32 end to end.
_KV_ELEM_BYTES = 4

_warned_corrupt: set = set()


def bucket_for(prompt_len: int, buckets=DEFAULT_BUCKETS) -> int:
    """The smallest lattice bucket that fits ``prompt_len`` (pad-up
    routing).  A prompt beyond the largest bucket has no compiled shape."""
    for b in sorted(buckets):
        if prompt_len <= b:
            return int(b)
    raise ValueError(f"prompt_len {prompt_len} exceeds the largest bucket "
                     f"{max(buckets)}; widen the lattice")


def kv_bytes(cfg, bucket: int) -> int:
    """Modeled KV-cache footprint of one request padded to ``bucket``:
    K and V, per kv-head, per layer, f32."""
    return int(bucket * 2 * cfg.n_kv_heads * cfg.hd * _KV_ELEM_BYTES
               * cfg.n_layers)


@dataclass
class WarmedArtifact:
    """One serving-pool entry: the compiled block for (arch, bucket)."""

    arch: str
    bucket: int
    cg: object              # repro.graph.CompiledGraph
    kv_bytes: int

    @property
    def makespan(self) -> float:
        return float(self.cg.makespan)


class ServingPool:
    """The warmed (arch × bucket) lattice of ``CompiledGraph`` artifacts.

    ``warmup()`` compiles the lattice (through ``cache`` when given) and
    admission-verifies every entry; ``route(request)`` returns the entry a
    request is served by.  ``admit`` is the verification gate and is public
    so corrupted artifacts (a bad cache payload, a hand-edited file) can be
    exercised directly.
    """

    def __init__(self, archs=("olmo-1b",), buckets=DEFAULT_BUCKETS, *,
                 cache=None, use_cache: bool | None = None,
                 fuse: bool = True):
        self.archs = tuple(archs)
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.cache = cache
        self.use_cache = (cache is not None) if use_cache is None \
            else bool(use_cache)
        self.fuse = fuse
        self.entries: dict[tuple[str, int], WarmedArtifact] = {}
        self.stats: dict = {}

    # -- compilation ---------------------------------------------------------
    def _compile(self, arch: str, bucket: int, *, use_cache: bool):
        from ..configs.registry import get_trace_config
        from ..graph.compile import compile_graph
        from ..graph.fuse import fuse_epilogues
        from ..graph.trace import trace_block
        cfg = get_trace_config(arch)
        g = trace_block(cfg, seq_len=bucket)
        decisions = []
        if self.fuse:
            g, decisions = fuse_epilogues(g)
        cg = compile_graph(g, cache=self.cache, use_cache=use_cache,
                           decisions=decisions)
        return cfg, cg

    def admit(self, cg, arch: str, bucket: int):
        """Admission gate: re-verify a ``CompiledGraph`` before it may
        serve; corrupt → warn once, evict, recompile fresh (cache
        bypassed).  Returns the pooled ``WarmedArtifact``."""
        from ..configs.registry import get_trace_config
        from ..verify import DiagnosticReport, verify_graph, verify_placement
        report = DiagnosticReport()
        report.extend(verify_graph(cg.graph))
        if cg.placement is not None:
            report.extend(verify_placement(cg.graph, cg.placement.locations,
                                           cg.placement.budget))
        evicted = False
        if not report.ok:
            key = (arch, bucket)
            if key not in _warned_corrupt:
                _warned_corrupt.add(key)
                warnings.warn(
                    f"evicting corrupt serving artifact {arch}/T{bucket} "
                    f"({len(report.errors)} error(s): "
                    f"{report.errors[0].rule}); recompiling fresh")
            _, cg = self._compile(arch, bucket, use_cache=False)
            evicted = True
        cfg = get_trace_config(arch)
        art = WarmedArtifact(arch=arch, bucket=bucket, cg=cg,
                             kv_bytes=kv_bytes(cfg, bucket))
        self.entries[(arch, bucket)] = art
        if evicted:
            self.stats["evicted"] = self.stats.get("evicted", 0) + 1
        return art

    def warmup(self) -> dict:
        """Pre-compile + admission-verify the whole lattice.  Returns the
        aggregate stats the CLI/CI lanes assert on (fresh vs cached
        compiles, cross-bucket dedupe)."""
        fresh = hits = nodes = 0
        unique: set[str] = set()
        self.stats = {"evicted": 0}
        for arch in self.archs:
            for bucket in self.buckets:
                _, cg = self._compile(arch, bucket,
                                      use_cache=self.use_cache)
                self.admit(cg, arch, bucket)
                cg = self.entries[(arch, bucket)].cg
                fresh += cg.stats["fresh_compiles"]
                hits += cg.stats["cache_hits"]
                nodes += cg.stats["nodes"]
                unique.update(cg.kernels)
        self.stats.update({
            "archs": len(self.archs), "buckets": len(self.buckets),
            "entries": len(self.entries), "nodes": nodes,
            "unique_programs": len(unique),
            "fresh_compiles": fresh, "cache_hits": hits,
        })
        return dict(self.stats)

    # -- routing -------------------------------------------------------------
    def get(self, arch: str, bucket: int) -> WarmedArtifact:
        return self.entries[(arch, bucket)]

    def route(self, request) -> WarmedArtifact:
        """The entry serving ``request``: its arch at the pad-up bucket."""
        return self.get(request.arch, bucket_for(request.prompt_len,
                                                 self.buckets))
