"""Seeded request generators — the traffic side of ``repro.serve``.

A ``Request`` is one inference call: it arrives at ``arrival`` seconds,
carries a ``prompt_len``-token prompt for one model family (``arch``) and
wants ``decode_len`` generated tokens.  Two arrival processes:

  * ``poisson`` — independent exponential inter-arrival gaps at ``rate``
    requests/second (the classic open-loop load model);
  * ``burst``   — requests arrive in simultaneous groups of ``burst_size``
    with exponential gaps *between* bursts, scaled so the long-run rate
    matches ``rate`` (the flash-crowd model).

Everything is drawn from one ``numpy.random.default_rng(seed)`` stream, so
a (seed, parameters) pair is bit-reproducible across machines — the serve
benchmarks and the CI lane rely on that determinism.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

import numpy as np

#: prompt/decode length menus the generator samples from by default; the
#: prompt menu stays inside the default bucket lattice (bucket.py).
DEFAULT_PROMPT_LENS = (2, 4, 6, 8, 12, 16)
DEFAULT_DECODE_LENS = (1, 2, 3, 4, 6, 8)


@dataclass(frozen=True)
class Request:
    """One inference request."""

    rid: int
    arch: str
    arrival: float          # seconds since the start of the run
    prompt_len: int         # tokens to prefill
    decode_len: int         # tokens to generate after the prefill

    @property
    def tokens(self) -> int:
        """Total tokens this request is worth (prefill step + decodes)."""
        return self.prompt_len + self.decode_len

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Request":
        return cls(rid=int(d["rid"]), arch=str(d["arch"]),
                   arrival=float(d["arrival"]),
                   prompt_len=int(d["prompt_len"]),
                   decode_len=int(d["decode_len"]))


def generate_requests(n: int, *, seed: int = 0, rate: float = 100.0,
                      arrival: str = "poisson", burst_size: int = 4,
                      archs=("olmo-1b",),
                      prompt_lens=DEFAULT_PROMPT_LENS,
                      decode_lens=DEFAULT_DECODE_LENS) -> list[Request]:
    """``n`` seeded requests, sorted by (arrival, rid).

    ``rate`` is the mean arrival rate in requests/second for both
    processes; ``archs`` / ``prompt_lens`` / ``decode_lens`` are uniform
    menus.  Deterministic: one rng stream, fixed draw order.
    """
    if n <= 0:
        return []
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        gaps = rng.exponential(scale=1.0 / rate, size=n)
        arrivals = np.cumsum(gaps)
    elif arrival == "burst":
        n_bursts = (n + burst_size - 1) // burst_size
        gaps = rng.exponential(scale=burst_size / rate, size=n_bursts)
        starts = np.cumsum(gaps)
        arrivals = np.repeat(starts, burst_size)[:n]
    else:
        raise ValueError(f"unknown arrival process {arrival!r} "
                         "(pick 'poisson' or 'burst')")
    arch_idx = rng.integers(0, len(archs), size=n)
    p_idx = rng.integers(0, len(prompt_lens), size=n)
    d_idx = rng.integers(0, len(decode_lens), size=n)
    reqs = [Request(rid=i, arch=archs[int(arch_idx[i])],
                    arrival=float(arrivals[i]),
                    prompt_len=int(prompt_lens[int(p_idx[i])]),
                    decode_len=int(decode_lens[int(d_idx[i])]))
            for i in range(n)]
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return reqs


def percentile(values, p: float) -> float:
    """Deterministic linear-interpolation percentile (p in [0, 100]) —
    the p50/p99 the serve metrics report.  Plain python on a sorted copy
    so the result is identical wherever the floats are."""
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    if len(vals) == 1:
        return vals[0]
    pos = (p / 100.0) * (len(vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac
