"""``python -m repro.serve`` / ``repro servesim`` — the serving simulator.

    repro servesim                                    # 32 reqs, online fifo
    repro servesim --requests 64 --rate 400 --seed 1  # heavier seeded load
    repro servesim --arrival burst --burst 8          # flash-crowd arrivals
    repro servesim --archs olmo-1b,qwen2-7b           # two model families
    repro servesim --scheduler static                 # one-shot baseline
    repro servesim --scheduler frozen                 # freeze online-fifo,
                                                      #   replay the trace
    repro servesim --compare                          # online vs static,
                                                      #   goodput both ways
    repro servesim --cache arts.json                  # warm through a cache
    repro servesim --cache arts.json --expect-cached  # 2nd run: 0 fresh
    repro servesim --tuning-model models.json         # PR 5 learned blocks
    repro servesim --verify --json report.json

Exit status: 0 iff the run completes every request, ``--verify`` finds no
``srv.*`` errors, ``--expect-cached`` sees zero fresh compiles, and (with
``--compare``) online goodput is at least static's.
"""
from __future__ import annotations

import argparse
import json


def _build_scheduler(name: str):
    from .scheduler import (FifoOnlineScheduler, StaticBatchScheduler,
                            make_static_scheduler)
    if name == "online":
        return FifoOnlineScheduler()
    if name == "static":
        return StaticBatchScheduler()
    if name == "frozen":
        return make_static_scheduler(FifoOnlineScheduler)()
    raise ValueError(f"unknown scheduler {name!r}")


def _print_metrics(label: str, m: dict) -> None:
    print(f"{label:<14} completed={m['completed']}/{m['n_requests']} "
          f"iters={m['iterations']} makespan={m['makespan_s']:.3e}s "
          f"p50={m['p50_latency_s']:.3e}s p99={m['p99_latency_s']:.3e}s "
          f"goodput={m['goodput_tps']:.1f} tok/s")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro servesim",
        description="Online continuous-batching serving simulation: seeded "
                    "request traffic against the warmed (arch x bucket) "
                    "lattice of compiled block graphs.")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate, requests/second (default 200)")
    ap.add_argument("--arrival", choices=("poisson", "burst"),
                    default="poisson")
    ap.add_argument("--burst", type=int, default=4,
                    help="burst size for --arrival burst (default 4)")
    ap.add_argument("--archs", default="olmo-1b",
                    help="comma list of model families (default olmo-1b)")
    ap.add_argument("--buckets", default=None,
                    help="comma list of seq-len buckets (default 4,8,16)")
    ap.add_argument("--scheduler", choices=("online", "static", "frozen"),
                    default="online")
    ap.add_argument("--compare", action="store_true",
                    help="run online AND static on the same workload; fail "
                         "if online goodput < static")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--kv-budget", type=int, default=1 << 20,
                    help="KV-cache byte budget (default 1 MiB)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="artifact cache for the bucket-lattice warmup")
    ap.add_argument("--expect-cached", action="store_true",
                    help="fail unless warmup performs zero fresh compiles")
    ap.add_argument("--tuning-model", default=None, metavar="PATH",
                    help="learned cost-model store: predict blocks for "
                         "never-tuned shapes (the PR 5 path)")
    ap.add_argument("--verify", action="store_true",
                    help="run the srv.* trace verifier on the result")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    from ..compile.cache import ArtifactCache
    from .bucket import DEFAULT_BUCKETS, ServingPool
    from .simulate import ServeParams, simulate_serving
    from .workload import generate_requests

    if args.tuning_model:
        from ..search.model import ModelStore, set_default_store
        set_default_store(ModelStore(args.tuning_model))

    archs = tuple(a.strip() for a in args.archs.split(",") if a.strip())
    buckets = DEFAULT_BUCKETS if args.buckets is None else \
        tuple(int(b) for b in args.buckets.split(","))
    cache = ArtifactCache(args.cache) if args.cache else None
    pool = ServingPool(archs=archs, buckets=buckets, cache=cache)
    warm = pool.warmup()
    print(f"warmup   {warm['entries']} bucket artifact(s) "
          f"({warm['archs']} arch x {warm['buckets']} bucket): "
          f"{warm['nodes']} nodes -> {warm['unique_programs']} unique "
          f"program(s), fresh={warm['fresh_compiles']} "
          f"cached={warm['cache_hits']} evicted={warm['evicted']}")

    failures = 0
    if args.expect_cached and warm["fresh_compiles"]:
        print(f"[FAIL] --expect-cached: {warm['fresh_compiles']} fresh "
              "compile(s) during warmup")
        failures += 1

    from .workload import DEFAULT_PROMPT_LENS
    prompt_lens = tuple(p for p in DEFAULT_PROMPT_LENS
                        if p <= max(buckets)) or (max(buckets),)
    requests = generate_requests(
        args.requests, seed=args.seed, rate=args.rate,
        arrival=args.arrival, burst_size=args.burst, archs=archs,
        prompt_lens=prompt_lens)
    params = ServeParams(max_batch=args.max_batch,
                         kv_budget=args.kv_budget)

    runs = {}
    names = ("online", "static") if args.compare else (args.scheduler,)
    for name in names:
        res = simulate_serving(requests, pool, _build_scheduler(name),
                               params)
        runs[name] = res
        _print_metrics(name, res.metrics)
        if res.metrics["starved"]:
            print(f"[FAIL] {name}: {res.metrics['starved']} request(s) "
                  "starved")
            failures += 1

    if args.compare:
        on, st = runs["online"].metrics, runs["static"].metrics
        ok = on["goodput_tps"] >= st["goodput_tps"]
        print(f"{'[ok]' if ok else '[FAIL]'} online goodput "
              f"{on['goodput_tps']:.1f} vs static {st['goodput_tps']:.1f} "
              "tok/s")
        failures += not ok

    if args.verify:
        from ..verify.serve import verify_serve_trace
        for name, res in runs.items():
            diags = verify_serve_trace(res.trace())
            errs = [d for d in diags if d.severity == "error"]
            print(f"{'[ok]' if not errs else '[FAIL]'} verify {name}: "
                  f"{len(errs)} error(s)")
            for d in errs:
                print(f"    {d}")
            failures += len(errs)

    if args.json:
        payload = {"schema": 1, "warmup": warm,
                   "runs": {name: res.trace() for name, res in runs.items()},
                   "failures": failures}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# report: {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
