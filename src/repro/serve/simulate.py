"""The request-level serving simulator — continuous batching on the event
timeline.

``ServeSim`` runs one engine: at every iteration the running batch is
grouped by (arch, bucket), each group costs
``ceil(n / tile_batch) × makespan`` of its pre-compiled block
(``simulate_kernel_graph``'s modeled makespan, via the ``ServingPool``
artifacts — the inner per-step cost oracle), and every member advances one
step (first the prefill, then one decode token per iteration).  Admission
happens only at iteration boundaries and is **KV-aware**: a request joins
the batch when its padded KV footprint fits the byte budget and the batch
cap, in the order the scheduler decided (head-of-line).  The iteration
timeline itself is laid on the fabric ``EventSim`` — one FIFO "engine"
resource, one task per iteration — so the run is auditable by
``verify_task_graph`` exactly like the collective timelines.

Everything is deterministic: seeded workload in, bit-identical
p50/p99/goodput out, on any machine.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field

from ..fabric.simulate import EventSim
from .bucket import bucket_for
from .workload import percentile

TRACE_SCHEMA = 1


@dataclass(frozen=True)
class ServeParams:
    """Engine/admission knobs (all modeled)."""

    max_batch: int = 8          # requests per iteration, hard cap
    kv_budget: int = 1 << 20    # KV-cache bytes the engine may hold
    tile_batch: int = 4         # requests one block replay serves at once
    slo_mult: float = 8.0       # SLO = slo_mult x the request's solo time

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class RequestRecord:
    """Per-request lifecycle: arrive → admit → bucket → … → complete."""

    rid: int
    arch: str
    arrival: float
    prompt_len: int
    decode_len: int
    bucket: int
    kv_bytes: int
    admitted: float | None = None
    completed: float | None = None

    @property
    def latency(self) -> float | None:
        if self.completed is None:
            return None
        return self.completed - self.arrival

    @property
    def tokens(self) -> int:
        return self.prompt_len + self.decode_len

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class _Live:
    """A request currently in the running batch."""

    record: RequestRecord
    steps_left: int
    wave: int


@dataclass
class ServeResult:
    """One simulated run: records, per-iteration timeline, metrics, and
    the auditable EventSim task pairs."""

    scheduler: str
    params: ServeParams
    buckets: tuple
    records: list = field(default_factory=list)
    iterations: list = field(default_factory=list)
    metrics: dict = field(default_factory=dict)
    tasks: list = field(default_factory=list)

    def completion_times(self) -> dict[int, float]:
        return {r.rid: r.completed for r in self.records
                if r.completed is not None}

    def trace(self) -> dict:
        """The serializable run trace ``repro.verify.serve`` checks."""
        return {"schema": TRACE_SCHEMA, "scheduler": self.scheduler,
                "params": self.params.to_dict(),
                "buckets": list(self.buckets),
                "requests": [r.to_dict() for r in self.records],
                "iterations": [dict(i) for i in self.iterations],
                "metrics": dict(self.metrics)}


class ServeSim:
    """Drive one scheduler over one workload against one warmed pool."""

    def __init__(self, requests, pool, scheduler, params: ServeParams):
        self.requests = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self.pool = pool
        self.scheduler = scheduler
        self.params = params
        self._by_rid = {r.rid: r for r in self.requests}

    def respawn(self, scheduler) -> "ServeSim":
        """A fresh simulator over the same workload/pool/params — what
        ``make_static_scheduler`` traces offline."""
        return ServeSim(self.requests, self.pool, scheduler, self.params)

    # -- per-request oracle --------------------------------------------------
    def request_kv(self, req) -> int:
        return self.pool.route(req).kv_bytes

    def solo_time(self, req) -> float:
        """Service time of the request alone on an idle engine: one block
        replay per step (prefill + each decode token)."""
        return (1 + req.decode_len) * self.pool.route(req).makespan

    def _iteration_cost(self, running: dict) -> float:
        groups: dict[tuple, int] = {}
        for lv in running.values():
            key = (lv.record.arch, lv.record.bucket)
            groups[key] = groups.get(key, 0) + 1
        cost = 0.0
        for (arch, bucket) in sorted(groups):
            n = groups[(arch, bucket)]
            cost += (math.ceil(n / self.params.tile_batch)
                     * self.pool.get(arch, bucket).makespan)
        return cost

    # -- admission control ---------------------------------------------------
    def _admit(self, pending, running, records, now) -> list[int]:
        """Pop head-of-line admissions whose constraints hold at ``now``:
        arrived, wave formed (wave >= 1: all members arrived, all lower
        waves drained), batch cap, KV budget."""
        admitted = []
        while pending:
            adm = pending[0]
            req = self._by_rid[adm.rid]
            if req.arrival > now:
                break
            if adm.wave >= 1:
                same = [a for a in pending if a.wave == adm.wave]
                if any(self._by_rid[a.rid].arrival > now for a in same):
                    break
                if any(lv.wave < adm.wave for lv in running.values()):
                    break
            if len(running) + 1 > self.params.max_batch:
                break
            need = self.request_kv(req)
            if self._kv_used + need > self.params.kv_budget:
                break
            pending.pop(0)
            rec = records[req.rid]
            rec.admitted = now
            running[req.rid] = _Live(record=rec,
                                     steps_left=1 + req.decode_len,
                                     wave=adm.wave)
            self._kv_used += need
            admitted.append(req.rid)
        return admitted

    # -- the run -------------------------------------------------------------
    def run(self) -> ServeResult:
        self.scheduler.init(self)
        records = {}
        for r in self.requests:
            b = bucket_for(r.prompt_len, self.pool.buckets)
            records[r.rid] = RequestRecord(
                rid=r.rid, arch=r.arch, arrival=r.arrival,
                prompt_len=r.prompt_len, decode_len=r.decode_len,
                bucket=b, kv_bytes=self.pool.get(r.arch, b).kv_bytes)
        pending: list = []
        running: dict[int, _Live] = {}
        iterations: list[dict] = []
        self._kv_used = 0
        esim = EventSim()
        prev_tid = None
        t = 0.0
        i_next = 0
        it = 0
        just_admitted: list[int] = []

        def collect_ready(now):
            nonlocal i_next
            ready = []
            while i_next < len(self.requests) \
                    and self.requests[i_next].arrival <= now:
                ready.append(self.requests[i_next])
                i_next += 1
            return ready

        while True:
            if not running:
                if i_next < len(self.requests):
                    t = max(t, self.requests[i_next].arrival)
                    new_ready = collect_ready(t)
                    pending += list(self.scheduler.schedule(new_ready, []))
                    just_admitted += self._admit(pending, running, records, t)
                    continue
                # no arrivals left: one final decision point, then either
                # the batch runs or whatever is still pending is starved —
                # the loop ends cleanly and srv.starvation flags the trace.
                pending += list(self.scheduler.schedule([], []))
                just_admitted += self._admit(pending, running, records, t)
                if not running:
                    break
            duration = self._iteration_cost(running)
            tid = f"iter:{it}"
            esim.add(tid, resource="engine", duration=duration,
                     deps=(prev_tid,) if prev_tid else (), ready=t)
            start, end = esim.run()[tid]
            if start != t:      # EventSim is the timing authority
                raise AssertionError(
                    f"iteration {it} start {start} != boundary {t}")
            iterations.append({
                "i": it, "start": start, "duration": duration,
                "running": sorted(running), "admitted": sorted(just_admitted),
                "kv_used": self._kv_used})
            just_admitted = []
            prev_tid, t, it = tid, end, it + 1
            finished = []
            for rid in list(running):
                lv = running[rid]
                lv.steps_left -= 1
                if lv.steps_left == 0:
                    lv.record.completed = t
                    self._kv_used -= lv.record.kv_bytes
                    finished.append(self._by_rid[rid])
                    del running[rid]
            new_ready = collect_ready(t)
            pending += list(self.scheduler.schedule(new_ready, finished))
            just_admitted += self._admit(pending, running, records, t)

        recs = [records[r.rid] for r in self.requests]
        metrics = self._metrics(recs, t, it)
        return ServeResult(
            scheduler=getattr(self.scheduler, "name", "?"),
            params=self.params, buckets=self.pool.buckets, records=recs,
            iterations=iterations, metrics=metrics, tasks=esim.tasks)

    def _metrics(self, recs, makespan: float, iterations: int) -> dict:
        done = [r for r in recs if r.completed is not None]
        lats = [r.latency for r in done]
        good_tokens = 0
        for r in done:
            slo = self.params.slo_mult * self.solo_time(self._by_rid[r.rid])
            if r.latency <= slo:
                good_tokens += r.tokens
        return {
            "n_requests": len(recs), "completed": len(done),
            "starved": len(recs) - len(done),
            "iterations": iterations, "makespan_s": makespan,
            "p50_latency_s": percentile(lats, 50.0),
            "p99_latency_s": percentile(lats, 99.0),
            "good_tokens": good_tokens,
            "goodput_tps": (good_tokens / makespan) if makespan > 0 else 0.0,
        }


def simulate_serving(requests, pool, scheduler,
                     params: ServeParams | None = None) -> ServeResult:
    """One-call entry: run ``scheduler`` over ``requests`` against the
    warmed ``pool``."""
    return ServeSim(requests, pool, scheduler,
                    params or ServeParams()).run()
