"""Request schedulers — estee's static-vs-online split, at request level.

The shapes mirror estee (SNIPPETS.md snippet 2): every scheduler gets
``init(simulator)`` and reacts to ``schedule(new_ready, new_finished)``
events; a ``StaticScheduler`` emits its whole plan once; a
``TracingScheduler`` records whatever its inner scheduler emits; and
``make_static_scheduler(cls)`` freezes an online policy by running a
traced *offline* simulation and replaying the recorded admissions.

A schedule result is a sequence of ``Admission(rid, wave)`` records — the
policy decision is the admission *order*, and the simulator derives the
timing from the hard constraints (arrival, KV budget, batch cap,
head-of-line order).  ``wave`` encodes formation semantics:

  * ``wave == 0`` — continuous: admit as soon as constraints allow;
  * ``wave >= 1`` — one-shot batch: every same-wave request must have
    arrived and every lower-wave request must have *completed* before any
    member is admitted (the static baseline's formation + drain waste).

Because timing is constraint-derived, replaying a traced admission
sequence through ``FixedScheduler`` reproduces the original run exactly —
the frozen-schedule acceptance test (and the ``srv.replay-drift`` rule)
pin that down.
"""
from __future__ import annotations

from typing import NamedTuple


class Admission(NamedTuple):
    """One scheduling decision: admit request ``rid`` under ``wave``
    semantics (0 = continuous, >=1 = atomic one-shot wave)."""

    rid: int
    wave: int = 0


class SchedulerBase:
    """React to request-level events; emit ``Admission`` records."""

    name = "base"

    def init(self, simulator) -> None:
        self.simulator = simulator

    def schedule(self, new_ready, new_finished):
        return ()


class StaticScheduler(SchedulerBase):
    """Offline planner: computes the whole admission plan once (it may
    inspect the simulator's full workload — it is an *offline* policy) and
    stays silent afterwards."""

    def init(self, simulator) -> None:
        super().init(simulator)
        self.scheduled = False

    def schedule(self, new_ready, new_finished):
        if self.scheduled:
            return ()
        self.scheduled = True
        return self.static_schedule()

    def static_schedule(self):
        raise NotImplementedError()


class FixedScheduler(StaticScheduler):
    """Replay a pre-recorded admission sequence (e.g. a frozen trace)."""

    name = "fixed"

    def __init__(self, schedules):
        self.schedules = [Admission(*a) for a in schedules]

    def static_schedule(self):
        return list(self.schedules)


class StaticBatchScheduler(StaticScheduler):
    """The one-shot baseline: FIFO waves of at most ``max_batch`` requests
    (each wave also sized to the KV budget), wave *k+1* forming only after
    wave *k* fully drains and every member has arrived."""

    name = "static"

    def static_schedule(self):
        sim = self.simulator
        plan, wave, batch, kv = [], 1, 0, 0
        for r in sim.requests:
            need = sim.request_kv(r)
            if batch and (batch + 1 > sim.params.max_batch
                          or kv + need > sim.params.kv_budget):
                wave += 1
                batch = kv = 0
            plan.append(Admission(r.rid, wave))
            batch += 1
            kv += need
        return plan


class FifoOnlineScheduler(SchedulerBase):
    """Continuous batching: every newly-arrived request is offered for
    admission immediately (wave 0); the simulator's KV-aware admission
    control decides *when* it actually joins the running batch."""

    name = "online-fifo"

    def schedule(self, new_ready, new_finished):
        return [Admission(r.rid, 0) for r in new_ready]


class TracingScheduler(SchedulerBase):
    """Record every admission an inner scheduler emits, in emission
    order — the trace ``make_static_scheduler`` freezes."""

    def __init__(self, scheduler):
        self.scheduler = scheduler
        self.name = f"traced-{scheduler.name}"

    def init(self, simulator) -> None:
        self.schedules: list[Admission] = []
        self.scheduler.init(simulator)

    def schedule(self, new_ready, new_finished):
        results = list(self.scheduler.schedule(new_ready, new_finished))
        self.schedules += results
        return results


def make_static_scheduler(cls):
    """Freeze an online policy: run a traced offline simulation of the
    same workload, then replay the recorded admission sequence as a static
    plan.  Deterministic simulator + constraint-derived timing ⇒ the
    frozen run completes every request at the identical time."""

    class Static(StaticScheduler):
        name = f"static-{cls.name}"

        def __init__(self, *args, **kwargs):
            self.scheduler = cls(*args, **kwargs)

        def static_schedule(self):
            tracer = TracingScheduler(self.scheduler)
            offline = self.simulator.respawn(tracer)
            offline.run()
            return list(tracer.schedules)

    return Static
