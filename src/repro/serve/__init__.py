"""``repro.serve`` — deterministic online serving on top of CompiledGraph.

The serving tier closes the loop the ROADMAP's north star asks for: heavy
request traffic against the compiled stack.  Four pieces:

  * ``workload``  — seeded Poisson/burst request generators (mixed
    prompt/decode lengths, multiple model families);
  * ``bucket``    — the shape-bucketed ``ServingPool``: pre-trace +
    pre-compile the (arch × bucket) lattice of whole-block
    ``CompiledGraph``s through the artifact cache, admission-verifying
    every artifact (``verify_graph``/``verify_placement``) before it may
    serve;
  * ``scheduler`` — estee's static-vs-online split at request level:
    ``StaticBatchScheduler`` one-shot waves vs ``FifoOnlineScheduler``
    continuous batching, plus ``TracingScheduler``/``make_static_scheduler``
    to freeze an online policy into a replayable plan;
  * ``simulate``  — the KV-aware request-level event loop on the fabric
    ``EventSim``, with each bucket's simulated graph makespan as the
    per-step cost oracle.

``python -m repro.serve`` (or ``repro servesim``) is the CLI;
``benchmarks/bench_serve.py`` reports p50/p99 and goodput-vs-load.
"""
from __future__ import annotations

from .bucket import (DEFAULT_BUCKETS, ServingPool, WarmedArtifact,
                     bucket_for, kv_bytes)
from .scheduler import (Admission, FifoOnlineScheduler, FixedScheduler,
                        SchedulerBase, StaticBatchScheduler, StaticScheduler,
                        TracingScheduler, make_static_scheduler)
from .simulate import (ServeParams, ServeResult, ServeSim, simulate_serving)
from .workload import (DEFAULT_DECODE_LENS, DEFAULT_PROMPT_LENS, Request,
                       generate_requests, percentile)

__all__ = [
    "Request", "generate_requests", "percentile", "DEFAULT_PROMPT_LENS",
    "DEFAULT_DECODE_LENS", "DEFAULT_BUCKETS", "ServingPool",
    "WarmedArtifact", "bucket_for", "kv_bytes", "Admission",
    "SchedulerBase", "StaticScheduler", "FixedScheduler",
    "StaticBatchScheduler", "FifoOnlineScheduler", "TracingScheduler",
    "make_static_scheduler", "ServeParams", "ServeResult", "ServeSim",
    "simulate_serving",
]
