"""train_step / serve_step builders shared by the dry-run, the trainer and
the server.  Everything is built AOT-friendly: callers lower these with
ShapeDtypeStructs and explicit in/out shardings."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import build_model
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, OptState, apply_updates, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state: OptState, batch):
        if grad_accum > 1:
            def micro(c, mb):
                loss, grads = jax.value_and_grad(model.loss)(params, mb)
                acc_loss, acc_g = c
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig, max_len: int = 0):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return model, prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, tokens, pos) -> (next_token_logits,
    cache).  For decode shapes the dry-run lowers THIS function (one new
    token against a seq_len-deep cache), not train_step."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits, cache

    return model, serve_step


def eval_shape_params(cfg: ModelConfig):
    """Parameter shapes without allocating anything."""
    model = build_model(cfg)
    return model, jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))


def eval_shape_opt_state(params_shape):
    return jax.eval_shape(lambda p: init_opt_state(p), params_shape)


def eval_shape_cache(cfg: ModelConfig, batch: int, seq_len: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, seq_len))
