"""Trip-count-aware analysis of optimized HLO text.

XLA's ``cost_analysis()`` counts a ``while`` body **once**, so scanned layer
stacks (our models scan over layers precisely to keep compile O(1) in depth)
under-report FLOPs, bytes and collective traffic by a factor of the trip
count.  This module re-derives all three from the HLO text with loop
multipliers applied:

  * computations are parsed into instruction lists with a shape symbol table,
  * ``while`` ops multiply their body/condition by the loop trip count
    (recovered from the scalar s32 constants in the condition computation),
  * ``fusion``/``call`` recurse at multiplier 1,
  * dot FLOPs = 2 x |output| x contraction size; bytes = operands + results
    at fusion granularity (mirrors XLA's accounting); collective bytes sum
    operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from ..core.dtypes import DTYPE_BYTES as _DTYPE_BYTES

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_ATOM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_ATOM.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_ATOM.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    params: dict            # param name -> shape string
    instrs: list            # of Instr
    table: dict             # name -> shape string (params + results)


_NAME_EQ = re.compile(r"%?([\w.\-]+)\s*=\s*")
_ARRAY_SHAPE = re.compile(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")


def _parse_instr(line: str):
    """(name, shape, op) for one instruction line; tuple types may contain
    /*index=N*/ comments, so tuples are matched with a paren counter."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    m = _NAME_EQ.match(s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    if rest.startswith("("):
        j = _match_paren(rest, 0)
        if j < 0:
            return None
        shape, rest2 = rest[:j + 1], rest[j + 1:].strip()
    else:
        sm = _ARRAY_SHAPE.match(rest)
        if not sm:
            return None
        shape, rest2 = sm.group(1), rest[sm.end():].strip()
    om = re.match(r"([\w\-]+)", rest2)
    if not om:
        return None
    return name, shape, om.group(1)


def _match_paren(s: str, start: int) -> int:
    depth = 0
    for j in range(start, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j
    return -1


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: `[ENTRY ]%name (params...) -> result {`
        if stripped.endswith("{") and ") -> " in stripped \
                and not stripped.startswith(("HloModule",)) \
                and "=" not in stripped.split("(")[0]:
            head = stripped
            if head.startswith("ENTRY "):
                head = head[len("ENTRY "):]
            name = head.split("(")[0].strip().lstrip("%").strip()
            popen = head.find("(")
            pclose = _match_paren(head, popen)
            params = {}
            if pclose > 0:
                for pm in re.finditer(
                        r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\]"
                        r"(?:\{[^}]*\})?|\([^:]*?\))",
                        head[popen + 1:pclose]):
                    params[pm.group(1)] = pm.group(2)
            cur = Computation(name, params, [], dict(params))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        parsed = _parse_instr(line)
        if parsed:
            name, shape, op = parsed
            cur.instrs.append(Instr(name, shape, op, line))
            cur.table[name] = shape
    return comps


def _called(line: str, attr: str) -> str | None:
    m = re.search(attr + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _operand_names(line: str) -> list[str]:
    """Names inside the op's argument parens."""
    parsed = _parse_instr(line)
    if not parsed:
        return []
    _, shape, op = parsed
    idx = line.find(op, line.find(shape) + len(shape))
    paren = line.find("(", idx)
    if paren < 0:
        return []
    j = _match_paren(line, paren)
    if j < 0:
        return []
    args = line[paren + 1:j]
    return re.findall(r"%([\w.\-]+)", args)


_KNOWN_TRIPS = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)')


def _trip_count(while_line: str, cond: Computation | None) -> int:
    """Loop bound: prefer XLA's known_trip_count backend_config annotation,
    fall back to the largest scalar int constant in the condition (scan
    emits `iter < L`)."""
    m = _KNOWN_TRIPS.search(while_line)
    if m:
        return max(1, int(m.group(1)))
    best = 1
    if cond is not None:
        for ins in cond.instrs:
            cm = re.search(r"constant\((\d+)\)", ins.line)
            if cm and ins.shape.strip().startswith(("s32[]", "u32[]", "s64[]")):
                best = max(best, int(cm.group(1)))
    return best


def _dot_flops(ins: Instr, table: dict) -> float:
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    ops = _operand_names(ins.line)
    if not ops:
        return 0.0
    lhs_shape = table.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2.0 * out_elems * contract


def _root_op(comp: Computation | None) -> str:
    if comp is None:
        return ""
    for ins in comp.instrs:
        if ins.line.strip().startswith("ROOT "):
            return ins.op
    return comp.instrs[-1].op if comp.instrs else ""


def _instr_bytes(ins: Instr, comp: Computation,
                 called: Computation | None) -> float:
    """HBM traffic of one standalone kernel (fusion or compute op).

    Dynamic-update-slice (incl. fused DUS — KV-cache writes!) updates its
    buffer in place: traffic is the update slice, not the whole buffer.
    Dynamic-slice reads only the slice it produces.
    """
    op = ins.op
    result_b = _shape_bytes(ins.shape)
    operand_b = [_shape_bytes(comp.table.get(on, ""))
                 for on in _operand_names(ins.line)]
    root = _root_op(called) if op == "fusion" else op
    if root == "dynamic-update-slice" or "dynamic-update-slice" in ins.name:
        small = [b for b in operand_b if b < result_b]
        return 2.0 * sum(small) if small else 2.0 * result_b
    if root == "dynamic-slice" or op == "dynamic-slice":
        return 2.0 * result_b
    return result_b + sum(operand_b)


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    collective_bytes_by_kind: dict = field(default_factory=dict)
    while_trip_counts: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_counts": dict(self.collective_counts),
            "collective_bytes_by_kind": dict(self.collective_bytes_by_kind),
            "while_trip_counts": sorted(self.while_trip_counts),
        }


def analyse_hlo(text: str) -> HloStats:
    comps = parse_computations(text)
    stats = HloStats()
    # ENTRY computation is the one no other computation calls; XLA marks it
    # with ENTRY in the header which our regex folds away — detect by absence
    # from call sites instead.
    called_names: set[str] = set()
    for c in comps.values():
        for ins in c.instrs:
            for attr in ("calls", "to_apply", "condition", "body"):
                t = _called(ins.line, attr)
                if t:
                    called_names.add(t)
    roots = [c for n, c in comps.items() if n not in called_names]
    # fall back: largest computation
    if not roots:
        roots = [max(comps.values(), key=lambda c: len(c.instrs))]

    fusion_like = {"fusion", "call", "async-start", "async-done"}
    _BYTE_OPS = {"dot", "convolution", "reduce", "reduce-window", "scatter",
                 "gather", "dynamic-slice", "dynamic-update-slice", "sort",
                 "custom-call", "rng", "rng-bit-generator", "cholesky",
                 "triangular-solve", "select-and-scatter", "pad", "concatenate"}

    def walk(comp: Computation, mult: float, inside_fusion: bool):
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                trips = _trip_count(ins.line, comps.get(cond))
                stats.while_trip_counts.append(trips)
                if body in comps:
                    walk(comps[body], mult * trips, False)
                if cond in comps:
                    walk(comps[cond], mult * trips, False)
                continue
            if op in fusion_like or op.startswith("async"):
                t = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if t in comps:
                    walk(comps[t], mult, True)
                if not inside_fusion:
                    stats.bytes_accessed += mult * _instr_bytes(
                        ins, comp, comps.get(t))
                continue
            if op in ("conditional",):
                for attr in ("true_computation", "false_computation"):
                    t = _called(ins.line, attr)
                    if t in comps:
                        walk(comps[t], mult, False)
            if op == "dot":
                stats.flops += mult * _dot_flops(ins, comp.table)
            kind = next((c2 for c2 in COLLECTIVES
                         if op == c2 or op.startswith(c2 + "-")), None)
            if kind and op.endswith("-done"):
                kind = None     # async pair: bytes counted at the -start op
            if kind:
                nb = 0
                for on in _operand_names(ins.line):
                    nb += _shape_bytes(comp.table.get(on, ""))
                if nb == 0:
                    nb = _shape_bytes(ins.shape)
                stats.collective_bytes += mult * nb
                stats.collective_bytes_by_kind[kind] = \
                    stats.collective_bytes_by_kind.get(kind, 0) + mult * nb
                stats.collective_counts[kind] = \
                    stats.collective_counts.get(kind, 0) + mult
            # Bytes are charged at fusion granularity for ops that would be
            # standalone kernels on the TPU target; layout / elementwise ops
            # are treated as fused into their neighbours (XLA:TPU fuses them;
            # the CPU backend used for the dry-run often does not).
            if not inside_fusion and op in _BYTE_OPS:
                stats.bytes_accessed += mult * _instr_bytes(ins, comp, None)

    for r in roots:
        walk(r, 1.0, False)
    return stats
