"""Batched serving driver: continuous-batching-style loop over prefill +
decode steps with a KV/recurrent cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke_config
from ..models import build_model


def generate(model, params, batch, max_new: int, greedy: bool = True,
             rng=None):
    """Prefill the prompt, then decode ``max_new`` tokens."""
    cfg = model.cfg
    tokens = batch["tokens"]
    B, T = tokens.shape
    prefix = cfg.frontend_tokens if cfg.family == "vlm" else 0
    max_len = prefix + T + max_new
    cache, logits = model.prefill(params, batch, max_len=max_len)
    out = []
    cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    for i in range(max_new):
        out.append(cur)
        pos = jnp.int32(prefix + T + i)
        logits, cache = model.decode_step(params, cache, cur, pos)
        if greedy:
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            cur = jax.random.categorical(k, logits).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true",
                    help="sample from the logits instead of greedy argmax")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result record as JSON (mirrors "
                         "benchmarks/run.py --json)")
    ap.add_argument("--tuned", action="store_true",
                    help="activate the repro.search tuning cache and the "
                         "repro.compile artifact cache for this process: "
                         "cache-aware ISAM kernels pick up autotuned configs "
                         "and precompiled CompiledKernel artifacts")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning cache path (with --tuned)")
    ap.add_argument("--compile-cache", default=None, metavar="PATH",
                    help="artifact cache path (with --tuned)")
    ap.add_argument("--tuning-model", default=None, metavar="PATH",
                    help="learned cost model store (with --tuned): untuned "
                         "GEMM shapes get a model-predicted BlockSpec")
    args = ap.parse_args(argv)

    if args.tuned:
        from .train import activate_caches
        activate_caches(args.tuning_cache, args.compile_cache, tag="serve",
                        model_path=args.tuning_model)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    # One split per consumer: reusing a PRNG key across init / randint /
    # normal / categorical correlates the streams.
    rng = jax.random.PRNGKey(0)
    rng, k_init, k_tokens, k_audio, k_gen = jax.random.split(rng, 5)
    params = model.init(k_init)

    batch = {"tokens": jax.random.randint(
        k_tokens, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model),
            cfg.activation_dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            k_audio, (args.batch, cfg.frontend_tokens, cfg.d_model)
        ).astype(cfg.activation_dtype)

    t0 = time.perf_counter()
    toks = generate(model, params, batch, args.gen,
                    greedy=not args.sample, rng=k_gen)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    total = args.batch * args.gen
    record = {
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": args.gen,
        "greedy": not args.sample,
        "tokens": int(total), "wall_s": round(dt, 3),
        "tok_per_s": round(total / dt, 2),
        "sample": np.asarray(toks[0, :8]).tolist(),
    }
    print(json.dumps(record))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "rows": [record]}, f, indent=2)
    return toks


if __name__ == "__main__":
    main()
