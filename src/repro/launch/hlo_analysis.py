"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``collective_bytes`` is not part of ``cost_analysis()``; we parse the
optimized HLO text and sum *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, per instructions.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from ..core.dtypes import DTYPE_BYTES as _DTYPE_BYTES
from ..core.sysgraph import GPU_HBM_BW, GPU_NVLINK_BW, GPU_PEAK_FLOPS

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9\[\],{}\s/#_:$extuple()]+?\)?)\s+"
    r"([\w\-]+)\(", re.IGNORECASE)


def shape_bytes(shape_str: str) -> int:
    """Bytes of one 'bf16[8,128]'-style shape (tuples handled upstream)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "total_count": self.total_count,
                "bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind)}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in optimized HLO text.

    Builds a symbol table of instruction result shapes, then resolves each
    collective's operand names; falls back to the op's own result shape when
    operands cannot be resolved (conservative, still a lower bound).
    """
    shapes: dict[str, str] = {}
    lines = hlo_text.splitlines()
    instr_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+"
        r"\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)")
    for ln in lines:
        m = instr_re.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)

    stats = CollectiveStats()
    for ln in lines:
        m = instr_re.match(ln)
        if not m:
            continue
        name, result_shape, op = m.groups()
        kind = next((c for c in COLLECTIVES
                     if op == c or op.startswith(c + "-")), None)
        if kind is None:
            continue
        # operand names inside the parens
        paren = ln[ln.find("("):]
        operand_names = re.findall(r"%?([\w.\-]+)", paren)
        nbytes = 0
        for on in operand_names:
            if on in shapes:
                nbytes += shape_bytes(shapes[on])
        if nbytes == 0:
            nbytes = shape_bytes(result_shape)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


# --------------------------------------------------------------------------- #
# Roofline terms (v5e constants; see DESIGN.md §7)
# --------------------------------------------------------------------------- #

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s / link

#: --target name -> (peak FLOP/s, HBM bytes/s, interconnect bytes/s) per
#: chip/device — the modeled machines of ``core.sysgraph``.  The dry-run
#: driver selects a row so the same lowered HLO yields a per-target
#: roofline (the nightly cross-backend sweep).
TARGET_ROOFLINES = {
    "tpu_v5e": (PEAK_FLOPS, HBM_BW, ICI_BW),
    "gpu_sm": (GPU_PEAK_FLOPS, GPU_HBM_BW, GPU_NVLINK_BW),
}


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int, target: str = "tpu_v5e") -> dict:
    peak, hbm_bw, link_bw = TARGET_ROOFLINES.get(
        target, TARGET_ROOFLINES["tpu_v5e"])
    compute_s = flops / (chips * peak)
    memory_s = hbm_bytes / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * link_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["roofline_s"] = bound
    terms["roofline_fraction_compute"] = (
        compute_s / bound if bound > 0 else 0.0)
    return terms
