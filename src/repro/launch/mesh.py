"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real (single) device.
"""
from __future__ import annotations

import jax

from ..dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic re-meshing."""
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"model axis {model} does not divide the {n} available "
            f"device(s); pass --model-axis dividing the device count")
    return compat.make_mesh((n // model, model), ("data", "model"))
