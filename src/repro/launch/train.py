"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --batch 8 --seq 128

Runs the full stack: config -> model -> sharded train step (host mesh) ->
deterministic data pipeline -> AdamW -> checkpoint/restart runtime with
straggler detection.  ``--smoke`` uses the reduced config so the driver runs
on CPU; on a real pod the same driver takes the production config and mesh.
"""
from __future__ import annotations

import argparse
import json

import jax

from ..checkpoint.ckpt import Checkpointer
from ..configs import ARCHS, get_config, get_smoke_config
from ..data.pipeline import DataConfig, add_frontend_stub, make_source
from ..dist.ctx import activation_sharding_ctx
from ..dist.sharding import make_activation_rules, param_shardings, replicated
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, init_opt_state
from ..runtime.fault_tolerance import TrainingRuntime
from .mesh import make_host_mesh
from .steps import make_train_step


def build_trainer(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
                  grad_accum: int = 1):
    model, train_step = make_train_step(cfg, opt_cfg, grad_accum)
    rules = make_activation_rules(mesh, cfg)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(params_shape, mesh, cfg)
    opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape))
    o_sh = type(opt_shape)(step=replicated(mesh),
                           mu=param_shardings(opt_shape.mu, mesh, cfg),
                           nu=param_shardings(opt_shape.nu, mesh, cfg))

    fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, None),
                 out_shardings=(p_sh, o_sh, replicated(mesh)),
                 donate_argnums=(0, 1))

    def init_state(rng):
        with mesh, activation_sharding_ctx(rules):
            params = jax.jit(model.init, out_shardings=p_sh)(rng)
            opt = jax.jit(init_opt_state, out_shardings=o_sh)(params)
        return params, opt

    def step(carry, batch):
        params, opt = carry
        with mesh, activation_sharding_ctx(rules):
            params, opt, metrics = fn(params, opt, batch)
        return (params, opt), metrics

    return model, init_state, step, (p_sh, o_sh)


def activate_caches(tuning_path=None, compile_path=None, tag="tuned",
                    model_path=None):
    """--tuned: point the process at the persistent tuning cache *and* the
    ``repro.compile`` artifact cache, so every cache-aware entry point
    (``tuned_block``/``plan_gemm``/``compile_gemm``...) reuses recorded
    winners and compiled artifacts.  ``model_path`` additionally activates
    the learned-cost-model store: GEMM shapes with no cache record get a
    model-predicted BlockSpec instead of the static default.  Shared by
    train and serve."""
    from ..compile.cache import ArtifactCache, set_default_artifact_cache
    from ..search.cache import TuningCache, set_default_cache
    cache = TuningCache(tuning_path)
    set_default_cache(cache)
    if model_path is not None:
        from ..search.model import ModelStore, set_default_store
        store = ModelStore(model_path)
        set_default_store(store)
        print(f"[{tag}] model store {store.path}: {len(store)} model(s)")
    print(f"[{tag}] tuning cache {cache.path}: {len(cache)} entries")
    for key in sorted(cache.keys()):
        rec = cache.lookup(key)
        print(f"[{tag}]   {rec.meta.get('case', key)}: "
              f"{rec.speedup:.2f}x ({rec.backend}/{rec.strategy})")
    acache = ArtifactCache(compile_path)
    set_default_artifact_cache(acache)
    print(f"[{tag}] compile artifact cache {acache.path}: "
          f"{len(acache)} artifact(s)")
    for key in sorted(acache.keys()):
        art = acache.lookup(key)
        if art is not None:
            print(f"[{tag}]   {art.program_name} on {art.graph_name}: "
                  f"cost={art.cost:.3e}s "
                  f"lowering={art.lowering.get('kind', '-')}")
    return cache, acache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    ap.add_argument("--tuned", action="store_true",
                    help="activate the repro.search tuning cache for this "
                         "process: any cache-aware ISAM kernel invoked "
                         "(repro.kernels tuned_block/plan_gemm) picks up "
                         "autotuned configs; the jnp model forward path is "
                         "unaffected until Pallas kernels are wired into it "
                         "(see ROADMAP follow-ups)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning cache path (with --tuned; default: the "
                         "repro.search default cache)")
    ap.add_argument("--compile-cache", default=None, metavar="PATH",
                    help="CompiledKernel artifact cache path (with --tuned; "
                         "default: the repro.compile default cache)")
    ap.add_argument("--tuning-model", default=None, metavar="PATH",
                    help="learned cost model store (with --tuned): GEMM "
                         "shapes with no tuning-cache record get a "
                         "model-predicted BlockSpec "
                         "(train one: python -m repro.search.model train)")
    args = ap.parse_args(argv)

    if args.tuned:
        activate_caches(args.tuning_cache, args.compile_cache,
                        model_path=args.tuning_model)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    mesh = make_host_mesh(model=args.model_axis)

    model, init_state, step, (p_sh, o_sh) = build_trainer(
        cfg, opt_cfg, mesh, args.grad_accum)

    dcfg = DataConfig(seed=17, global_batch=args.batch, seq_len=args.seq)
    source = make_source(dcfg, cfg)

    def batch_fn(s):
        b = source.batch(s)
        return add_frontend_stub(b, cfg, s, seed=dcfg.seed)

    ckpt = Checkpointer(args.ckpt_dir)
    rt = TrainingRuntime(ckpt, save_every=args.save_every)
    rt.install_preemption_handler()

    carry = None
    if args.resume:
        template = jax.eval_shape(
            lambda: (model.init(jax.random.PRNGKey(0)),
                     init_opt_state(
                         jax.eval_shape(
                             lambda: model.init(jax.random.PRNGKey(0))))))
        template = init_state(jax.random.PRNGKey(0))
        restored = rt.try_restore(template, shardings=(p_sh, o_sh))
        if restored is not None:
            carry = restored[0]
            print(f"resumed from step {restored[1]}")
    if carry is None:
        carry = init_state(jax.random.PRNGKey(0))

    losses = []

    def on_metrics(s, m, dt, slow):
        loss = float(m["loss"])
        losses.append(loss)
        flag = " SLOW" if slow else ""
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {loss:.4f} gnorm "
                  f"{float(m['grad_norm']):.3f} {dt*1e3:.0f}ms{flag}",
                  flush=True)

    carry = rt.run(carry, step, batch_fn, args.steps, on_metrics,
                   inject_fault_at=args.inject_fault_at)
    print(json.dumps({"final_loss": losses[-1] if losses else None,
                      "first_loss": losses[0] if losses else None,
                      "steps_run": len(losses),
                      "slow_steps": len(rt.straggler.slow_steps),
                      "resumed": rt.state.resumed}))
    return losses


if __name__ == "__main__":
    main()
