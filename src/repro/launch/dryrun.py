import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) cell this lowers + compiles
the real train/prefill/serve step with production shardings against 512
placeholder host devices, then records:

  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective traffic — parsed from the optimized HLO text,
  * roofline terms     — compute / memory / collective seconds (v5e).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, cell_applicable, get_config, input_specs
from ..dist.ctx import activation_sharding_ctx
from ..dist.sharding import (batch_shardings, cache_shardings,
                             make_activation_rules, param_shardings,
                             replicated)
from ..models.config import SHAPES
from .hlo_analysis import TARGET_ROOFLINES, roofline_terms
from .hlo_flops import analyse_hlo
from .mesh import make_production_mesh
from .steps import (eval_shape_cache, eval_shape_opt_state,
                    eval_shape_params, make_prefill_step, make_serve_step,
                    make_train_step)


def _with_sharding(shape_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Build + lower + compile one cell; returns (compiled, lowered, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    rules = make_activation_rules(mesh, cfg)
    model, params_shape = eval_shape_params(cfg)
    p_sh = param_shardings(params_shape, mesh, cfg)
    params_in = _with_sharding(params_shape, p_sh)
    specs = input_specs(cfg, shape)
    b_sh = batch_shardings(specs, mesh)
    batch_in = _with_sharding(specs, b_sh)

    if shape.kind == "train":
        _, train_step = make_train_step(cfg)
        opt_shape = eval_shape_opt_state(params_shape)
        # moments mirror the param shardings; step counter replicated
        o_sh = type(opt_shape)(
            step=replicated(mesh),
            mu=param_shardings(opt_shape.mu, mesh, cfg),
            nu=param_shardings(opt_shape.nu, mesh, cfg))
        opt_in = _with_sharding(opt_shape, o_sh)
        fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, replicated(mesh)),
                     donate_argnums=(0, 1))
        with mesh, activation_sharding_ctx(rules):
            lowered = fn.lower(params_in, opt_in, batch_in)
    elif shape.kind == "prefill":
        _, prefill_step = make_prefill_step(cfg, max_len=shape.seq_len)
        cache_shape = eval_shape_cache(cfg, shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(cache_shape, mesh, cfg)
        fn = jax.jit(prefill_step,
                     in_shardings=(p_sh, b_sh),
                     out_shardings=(c_sh, replicated(mesh)))
        with mesh, activation_sharding_ctx(rules):
            lowered = fn.lower(params_in, batch_in)
    else:  # decode
        _, serve_step = make_serve_step(cfg)
        cache_shape = eval_shape_cache(cfg, shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(cache_shape, mesh, cfg)
        cache_in = _with_sharding(cache_shape, c_sh)
        tok_in = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                      sharding=b_sh["tokens"])
        pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=replicated(mesh))
        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                   replicated(mesh)),
                     out_shardings=(replicated(mesh), c_sh),
                     donate_argnums=(1,))
        with mesh, activation_sharding_ctx(rules):
            lowered = fn.lower(params_in, cache_in, tok_in, pos_in)

    compiled = lowered.compile()
    return compiled, lowered, {"chips": chips, "cfg": cfg, "shape": shape}


def analyse(compiled, lowered, meta, elapsed: float,
            target: str = "tpu_v5e") -> dict:
    chips = meta["chips"]
    cfg, shape = meta["cfg"], meta["shape"]
    out: dict = {"arch": cfg.name, "shape": shape.name, "chips": chips,
                 "kind": shape.kind, "target": target,
                 "compile_s": round(elapsed, 2)}

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out["xla_cost_analysis"] = {"flops": float(cost.get("flops", 0.0)),
                                "bytes": float(cost.get("bytes accessed", 0.0))}

    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        out["memory"] = {"error": str(e)}

    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    # trip-count-aware analysis (XLA counts while bodies once; our models
    # scan over layers, so the multiplier matters — see hlo_flops.py)
    stats = analyse_hlo(text)
    flops = stats.flops
    nbytes = stats.bytes_accessed
    out["hlo_flops"] = flops
    out["hlo_bytes"] = nbytes
    out["while_trip_counts"] = sorted(stats.while_trip_counts)
    out["collectives"] = {
        "total_bytes": stats.collective_bytes,
        "total_count": sum(stats.collective_counts.values()),
        "bytes_by_kind": dict(stats.collective_bytes_by_kind),
        "count_by_kind": dict(stats.collective_counts),
    }

    # the parsed module is the per-device SPMD program; scale to the job.
    out["roofline"] = roofline_terms(flops * chips, nbytes * chips,
                                     stats.collective_bytes * chips, chips,
                                     target=target)
    # Model FLOPs: 6 * N_active * D(tokens) for training; decode counts 1 tok
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        out["model_flops"] = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        out["model_flops"] = 2 * n_active * tokens
    else:
        out["model_flops"] = 2 * n_active * shape.global_batch
    total_hlo = flops * chips
    out["model_flops_ratio"] = (out["model_flops"] / total_hlo
                                if total_hlo else None)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: dict | None = None,
             target: str = "tpu_v5e") -> dict:
    multi = mesh_kind == "multi"
    t0 = time.time()
    record: dict
    if not cell_applicable(arch, shape_name):
        record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "full-attention arch: long_500k inapplicable "
                            "(DESIGN.md §Arch-applicability)"}
    else:
        try:
            compiled, lowered, meta = lower_cell(arch, shape_name, multi,
                                                 overrides)
            record = analyse(compiled, lowered, meta, time.time() - t0,
                             target=target)
            record["mesh"] = mesh_kind
            record["status"] = "ok"
        except Exception as e:
            record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                      "status": "error", "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-4000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_kind}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=2, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--target", choices=sorted(TARGET_ROOFLINES),
                    default="tpu_v5e",
                    help="modeled machine for the roofline terms (the HLO "
                         "itself is target-independent); nightly sweeps "
                         "both, each into its own --out dir")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if not args.all and not (args.arch and args.shape):
        ap.error("either --all or both --arch and --shape are required")

    cells = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            path = os.path.join(args.out,
                                f"{arch}__{shape}__{mesh_kind}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip] {arch} {shape} {mesh_kind} (cached)")
                    continue
            t0 = time.time()
            rec = run_cell(arch, shape, mesh_kind, args.out,
                           target=args.target)
            dt = time.time() - t0
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f"flops={rec['hlo_flops']:.3g} "
                         f"coll={rec['collectives']['total_bytes']:.3g}B "
                         f"dom={r['dominant']}")
            elif status == "error":
                extra = rec["error"][:160]
                failures += 1
            print(f"[{status}] {arch} {shape} {mesh_kind} ({dt:.0f}s) {extra}",
                  flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
