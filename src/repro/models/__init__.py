from .api import build_model
from .config import (FULL_ATTENTION_ARCHS, SHAPES, ModelConfig, ShapeConfig,
                     shape_applicable)

__all__ = ["build_model", "ModelConfig", "ShapeConfig", "SHAPES",
           "FULL_ATTENTION_ARCHS", "shape_applicable"]
