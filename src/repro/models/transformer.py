"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Layers are parameter-stacked and driven by ``jax.lax.scan`` so compile time
and HLO size are O(1) in depth — essential for the 512-device dry-runs.
Remat (``jax.checkpoint``) wraps the scanned body when cfg.remat is set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from .attention import (attention, decode_attention, init_attn_params,
                        init_kv_cache, prefill_attention)
from .config import ModelConfig
from .layers import cross_entropy_loss, init_dense, norm_fn
from .moe import init_moe_params, moe_ffn


def init_ffn_params(rng, cfg: ModelConfig, dtype) -> dict:
    if cfg.n_experts:
        return init_moe_params(rng, cfg, dtype)
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {"w_gate": init_dense(ks[0], D, F, dtype),
            "w_up": init_dense(ks[1], D, F, dtype),
            "w_down": init_dense(ks[2], F, D, dtype)}


def ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.n_experts:
        return moe_ffn(p, x, cfg)
    g = jax.nn.silu(jnp.dot(x, p["w_gate"]))
    u = jnp.dot(x, p["w_up"])
    h = constrain(g * u, "ffn_hidden")
    return constrain(jnp.dot(h, p["w_down"]), "residual")


def init_layer_params(rng, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {"attn": init_attn_params(k1, cfg, dtype),
         "ffn": init_ffn_params(k2, cfg, dtype)}
    if cfg.norm == "rmsnorm":
        p["norm1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def _norms(p, cfg):
    nf = norm_fn(cfg.norm)
    n1 = functools.partial(nf, scale=p.get("norm1"))
    n2 = functools.partial(nf, scale=p.get("norm2"))
    return n1, n2


def layer_fwd(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    n1, n2 = _norms(p, cfg)
    x = constrain(x, "residual")
    x = x + attention(p["attn"], n1(x), cfg)
    x = x + ffn(p["ffn"], n2(x), cfg)
    return constrain(x, "residual")


def layer_prefill(p: dict, x: jax.Array, cfg: ModelConfig, max_len: int = 0):
    n1, n2 = _norms(p, cfg)
    a, cache = prefill_attention(p["attn"], n1(x), cfg, max_len=max_len)
    x = x + a
    x = x + ffn(p["ffn"], n2(x), cfg)
    return x, cache


def layer_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                 cfg: ModelConfig):
    n1, n2 = _norms(p, cfg)
    a, cache = decode_attention(p["attn"], n1(x), cache, pos, cfg)
    x = x + a
    x = x + ffn(p["ffn"], n2(x), cfg)
    return x, cache


class DecoderLM:
    """Families: dense (olmo/qwen*), moe (mixtral/phi3.5-moe), vlm (llava)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)

    # ---- parameters -------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)
        layers = jax.vmap(
            lambda k: init_layer_params(k, cfg, self.pdtype))(layer_keys)
        p = {
            "embed": (jax.random.normal(
                ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(self.pdtype),
            "layers": layers,
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init_dense(ks[2], cfg.d_model, cfg.vocab_size,
                                      self.pdtype)
        return p

    # ---- embedding / head ----------------------------------------------------
    def _embed_tokens(self, params, batch) -> jax.Array:
        x = constrain(jnp.take(params["embed"].astype(self.dtype),
                               batch["tokens"], axis=0), "residual")
        if self.cfg.family == "vlm" and "patch_embeds" in batch:
            # anyres frontend stub: precomputed patch embeddings are prefixed
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(self.dtype), x], axis=1)
        return x

    def _head(self, params, x) -> jax.Array:
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["lm_head"]).astype(self.dtype)
        return constrain(jnp.dot(x, w), "logits")

    # ---- scanned layer stack ---------------------------------------------------
    def _run_layers(self, params, x) -> jax.Array:
        cfg = self.cfg
        cast = functools.partial(jax.tree.map,
                                 lambda a: a.astype(self.dtype)
                                 if a.dtype == self.pdtype else a)

        def body(h, layer_p):
            return layer_fwd(cast(layer_p), h, cfg), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    def logits(self, params, batch) -> jax.Array:
        x = self._embed_tokens(params, batch)
        x = self._run_layers(params, x)
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return self._head(params, x)

    def loss(self, params, batch) -> jax.Array:
        logits = self.logits(params, batch)
        T = batch["tokens"].shape[1]
        logits_txt = logits[:, -T:]                      # vlm: text positions
        return cross_entropy_loss(logits_txt[:, :-1], batch["tokens"][:, 1:])

    # ---- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        one = init_kv_cache(cfg, batch, seq_len, self.dtype)
        return {"kv": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)}

    def prefill(self, params, batch, max_len: int = 0):
        cfg = self.cfg
        x = self._embed_tokens(params, batch)
        cast = functools.partial(jax.tree.map,
                                 lambda a: a.astype(self.dtype)
                                 if a.dtype == self.pdtype else a)

        def body(h, layer_p):
            h2, cache = layer_prefill(cast(layer_p), h, cfg, max_len=max_len)
            return h2, cache

        if cfg.remat:
            body = jax.checkpoint(body)
        x, caches = jax.lax.scan(body, x, params["layers"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return {"kv": caches}, self._head(params, x[:, -1:])

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B,) int32; pos scalar int32 absolute position."""
        cfg = self.cfg
        x = jnp.take(params["embed"].astype(self.dtype), tokens[:, None],
                     axis=0)
        cast = functools.partial(jax.tree.map,
                                 lambda a: a.astype(self.dtype)
                                 if a.dtype == self.pdtype else a)

        def body(h, xs):
            layer_p, layer_cache = xs
            h2, new_cache = layer_decode(cast(layer_p), h, layer_cache, pos,
                                         cfg)
            return h2, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return self._head(params, x)[:, 0], {"kv": new_caches}
