"""Mamba selective-SSM block (for the Jamba hybrid).

Training uses a chunked scan: lax.scan over time-chunks with an inner
first-order recurrence unrolled via associative_scan — compile size O(1) in
sequence length, memory O(chunk).  Decode carries the (d_inner, d_state)
state plus the causal-conv tail: O(1) per generated token, which is what
makes jamba's long_500k shape runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense


def init_mamba_params(rng, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    di = cfg.mamba_expand * D
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(rng, 6)
    return {
        "w_in": init_dense(ks[0], D, 2 * di, dtype),          # x and gate
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bcdt": init_dense(ks[2], di, 2 * ds + 1, dtype),   # B, C, dt
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),
        "A_log": jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)
                         )[None, :].repeat(di, 0),            # (di, ds)
        "D_skip": jnp.ones((di,), jnp.float32),
        "w_out": init_dense(ks[3], di, D, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x (B, T, di), w (dc, di)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(dc):          # dc is 4: unrolled adds, no gather
        out = out + pad[:, i:i + x.shape[1]] * w[i]
    return out + b


def _selective_scan(x, dt, A, Bm, Cm, chunk: int):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t.

    x: (B, T, di); dt: (B, T, di); A: (di, ds); Bm/Cm: (B, T, ds).

    The (B, T, di, ds) decay/input tensors are built INSIDE the chunk body —
    materializing them for the full sequence costs T/chunk x more activation
    memory (measured: 4.3 TB/device on jamba train_4k before this change).
    """
    Bb, T, di = x.shape
    ds = A.shape[1]
    nc = max(1, T // chunk)
    chunk = T // nc

    def chunk_body(h0, xs):
        x_c, dt_c, B_c, C_c = xs              # (c,B,di) (c,B,di) (c,B,ds) x2
        decay = jnp.exp(dt_c[..., None] * A[None, None])      # (c,B,di,ds)
        inp = (dt_c * x_c)[..., None] * B_c[:, :, None, :]

        def assoc(a, b):
            da, ia = a
            db, ib = b
            return (da * db, ib + db * ia)
        d_scan, i_scan = jax.lax.associative_scan(
            assoc, (decay, inp), axis=0)
        h = d_scan * h0[None] + i_scan                        # (c,B,di,ds)
        y = jnp.einsum("cbis,cbs->cbi", h, C_c)
        return h[-1], y

    def to_chunks(a):
        # (B, T, ...) -> (nc, chunk, B, ...)
        return jnp.moveaxis(
            a.reshape((Bb, nc, chunk) + a.shape[2:]), (1, 2), (0, 1))

    h0 = jnp.zeros((Bb, di, ds), x.dtype)
    _, ys = jax.lax.scan(chunk_body, h0,
                         (to_chunks(x), to_chunks(dt), to_chunks(Bm),
                          to_chunks(Cm)))                     # (nc,c,B,di)
    y = jnp.moveaxis(ys, (0, 1), (1, 2)).reshape(Bb, T, di)
    return y


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig,
                chunk: int = 256) -> jax.Array:
    B, T, D = x.shape
    di = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    up = jnp.dot(x, p["w_in"])
    xi, gate = up[..., :di], up[..., di:]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    bcdt = jnp.dot(xi, p["w_bcdt"])
    Bm = bcdt[..., :ds].astype(jnp.float32)
    Cm = bcdt[..., ds:2 * ds].astype(jnp.float32)
    dt = jax.nn.softplus(bcdt[..., -1:].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    c = min(chunk, T)
    while T % c:
        c -= 1
    y = _selective_scan(xi.astype(jnp.float32), dt, A, Bm, Cm, c)
    y = y + xi.astype(jnp.float32) * p["D_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(gate)
    return jnp.dot(y, p["w_out"])


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = cfg.mamba_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    }


def mamba_decode_step(p: dict, x: jax.Array, state: dict,
                      cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """x (B, 1, D) -> (B, 1, D); O(1) recurrent state."""
    B, _, D = x.shape
    di = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    up = jnp.dot(x[:, 0], p["w_in"])
    xi, gate = up[..., :di], up[..., di:]
    # causal conv over [conv_tail ; x_t]
    window = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B,dc,di)
    conv = jnp.einsum("bci,ci->bi", window, p["conv_w"]) + p["conv_b"]
    xi = jax.nn.silu(conv)
    bcdt = jnp.dot(xi, p["w_bcdt"])
    Bm = bcdt[..., :ds].astype(jnp.float32)
    Cm = bcdt[..., ds:2 * ds].astype(jnp.float32)
    dt = jax.nn.softplus(bcdt[..., -1:].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt[..., None] * A[None])                  # (B,di,ds)
    h = decay * state["h"] + (dt * xi.astype(jnp.float32))[..., None] \
        * Bm[:, None, :]
    y = jnp.einsum("bis,bs->bi", h, Cm) + xi.astype(jnp.float32) * p["D_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(gate)
    out = jnp.dot(y, p["w_out"]).reshape(B, 1, D)
    return out, {"h": h, "conv": window[:, 1:]}
