"""Shared layer substrate: norms, rotary embedding, initializers, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def nonparam_ln(x, scale=None, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    del scale
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_fn(kind: str):
    return {"rmsnorm": rmsnorm, "nonparam_ln": nonparam_ln}[kind]


def init_dense(rng, fan_in: int, fan_out: int, dtype) -> jax.Array:
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return (jax.random.normal(rng, (fan_in, fan_out), jnp.float32)
            * scale).astype(dtype)


def rotary(pos: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables at integer positions ``pos`` (any shape)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., T, H, hd); cos/sin: (T, hd/2) broadcast over batch/heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if cos.ndim < x.ndim - 1 else cos
    s = sin[..., None, :] if sin.ndim < x.ndim - 1 else sin
    # reshape cos/sin (T, half) -> broadcast to (..., T, 1, half)
    while c.ndim < x.ndim:
        c, s = c[None], s[None]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jax.nn.silu(jnp.dot(x, w_gate))
    u = jnp.dot(x, w_up)
    return jnp.dot(g * u, w_down)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Stable next-token cross entropy; logits (B, T, V), labels (B, T).

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis so vocabulary-sharded logits never get all-gathered
    (the contraction lowers to a per-shard dot + psum under GSPMD)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
