"""Attention: GQA, causal / sliding-window masks, rotary, KV-cache decode.

Shapes follow (B, T, H, hd).  GQA repeats KV heads by gather-free reshape;
sliding-window attention masks beyond the window (Mixtral).  Decode attends a
single query token against the cache — for SWA the cache is a rolling buffer
of ``window`` positions, which is what makes 500k-token contexts O(window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from .config import ModelConfig
from .layers import apply_rotary, init_dense, rotary

NEG_INF = -1e30


def init_attn_params(rng, cfg: ModelConfig, dtype) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_dense(ks[0], D, H * hd, dtype),
        "wk": init_dense(ks[1], D, KV * hd, dtype),
        "wv": init_dense(ks[2], D, KV * hd, dtype),
        "wo": init_dense(ks[3], H * hd, D, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    B, T, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.dot(x, p["wq"])
    k = jnp.dot(x, p["wk"])
    v = jnp.dot(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (constrain(q.reshape(B, T, H, hd), "heads"),
            constrain(k.reshape(B, T, KV, hd), "heads"),
            constrain(v.reshape(B, T, KV, hd), "heads"))


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV head."""
    B, S, KV, hd = k.shape
    rep = n_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, rep, hd)) \
              .reshape(B, S, n_heads, hd)


#: query-chunk size above which attention runs chunked (memory O(T*chunk))
ATTN_CHUNK = 2048


def _attend(q, k, v, positions, cfg: ModelConfig, causal: bool) -> jax.Array:
    """Softmax attention on projected/rotated q, k, v (B, T|S, H, hd).
    Long sequences are processed in query chunks (lax.scan): exact softmax
    per row, activation memory O(T * chunk) instead of O(T^2)."""
    B, T, H, hd = q.shape
    S = k.shape[1]

    def block(q_blk, pos_blk):
        scores = constrain(jnp.einsum("bthd,bshd->bhts", q_blk, k),
                           "scores") / (hd ** 0.5)
        if causal:
            i = pos_blk[:, None]
            j = positions[None, :S] if positions.shape[0] >= S \
                else jnp.arange(S)[None, :]
            mask = j <= i
            if cfg.sliding_window:
                mask &= j > i - cfg.sliding_window
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                           ).astype(q_blk.dtype)
        return jnp.einsum("bhts,bshd->bthd", w, v)

    if T <= ATTN_CHUNK or T % ATTN_CHUNK:
        return block(q, positions)

    nc = T // ATTN_CHUNK
    qc = jnp.moveaxis(q.reshape(B, nc, ATTN_CHUNK, H, hd), 1, 0)
    pc = positions.reshape(nc, ATTN_CHUNK)

    def body(_, xs):
        qb, pb = xs
        return None, block(qb, pb)

    _, outs = jax.lax.scan(body, None, (qc, pc))      # (nc, B, c, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H, hd)


def attention(p: dict, x: jax.Array, cfg: ModelConfig,
              causal: bool = True, positions: jax.Array | None = None) -> jax.Array:
    """Full self-attention over (B, T, D)."""
    B, T, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q, k, v = _project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(T)
    cos, sin = rotary(positions, hd, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    out = _attend(q, k, v, positions, cfg, causal)
    out = constrain(out, "heads").reshape(B, T, H * hd)
    return constrain(jnp.dot(out, p["wo"]), "residual")


# --------------------------------------------------------------------------- #
# KV-cache serving
# --------------------------------------------------------------------------- #


def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> dict:
    """Cache for one attention layer.  SWA archs keep a rolling buffer of
    ``sliding_window`` slots; full attention keeps all ``seq_len``."""
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, S, KV, hd), dtype),
        "v": jnp.zeros((batch, S, KV, hd), dtype),
    }


def prefill_attention(p, x, cfg: ModelConfig, max_len: int = 0):
    """Run attention AND return the layer cache, sized for subsequent decode
    up to ``max_len`` positions (rolling buffer for SWA).  QKV is projected
    once and shared between the attention output and the cache."""
    B, T, D = x.shape
    H = cfg.n_heads
    q, k, v = _project_qkv(p, x, cfg)
    pos = jnp.arange(T)
    cos, sin = rotary(pos, cfg.hd, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    out = _attend(q, _expand_kv(k, H), _expand_kv(v, H), pos, cfg,
                  causal=True)
    out = constrain(out, "heads").reshape(B, T, H * cfg.hd)
    out = constrain(jnp.dot(out, p["wo"]), "residual")
    max_len = max(max_len, T)
    if cfg.sliding_window:
        S = min(cfg.sliding_window, max_len)
        if T > S:
            k, v = k[:, -S:], v[:, -S:]
        elif S > T:
            k = jnp.pad(k, ((0, 0), (0, S - T), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, S - T), (0, 0), (0, 0)))
        # rolling-buffer layout: position p lives at slot p % S
        k = jnp.roll(k, T % S if T > S else 0, axis=1)
        v = jnp.roll(v, T % S if T > S else 0, axis=1)
    elif max_len > T:
        k = jnp.pad(k, ((0, 0), (0, max_len - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, max_len - T), (0, 0), (0, 0)))
    return out, {"k": k, "v": v}


def decode_attention(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                     cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, D), cache K/V (B, S, KV, hd), pos scalar
    (current absolute position).  Returns (out (B, 1, D), new cache)."""
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, cfg)
    cos, sin = rotary(pos[None], hd, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)

    slot = pos % S if cfg.sliding_window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    kk = _expand_kv(ck, H)   # (B, S, H, hd)
    vv = _expand_kv(cv, H)
    scores = jnp.einsum("bthd,bshd->bhts", q, kk)[:, :, 0] / (hd ** 0.5)
    span = jnp.arange(S)
    if cfg.sliding_window:
        age = (pos % S - span) % S          # rolling-buffer age of each slot
        valid = (age < cfg.sliding_window) & (span < S) & (age <= pos)
    else:
        valid = span <= pos
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhs,bshd->bhd", w, vv).reshape(B, 1 * H * hd)
    out = jnp.dot(out, p["wo"]).reshape(B, 1, D)
    return out, {"k": ck, "v": cv}


def cross_attention(p: dict, x: jax.Array, kv_src: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Encoder-decoder cross attention (whisper): queries from x, keys and
    values from the encoder output (no mask, no rotary)."""
    B, T, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = kv_src.shape[1]
    q = jnp.dot(x, p["wq"]).reshape(B, T, H, hd)
    k = jnp.dot(kv_src, p["wk"]).reshape(B, S, KV, hd)
    v = jnp.dot(kv_src, p["wv"]).reshape(B, S, KV, hd)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / (hd ** 0.5)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhts,bshd->bthd", w, v).reshape(B, T, H * hd)
    return jnp.dot(out, p["wo"])
