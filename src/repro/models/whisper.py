"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_audio, d_model) directly to the encoder.
The decoder is a causal transformer with cross-attention; decode caches both
the self-attention KV and the per-layer cross KV projections."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .attention import (attention, cross_attention, decode_attention,
                        init_attn_params, init_kv_cache, prefill_attention)
from .config import ModelConfig
from .layers import cross_entropy_loss, init_dense, norm_fn
from .transformer import ffn, init_ffn_params


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"attn": init_attn_params(k1, cfg, self.pdtype),
                    "ffn": init_ffn_params(k2, cfg, self.pdtype),
                    "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                    "norm2": jnp.ones((cfg.d_model,), jnp.float32)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"self": init_attn_params(k1, cfg, self.pdtype),
                    "cross": init_attn_params(k2, cfg, self.pdtype),
                    "ffn": init_ffn_params(k3, cfg, self.pdtype),
                    "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                    "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                    "norm3": jnp.ones((cfg.d_model,), jnp.float32)}

        enc = jax.vmap(enc_layer)(jax.random.split(ks[0], cfg.encoder_layers))
        dec = jax.vmap(dec_layer)(jax.random.split(ks[1], cfg.n_layers))
        return {
            "embed": (jax.random.normal(
                ks[2], (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(self.pdtype),
            "enc": enc,
            "dec": dec,
            "norm_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": init_dense(ks[3], cfg.d_model, cfg.vocab_size,
                                  self.pdtype),
        }

    def _cast(self, tree):
        return jax.tree.map(
            lambda a: a.astype(self.dtype) if a.dtype == self.pdtype else a,
            tree)

    # ---- encoder --------------------------------------------------------------
    def encode(self, params, audio_embeds) -> jax.Array:
        cfg = self.cfg
        nf = norm_fn(cfg.norm)
        x = audio_embeds.astype(self.dtype)

        def body(h, lp):
            lp = self._cast(lp)
            h = h + attention(lp["attn"], nf(h, lp["norm1"]), cfg,
                              causal=False)
            h = h + ffn(lp["ffn"], nf(h, lp["norm2"]), cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return norm_fn("rmsnorm")(x, params["norm_enc"])

    # ---- decoder (teacher forcing) ----------------------------------------------
    def logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        nf = norm_fn(cfg.norm)
        enc_out = self.encode(params, batch["audio_embeds"])
        x = jnp.take(params["embed"].astype(self.dtype), batch["tokens"],
                     axis=0)

        def body(h, lp):
            lp = self._cast(lp)
            h = h + attention(lp["self"], nf(h, lp["norm1"]), cfg)
            h = h + cross_attention(lp["cross"], nf(h, lp["norm2"]), enc_out,
                                    cfg)
            h = h + ffn(lp["ffn"], nf(h, lp["norm3"]), cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return jnp.dot(x, params["lm_head"].astype(self.dtype))

    def loss(self, params, batch) -> jax.Array:
        logits = self.logits(params, batch)
        return cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])

    # ---- serving -----------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        kv = init_kv_cache(cfg, batch, seq_len, self.dtype)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            kv)
        Ta = cfg.frontend_tokens or 1500
        KV, hd = cfg.n_kv_heads, cfg.hd
        cross = {
            "k": jnp.zeros((cfg.n_layers, batch, Ta, KV, hd), self.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, Ta, KV, hd), self.dtype),
        }
        return {"kv": kv, "cross": cross}

    def prefill(self, params, batch, max_len: int = 0):
        """Encode audio, consume the text prompt, cache self+cross KV."""
        cfg = self.cfg
        nf = norm_fn(cfg.norm)
        enc_out = self.encode(params, batch["audio_embeds"])
        x = jnp.take(params["embed"].astype(self.dtype), batch["tokens"],
                     axis=0)
        B, Ta, D = enc_out.shape
        KV, hd = cfg.n_kv_heads, cfg.hd

        def body(h, lp):
            lp = self._cast(lp)
            a, kv = prefill_attention(lp["self"], nf(h, lp["norm1"]), cfg,
                                      max_len=max_len)
            h = h + a
            ck = jnp.dot(enc_out, lp["cross"]["wk"]).reshape(B, Ta, KV, hd)
            cv = jnp.dot(enc_out, lp["cross"]["wv"]).reshape(B, Ta, KV, hd)
            h = h + cross_attention(lp["cross"], nf(h, lp["norm2"]), enc_out,
                                    cfg)
            h = h + ffn(lp["ffn"], nf(h, lp["norm3"]), cfg)
            return h, (kv, {"k": ck, "v": cv})

        x, (kvs, crosses) = jax.lax.scan(body, x, params["dec"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        logits = jnp.dot(x[:, -1:], params["lm_head"].astype(self.dtype))
        return {"kv": kvs, "cross": crosses}, logits

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        nf = norm_fn(cfg.norm)
        x = jnp.take(params["embed"].astype(self.dtype), tokens[:, None],
                     axis=0)
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

        def body(h, xs):
            lp, kv_c, cross_c = xs
            lp = self._cast(lp)
            a, kv2 = decode_attention(lp["self"], nf(h, lp["norm1"]), kv_c,
                                      pos, cfg)
            h = h + a
            # cross attention against cached enc projections
            B = h.shape[0]
            q = jnp.dot(nf(h, lp["norm2"]),
                        lp["cross"]["wq"]).reshape(B, 1, H, hd)
            from .attention import _expand_kv
            k = _expand_kv(cross_c["k"], H)
            v = _expand_kv(cross_c["v"], H)
            s = jnp.einsum("bthd,bshd->bhts", q, k) / (hd ** 0.5)
            w = jax.nn.softmax(s.astype(jnp.float32), -1).astype(h.dtype)
            o = jnp.einsum("bhts,bshd->bthd", w, v).reshape(B, 1, H * hd)
            h = h + jnp.dot(o, lp["cross"]["wo"])
            h = h + ffn(lp["ffn"], nf(h, lp["norm3"]), cfg)
            return h, kv2

        x, kv2 = jax.lax.scan(body, x,
                              (params["dec"], cache["kv"], cache["cross"]))
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        logits = jnp.dot(x, params["lm_head"].astype(self.dtype))[:, 0]
        return logits, {"kv": kv2, "cross": cache["cross"]}
