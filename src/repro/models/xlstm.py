"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential), interleaved mLSTM:sLSTM = 7:1.

mLSTM training uses the chunkwise-parallel formulation: within a chunk the
stabilized quadratic form, across chunks a (d_k x d_v) matrix-state carry —
O(T·c) instead of O(T^2), which is what makes the 500k-token decode shape
runnable (state is O(1) per step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from .config import ModelConfig
from .layers import init_dense, norm_fn


def init_mlstm_params(rng, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    di = int(cfg.mlstm_proj_factor * D)
    H = cfg.n_heads
    dh = di // H
    ks = jax.random.split(rng, 8)

    def blockdiag(k):   # per-head block-diagonal projection (H, dh, dh)
        return (jax.random.normal(k, (H, dh, dh), jnp.float32)
                * (1.0 / dh) ** 0.5).astype(dtype)

    return {
        "w_in": init_dense(ks[0], D, 2 * di, dtype),    # up-proj (x, gate)
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "w_i": init_dense(ks[4], di, H, jnp.float32),   # input gate (per head)
        "w_f": init_dense(ks[5], di, H, jnp.float32),   # forget gate
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.ones((H,), jnp.float32) * 3.0,       # open forget gates
        "w_out": init_dense(ks[6], di, D, dtype),
        "norm_scale": jnp.ones((di,), jnp.float32),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int):
    """Chunkwise stabilized mLSTM.

    q/k/v: (B, H, T, dk|dv); log_i/log_f: (B, H, T) log input/forget gates.
    Returns (B, H, T, dv).
    """
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    nc = T // chunk
    qc = q.reshape(B, H, nc, chunk, dk)
    kc = k.reshape(B, H, nc, chunk, dk)
    vc = v.reshape(B, H, nc, chunk, dv)
    ic = log_i.reshape(B, H, nc, chunk)
    fc = log_f.reshape(B, H, nc, chunk)

    csum_f = jnp.cumsum(fc, axis=-1)                     # within-chunk cumsum
    f_total = csum_f[..., -1]                            # (B, H, nc)

    def body(carry, xs):
        C, n, m = carry      # (B,H,dk,dv), (B,H,dk), (B,H) running stabilizer
        qt, kt, vt, it, ft_cum, ftot = xs
        # decay from chunk start to position t: ft_cum
        # inter-chunk contribution: q_t (C scaled by decay)
        b = ft_cum + m[..., None]                         # log scale of carry
        # intra-chunk: log weights  D_ts = cumF_t - cumF_s + i_s   (s <= t)
        lw = (ft_cum[..., :, None] - ft_cum[..., None, :] + it[..., None, :])
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        lw = jnp.where(tri, lw, -jnp.inf)
        m_intra = jnp.max(lw, axis=-1)                    # (B,H,c)
        m_new = jnp.maximum(b, m_intra)                   # stabilizer per t
        w_intra = jnp.exp(lw - m_new[..., None])          # (B,H,c,c)
        scale_inter = jnp.exp(b - m_new)                  # (B,H,c)

        qs = qt / (qt.shape[-1] ** 0.5)
        attn = jnp.einsum("bhtk,bhsk->bhts", qs, kt) * w_intra
        intra = jnp.einsum("bhts,bhsv->bhtv", attn, vt)
        inter = jnp.einsum("bhtk,bhkv->bhtv", qs, C) * scale_inter[..., None]
        # denominator: |q . n_t| with n_t the stabilized normalizer state
        dot_n = attn.sum(-1) + jnp.einsum("bhtk,bhk->bht", qs, n) * scale_inter
        denom = jnp.maximum(jnp.abs(dot_n), jnp.exp(-m_new))
        out = (intra + inter) / denom[..., None]

        # carry update: C' = exp(ftot + m - m')*C + sum_s exp(ftot - cumF_s + i_s - m') k_s v_s
        m_next = jnp.maximum(ftot + m, jnp.max(
            ftot[..., None] - ft_cum + it, axis=-1))
        decay_old = jnp.exp(ftot + m - m_next)
        w_new = jnp.exp(ftot[..., None] - ft_cum + it - m_next[..., None])
        C2 = decay_old[..., None, None] * C + jnp.einsum(
            "bhs,bhsk,bhsv->bhkv", w_new, kt, vt)
        n2 = decay_old[..., None] * n + jnp.einsum("bhs,bhsk->bhk", w_new, kt)
        return (C2, n2, m_next), out

    C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    n0 = jnp.zeros((B, H, dk), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    # q/k/v stay in the compute dtype (bf16): halves the dominant memory
    # traffic; the f32 carry + stabilizers keep the recurrence exact enough
    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0),
          jnp.moveaxis(ic, 2, 0), jnp.moveaxis(csum_f, 2, 0),
          jnp.moveaxis(f_total, 2, 0))
    _, outs = jax.lax.scan(body, (C0, n0, m0), xs)       # (nc, B, H, c, dv)
    return jnp.moveaxis(outs, 0, 2).reshape(B, H, T, dv).astype(q.dtype)


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig,
                chunk: int = 64) -> jax.Array:
    """x: (B, T, D) -> (B, T, D)."""
    B, T, D = x.shape
    H = cfg.n_heads
    di = int(cfg.mlstm_proj_factor * D)
    up = jnp.dot(x, p["w_in"])
    xin, gate = up[..., :di], up[..., di:]
    xh = xin.reshape(B, T, H, di // H)
    q = jnp.einsum("bthd,hde->bhte", xh, p["wq"])
    k = jnp.einsum("bthd,hde->bhte", xh, p["wk"])
    v = jnp.einsum("bthd,hde->bhte", xh, p["wv"])
    log_i = jax.nn.log_sigmoid(
        jnp.dot(xin.astype(jnp.float32), p["w_i"]) + p["b_i"]).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        jnp.dot(xin.astype(jnp.float32), p["w_f"]) + p["b_f"]).transpose(0, 2, 1)
    c = min(chunk, T)
    while T % c:
        c -= 1
    h = _mlstm_chunk_scan(q, k, v, log_i, log_f, c)
    h = h.transpose(0, 2, 1, 3).reshape(B, T, di)
    h = norm_fn("rmsnorm")(h, p["norm_scale"])
    h = h * jax.nn.silu(gate)
    return jnp.dot(h, p["w_out"])


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = di // H
    del dtype  # recurrent state is kept in f32 for stability
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode_step(p: dict, x: jax.Array, state: dict,
                      cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Single-token recurrent step: x (B, 1, D) -> (B, 1, D); O(1) state."""
    B, _, D = x.shape
    H = cfg.n_heads
    di = int(cfg.mlstm_proj_factor * D)
    dh = di // H
    up = jnp.dot(x[:, 0], p["w_in"])
    xin, gate = up[..., :di], up[..., di:]
    xh = xin.reshape(B, H, dh)
    q = jnp.einsum("bhd,hde->bhe", xh, p["wq"]) / (dh ** 0.5)
    k = jnp.einsum("bhd,hde->bhe", xh, p["wk"])
    v = jnp.einsum("bhd,hde->bhe", xh, p["wv"])
    log_i = jax.nn.log_sigmoid(
        jnp.dot(xin.astype(jnp.float32), p["w_i"]) + p["b_i"])   # (B, H)
    log_f = jax.nn.log_sigmoid(
        jnp.dot(xin.astype(jnp.float32), p["w_f"]) + p["b_f"])

    m_new = jnp.maximum(log_f + state["m"], log_i)
    decay = jnp.exp(log_f + state["m"] - m_new)
    inp = jnp.exp(log_i - m_new)
    C = decay[..., None, None] * state["C"] + inp[..., None, None] \
        * k[..., :, None] * v[..., None, :]
    n = decay[..., None] * state["n"] + inp[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(B, di)
    h = norm_fn("rmsnorm")(h, p["norm_scale"])
    h = h * jax.nn.silu(gate)
    out = jnp.dot(h, p["w_out"]).reshape(B, 1, D).astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------- #
# sLSTM — scalar memory, inherently sequential (scanned over time)
# --------------------------------------------------------------------------- #


def init_slstm_params(rng, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    ks = jax.random.split(rng, 5)
    return {
        "w_z": init_dense(ks[0], D, D, dtype),
        "w_i": init_dense(ks[1], D, D, dtype),
        "w_f": init_dense(ks[2], D, D, dtype),
        "w_o": init_dense(ks[3], D, D, dtype),
        "r_z": init_dense(ks[4], D, D, dtype) * 0.1,   # recurrent weights
        "b_z": jnp.zeros((D,), jnp.float32),
        "b_i": jnp.zeros((D,), jnp.float32),
        "b_f": jnp.ones((D,), jnp.float32) * 3.0,
        "b_o": jnp.zeros((D,), jnp.float32),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, D), -1e30, jnp.float32)}


def _slstm_projections(p: dict, x: jax.Array):
    """The four x-dependent pre-activations, hoisted OUT of the recurrence —
    they are embarrassingly parallel over time (TP-shardable big matmuls),
    leaving only the h @ r_z matvec inside the sequential scan."""
    f32 = jnp.float32
    zx = jnp.dot(x, p["w_z"]).astype(f32) + p["b_z"]
    ix = jnp.dot(x, p["w_i"]).astype(f32) + p["b_i"]
    fx = jnp.dot(x, p["w_f"]).astype(f32) + p["b_f"]
    ox = jnp.dot(x, p["w_o"]).astype(f32) + p["b_o"]
    return zx, ix, fx, ox


def slstm_step(p: dict, pre: tuple, st: dict) -> tuple[dict, jax.Array]:
    """One stabilized sLSTM step from precomputed projections."""
    f32 = jnp.float32
    zx, ix, fx, ox = pre
    h_prev = st["h"].astype(p["r_z"].dtype)
    z = jnp.tanh(zx + jnp.dot(h_prev, p["r_z"]).astype(f32))
    log_i = ix
    log_f = jax.nn.log_sigmoid(fx)
    o = jax.nn.sigmoid(ox)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    c = jnp.exp(log_f + st["m"] - m_new) * st["c"] + jnp.exp(log_i - m_new) * z
    n = jnp.exp(log_f + st["m"] - m_new) * st["n"] + jnp.exp(log_i - m_new)
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, T, D) -> (B, T, D); projections batched, recurrence scanned."""
    B, T, D = x.shape
    st0 = init_slstm_state(cfg, B, x.dtype)
    # gather the projections across the model axis ONCE — the sequential
    # recurrence then runs fully local (no per-step collectives)
    zx, ix, fx, ox = (constrain(a, "residual")
                      for a in _slstm_projections(p, x))

    def body(st, pre_t):
        st2, h = slstm_step(p, pre_t, st)
        return st2, h

    pres = tuple(jnp.moveaxis(a, 1, 0) for a in (zx, ix, fx, ox))
    _, hs = jax.lax.scan(body, st0, pres)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def slstm_decode_step(p: dict, x: jax.Array, state: dict,
                      cfg: ModelConfig) -> tuple[jax.Array, dict]:
    zx, ix, fx, ox = _slstm_projections(p, x[:, 0])
    st2, h = slstm_step(p, (zx, ix, fx, ox), state)
    return h[:, None].astype(x.dtype), st2
