"""xLSTM language model: macro-blocks of (slstm_period - 1) mLSTM blocks
followed by one sLSTM block (the paper's xLSTM[7:1] layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cross_entropy_loss, init_dense, norm_fn
from .xlstm import (init_mlstm_params, init_mlstm_state, init_slstm_params,
                    init_slstm_state, mlstm_block, mlstm_decode_step,
                    slstm_block, slstm_decode_step)


class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.slstm_period >= 2
        assert cfg.n_layers % cfg.slstm_period == 0
        self.cfg = cfg
        self.nb = cfg.n_layers // cfg.slstm_period
        self.nm = cfg.slstm_period - 1
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 5)

        def init_m(k):
            return {"p": init_mlstm_params(k, cfg, self.pdtype),
                    "norm": jnp.ones((cfg.d_model,), jnp.float32)}

        def init_s(k):
            return {"p": init_slstm_params(k, cfg, self.pdtype),
                    "norm": jnp.ones((cfg.d_model,), jnp.float32)}

        mkeys = jax.random.split(ks[0], self.nb * self.nm)
        m_p = jax.vmap(init_m)(mkeys)
        m_p = jax.tree.map(
            lambda a: a.reshape((self.nb, self.nm) + a.shape[1:]), m_p)
        s_p = jax.vmap(init_s)(jax.random.split(ks[1], self.nb))
        return {
            "embed": (jax.random.normal(
                ks[2], (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(self.pdtype),
            "blocks": {"mlstm": m_p, "slstm": s_p},
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": init_dense(ks[3], cfg.d_model, cfg.vocab_size,
                                  self.pdtype),
        }

    def _cast(self, tree):
        return jax.tree.map(
            lambda a: a.astype(self.dtype) if a.dtype == self.pdtype else a,
            tree)

    def logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        nf = norm_fn(cfg.norm)
        x = jnp.take(params["embed"].astype(self.dtype), batch["tokens"],
                     axis=0)

        def block(h, bp):
            def msub(hh, mp):
                mp = self._cast(mp)
                return hh + mlstm_block(mp["p"], nf(hh, mp["norm"]), cfg), None
            h, _ = jax.lax.scan(msub, h, bp["mlstm"])
            sp = self._cast(bp["slstm"])
            h = h + slstm_block(sp["p"], nf(h, sp["norm"]), cfg)
            return h, None

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(block, x, params["blocks"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return jnp.dot(x, params["lm_head"].astype(self.dtype))

    def loss(self, params, batch) -> jax.Array:
        logits = self.logits(params, batch)
        return cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])

    # ---- serving: O(1) recurrent state, no KV cache -------------------------
    def init_cache(self, batch: int, seq_len: int) -> dict:
        del seq_len  # recurrent: state is sequence-length independent
        cfg = self.cfg
        m = init_mlstm_state(cfg, batch, self.dtype)
        m = jax.tree.map(lambda a: jnp.broadcast_to(
            a[None, None], (self.nb, self.nm) + a.shape), m)
        s = init_slstm_state(cfg, batch, self.dtype)
        s = jax.tree.map(lambda a: jnp.broadcast_to(
            a[None], (self.nb,) + a.shape), s)
        return {"mlstm": m, "slstm": s}

    def prefill(self, params, batch, max_len: int = 0):
        """Consume the prompt stepwise (recurrent prefill) via decode_step
        scanned over positions; returns final state + last logits."""
        tokens = batch["tokens"]
        B, T = tokens.shape
        cache = self.init_cache(B, T)

        def step(carry, t_tok):
            cache = carry
            logits, cache = self.decode_step(params, cache, t_tok,
                                             jnp.int32(0))
            return cache, logits

        cache, logits = jax.lax.scan(step, cache, jnp.moveaxis(tokens, 1, 0))
        return cache, logits[-1][:, None]

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        del pos  # recurrent
        nf = norm_fn(cfg.norm)
        x = jnp.take(params["embed"].astype(self.dtype), tokens[:, None],
                     axis=0)

        def block(h, xs):
            bp, mstate, sstate = xs

            def msub(hh, sub):
                mp, st = sub
                mp = self._cast(mp)
                dx, st2 = mlstm_decode_step(mp["p"], nf(hh, mp["norm"]), st,
                                            cfg)
                return hh + dx, st2

            h, m2 = jax.lax.scan(msub, h, (bp["mlstm"], mstate))
            sp = self._cast(bp["slstm"])
            dx, s2 = slstm_decode_step(sp["p"], nf(h, sp["norm"]), sstate, cfg)
            h = h + dx
            return h, (m2, s2)

        x, (m2, s2) = jax.lax.scan(
            block, x, (params["blocks"], cache["mlstm"], cache["slstm"]))
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        logits = jnp.dot(x, params["lm_head"].astype(self.dtype))[:, 0]
        return logits, {"mlstm": m2, "slstm": s2}
