"""The plain-jax reference for the traceable decoder block.

``repro.graph.trace.trace_block`` lowers a norm-free, transcendental-free
decoder block into ISAMIR kernels; this module is the *other side* of that
contract — the same block written directly in jax.numpy.  The compiled
graph's interpreted/executed output must be **bit-exact** against
``block_reference`` (the CI ``graph-smoke`` lane asserts it), which works
because:

  * every op in the block (dot products, adds, relu/max, multiplication by
    powers of two) is exact over the dyadic values the tracer's
    ``block_inputs`` generates, in *any* summation order — XLA's, numpy's,
    or the ISAMIR interpreter's;
  * the reference computes in float64 (``jax.experimental.enable_x64``) and
    casts to float32 at exactly the traced node boundaries, mirroring the
    graph interpreter's per-node dtype casts.

Keeping the reference here (not next to the tracer) mirrors the repo rule
that ``models/`` holds the jax truth the compiler tiers are validated
against.
"""
from __future__ import annotations

import numpy as np

from .config import ModelConfig


def _boundary(a):
    """One traced node boundary: round to the tensor dtype (f32)."""
    import jax.numpy as jnp
    return a.astype(jnp.float32).astype(jnp.float64)


def block_reference(inputs: dict[str, np.ndarray], cfg: ModelConfig,
                    seq_len: int) -> np.ndarray:
    """Evaluate the traceable decoder block in plain jax; returns float32.

    ``inputs`` uses the tracer's tensor names: ``x``, per-head ``wq{h}`` /
    ``wk{h}`` / ``wv{h}`` / ``wo{h}``, and ``w_gate`` / ``w_up`` /
    ``w_down``.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    H, Dh = cfg.n_heads, cfg.hd
    halvings = (Dh.bit_length() - 1) // 2
    if 4 ** halvings != Dh:
        raise ValueError(f"head_dim {Dh} is not a power of 4")
    scale = 2.0 ** -halvings

    with enable_x64():
        t = {k: jnp.asarray(np.asarray(v), jnp.float64)
             for k, v in inputs.items()}
        x = _boundary(t["x"])
        attn = None
        for h in range(H):
            q = _boundary(x @ t[f"wq{h}"])
            k = _boundary(x @ t[f"wk{h}"])
            v = _boundary(x @ t[f"wv{h}"])
            sraw = _boundary(q @ k.T)
            s = _boundary(jnp.maximum(sraw * scale, 0.0))
            a = _boundary(s @ v)
            p = _boundary(a @ t[f"wo{h}"])
            attn = p if attn is None else _boundary(attn + p)
        y1 = _boundary(x + attn)
        g = _boundary(jnp.maximum(_boundary(y1 @ t["w_gate"]), 0.0))
        u = _boundary(y1 @ t["w_up"])
        hid = _boundary(g + u)
        o = _boundary(hid @ t["w_down"])
        y2 = (y1 + o).astype(jnp.float32)
        return np.asarray(y2)
