"""Jamba-style hybrid LM: Mamba + attention interleaved 7:1, MoE every
``moe_period``-th FFN (Jamba-1.5: every 2nd — 398B total / ~94B active).

The stack is organised as macro-blocks of ``attn_period`` layers.  Within a
block the Mamba sublayers are grouped by FFN kind (dense-FFN group, then
MoE-FFN group, then the attention+MoE layer) so each group is one
``lax.scan`` over homogeneous stacked parameters — same parameter count,
FLOPs and sharding as the published interleave; only the within-block order
of the dense/MoE FFNs differs (noted in DESIGN.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention, decode_attention, init_attn_params,
                        init_kv_cache, prefill_attention)
from .config import ModelConfig
from .layers import cross_entropy_loss, init_dense, norm_fn
from .mamba import (init_mamba_params, init_mamba_state, mamba_block,
                    mamba_decode_step)
from .transformer import ffn, init_ffn_params


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.attn_period >= 2
        assert cfg.n_layers % cfg.attn_period == 0
        self.cfg = cfg
        self.nb = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1               # mamba sublayers per block
        per_block_moe = (cfg.attn_period // cfg.moe_period
                         if cfg.n_experts else 0)
        # the attention layer takes one MoE slot when any exist
        self.n_moe_mamba = max(per_block_moe - 1, 0)
        self.n_dense_mamba = nm - self.n_moe_mamba
        self.dense_cfg = cfg.scaled(n_experts=0, top_k=0)
        self.attn_ffn_cfg = cfg if per_block_moe else self.dense_cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)

    # ---- init ---------------------------------------------------------------
    def _init_mamba_sub(self, k, sub_cfg):
        k1, k2 = jax.random.split(k)
        return {"mamba": init_mamba_params(k1, self.cfg, self.pdtype),
                "ffn": init_ffn_params(k2, sub_cfg, self.pdtype),
                "norm1": jnp.ones((self.cfg.d_model,), jnp.float32),
                "norm2": jnp.ones((self.cfg.d_model,), jnp.float32)}

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)

        def stacked(key, n, sub_cfg):
            kk = jax.random.split(key, self.nb * n)
            p = jax.vmap(lambda k: self._init_mamba_sub(k, sub_cfg))(kk)
            return jax.tree.map(
                lambda a: a.reshape((self.nb, n) + a.shape[1:]), p)

        def init_attn_sub(k):
            k1, k2 = jax.random.split(k)
            return {"attn": init_attn_params(k1, cfg, self.pdtype),
                    "ffn": init_ffn_params(k2, self.attn_ffn_cfg,
                                           self.pdtype),
                    "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                    "norm2": jnp.ones((cfg.d_model,), jnp.float32)}

        blocks = {
            "dense": stacked(ks[0], self.n_dense_mamba, self.dense_cfg),
            "attn": jax.vmap(init_attn_sub)(jax.random.split(ks[2], self.nb)),
        }
        if self.n_moe_mamba:
            blocks["moe"] = stacked(ks[1], self.n_moe_mamba, cfg)
        return {
            "embed": (jax.random.normal(
                ks[3], (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(self.pdtype),
            "blocks": blocks,
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": init_dense(ks[4], cfg.d_model, cfg.vocab_size,
                                  self.pdtype),
        }

    def _cast(self, tree):
        return jax.tree.map(
            lambda a: a.astype(self.dtype) if a.dtype == self.pdtype else a,
            tree)

    # ---- shared block machinery ------------------------------------------------
    def _mamba_sub_fwd(self, p, x, sub_cfg):
        nf = norm_fn(self.cfg.norm)
        x = x + mamba_block(p["mamba"], nf(x, p["norm1"]), self.cfg)
        x = x + ffn(p["ffn"], nf(x, p["norm2"]), sub_cfg)
        return x

    def _attn_sub_fwd(self, p, x):
        nf = norm_fn(self.cfg.norm)
        x = x + attention(p["attn"], nf(x, p["norm1"]), self.cfg)
        x = x + ffn(p["ffn"], nf(x, p["norm2"]), self.attn_ffn_cfg)
        return x

    # ---- training ---------------------------------------------------------------
    def logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = jnp.take(params["embed"].astype(self.dtype), batch["tokens"],
                     axis=0)

        def block(h, bp):
            def dsub(hh, mp):
                return self._mamba_sub_fwd(self._cast(mp), hh,
                                           self.dense_cfg), None
            h, _ = jax.lax.scan(dsub, h, bp["dense"])
            if self.n_moe_mamba:
                def msub(hh, mp):
                    return self._mamba_sub_fwd(self._cast(mp), hh, cfg), None
                h, _ = jax.lax.scan(msub, h, bp["moe"])
            h = self._attn_sub_fwd(self._cast(bp["attn"]), h)
            return h, None

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(block, x, params["blocks"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        return jnp.dot(x, params["lm_head"].astype(self.dtype))

    def loss(self, params, batch) -> jax.Array:
        logits = self.logits(params, batch)
        return cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])

    # ---- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        kv = init_kv_cache(cfg, batch, seq_len, self.dtype)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.nb,) + a.shape), kv)
        ms = init_mamba_state(cfg, batch, self.dtype)

        def stack(n):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None, None],
                                           (self.nb, n) + a.shape), ms)
        cache = {"kv": kv, "dense": stack(self.n_dense_mamba)}
        if self.n_moe_mamba:
            cache["moe"] = stack(self.n_moe_mamba)
        return cache

    def prefill(self, params, batch, max_len: int = 0):
        cfg = self.cfg
        x = jnp.take(params["embed"].astype(self.dtype), batch["tokens"],
                     axis=0)
        nf = norm_fn(cfg.norm)

        def block(h, bp):
            def dsub(hh, mp):
                mp = self._cast(mp)
                st = _mamba_state_from_seq(mp, nf(hh, mp["norm1"]), cfg)
                return self._mamba_sub_fwd(mp, hh, self.dense_cfg), st
            h, dstates = jax.lax.scan(dsub, h, bp["dense"])
            out_states = {"dense": dstates}
            if self.n_moe_mamba:
                def msub(hh, mp):
                    mp = self._cast(mp)
                    st = _mamba_state_from_seq(mp, nf(hh, mp["norm1"]), cfg)
                    return self._mamba_sub_fwd(mp, hh, cfg), st
                h, mstates = jax.lax.scan(msub, h, bp["moe"])
                out_states["moe"] = mstates
            ap = self._cast(bp["attn"])
            a, kv = prefill_attention(ap["attn"], nf(h, ap["norm1"]), cfg,
                                      max_len=max_len)
            h = h + a
            h = h + ffn(ap["ffn"], nf(h, ap["norm2"]), self.attn_ffn_cfg)
            return h, (out_states, kv)

        x, (states, kvs) = jax.lax.scan(block, x, params["blocks"])
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        logits = jnp.dot(x[:, -1:], params["lm_head"].astype(self.dtype))
        cache = {"kv": kvs, **states}
        return cache, logits

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"].astype(self.dtype), tokens[:, None],
                     axis=0)
        nf = norm_fn(cfg.norm)

        def mamba_dec(hh, mp, st, sub_cfg):
            dx, st2 = mamba_decode_step(mp["mamba"], nf(hh, mp["norm1"]),
                                        st, cfg)
            hh = hh + dx
            hh = hh + ffn(mp["ffn"], nf(hh, mp["norm2"]), sub_cfg)
            return hh, st2

        def block(h, xs):
            bp, kv_cache, dstate = xs[0], xs[1], xs[2]
            mstate = xs[3] if self.n_moe_mamba else None

            def dsub(hh, sub):
                mp, st = sub
                return mamba_dec(hh, self._cast(mp), st, self.dense_cfg)
            h, d2 = jax.lax.scan(dsub, h, (bp["dense"], dstate))
            m2 = None
            if self.n_moe_mamba:
                def msub(hh, sub):
                    mp, st = sub
                    return mamba_dec(hh, self._cast(mp), st, cfg)
                h, m2 = jax.lax.scan(msub, h, (bp["moe"], mstate))
            ap = self._cast(bp["attn"])
            a, kv2 = decode_attention(ap["attn"], nf(h, ap["norm1"]),
                                      kv_cache, pos, cfg)
            h = h + a
            h = h + ffn(ap["ffn"], nf(h, ap["norm2"]), self.attn_ffn_cfg)
            return h, (d2, m2, kv2)

        xs = [params["blocks"], cache["kv"], cache["dense"]]
        if self.n_moe_mamba:
            xs.append(cache["moe"])
        x, (d2, m2, kv2) = jax.lax.scan(block, x, tuple(xs))
        x = norm_fn("rmsnorm")(x, params["norm_f"])
        logits = jnp.dot(x, params["lm_head"].astype(self.dtype))[:, 0]
        new_cache = {"kv": kv2, "dense": d2}
        if self.n_moe_mamba:
            new_cache["moe"] = m2
        return logits, new_cache


def _mamba_state_from_seq(mp, x_seq, cfg) -> dict:
    """Decode-ready Mamba state after consuming x_seq (B, T, D): the final
    SSM state (recomputed with a running scan) plus the causal-conv tail."""
    from .mamba import _causal_conv

    B, T, D = x_seq.shape
    di = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    up = jnp.dot(x_seq, mp["mamba"]["w_in"])
    xi = jax.nn.silu(_causal_conv(up[..., :di], mp["mamba"]["conv_w"],
                                  mp["mamba"]["conv_b"]))
    bcdt = jnp.dot(xi, mp["mamba"]["w_bcdt"])
    Bm = bcdt[..., :ds].astype(jnp.float32)
    dt = jax.nn.softplus(bcdt[..., -1:].astype(jnp.float32)
                         + mp["mamba"]["dt_bias"])
    A = -jnp.exp(mp["mamba"]["A_log"])
    decay = jnp.exp(dt[..., None] * A[None, None])          # (B,T,di,ds)
    inp = (dt * xi.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    def step(h, xs):
        d, i = xs
        return d * h + i, None

    h, _ = jax.lax.scan(step, jnp.zeros((B, di, ds), jnp.float32),
                        (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(inp, 1, 0)))
    dc = cfg.mamba_d_conv
    conv_tail = up[..., :di][:, -(dc - 1):, :]
    return {"h": h, "conv": conv_tail.astype(x_seq.dtype)}
