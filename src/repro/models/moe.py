"""Mixture-of-Experts FFN with capacity-based dispatch (GSPMD-friendly).

Top-k routing materialises a (tokens, experts, capacity) dispatch tensor so
expert compute is two dense einsums over an (E, C, D) layout — the standard
expert-parallel pattern: the E dimension shards over the 'model' mesh axis
(EP) when divisible, and expert weights shard internally (TP) otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from .config import ModelConfig
from .layers import init_dense


def init_moe_params(rng, cfg: ModelConfig, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": init_dense(ks[0], D, E, jnp.float32),
        "w_gate": init_dense(ks[1], D, F, dtype)[None].repeat(E, 0),
        "w_up": init_dense(ks[2], D, F, dtype)[None].repeat(E, 0),
        "w_down": init_dense(ks[3], F, D, dtype)[None].repeat(E, 0),
    }


MOE_GROUP = 4096  # tokens per dispatch group (keeps dispatch linear in N)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, T, D) -> (B, T, D) through top-k experts with capacity.

    Dispatch is **group-wise**: tokens are split into groups of at most
    MOE_GROUP and each group gets its own capacity slice.  With a single
    global queue the one-hot dispatch tensors are (N, E, C) with C
    proportional to N — an O(N^2) term that dwarfed the expert GEMMs at
    training shapes (measured: useful-flops ratio 0.001 on mixtral
    train_4k).  Grouping keeps the tensors (G, n, E, c) with n, c fixed, so
    dispatch cost stays a small constant fraction of expert compute."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, D)

    n = min(MOE_GROUP, N)
    while N % n:
        n -= 1
    G = N // n
    xg = xf.reshape(G, n, D)

    logits = jnp.dot(xg.astype(jnp.float32), p["router"])        # (G, n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (G, n, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(cfg.capacity_factor * n * K / E))
    # position of each (token, k) within its expert queue, per group
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (G, n, K, E)
    flat = onehot.reshape(G, n * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G, n*K, E)
    pos = (pos * flat).sum(-1).reshape(G, n, K)
    keep = pos < C

    exp_oh = jax.nn.one_hot(gate_idx, E, dtype=xf.dtype)         # (G, n, K, E)
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                             dtype=xf.dtype)[..., :C]            # (G, n, K, C)
    disp = jnp.einsum("gnke,gnkc->gnec", exp_oh, slot_oh)
    combine = jnp.einsum("gnk,gnke,gnkc->gnec",
                         gate_vals.astype(xf.dtype), exp_oh, slot_oh)

    xe = constrain(jnp.einsum("gnd,gnec->egcd", xg, disp), "expert_tokens4")
    g = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["w_gate"]))
    u = jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
    h = constrain(g * u, "expert_hidden4")
    ye = constrain(jnp.einsum("egcf,efd->egcd", h, p["w_down"]),
                   "expert_tokens4")                             # (E, G, c, D)
    y = jnp.einsum("gnec,egcd->gnd", combine, ye)
    return y.reshape(B, T, D)


def moe_aux_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    B, T, D = x.shape
    logits = jnp.dot(x.reshape(-1, D).astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), cfg.n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
