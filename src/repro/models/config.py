"""Model configuration + shape descriptors for the assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"       # rmsnorm | nonparam_ln
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0     # >0: SWA (mixtral)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_period: int = 1         # MoE FFN every Nth layer (jamba: 2)
    # hybrid (jamba): one attention layer per `attn_period`, rest mamba
    attn_period: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # xlstm: one sLSTM block per `slstm_period`, rest mLSTM
    slstm_period: int = 0
    mlstm_proj_factor: float = 2.0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    # modality frontend stubs
    frontend_tokens: int = 0    # patches / audio frames provided pre-embedded
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    loss_chunk: int = 0         # 0 = no logits chunking

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ---- parameter count (for MODEL_FLOPS = 6 N D) -------------------------
    def param_count(self, active_only: bool = False) -> int:
        D, H, KV, hd, F, V = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.hd, self.d_ff, self.vocab_size)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * hd
        mlp_dense = 3 * D * F                    # swiglu gate/up/down
        if self.family == "hybrid" and self.attn_period:
            n_attn_layers = self.n_layers // self.attn_period
            n_mamba = self.n_layers - n_attn_layers
            di = self.mamba_expand * D
            mamba = (D * 2 * di + di * self.mamba_d_conv
                     + di * (2 * self.mamba_d_state + 1)
                     + di + di * D)
            total = n_attn_layers * attn + n_mamba * mamba
        elif self.family == "ssm":
            # xlstm mLSTM: in/out proj + block-diagonal per-head qkv + gates
            di = int(self.mlstm_proj_factor * D)
            dh = di // max(self.n_heads, 1)
            mlstm = 2 * D * di + 3 * dh * dh * self.n_heads + 2 * di + di * D
            total = self.n_layers * mlstm
        else:
            total = self.n_layers * attn
        if self.family != "ssm":
            n_moe = self.n_layers // self.moe_period if self.n_experts else 0
            n_dense = self.n_layers - n_moe
            if n_moe:
                experts = self.n_experts * mlp_dense + D * self.n_experts
                active = self.top_k * mlp_dense + D * self.n_experts
                total += n_moe * (active if active_only else experts)
            total += n_dense * mlp_dense
        total += 2 * D  # final norm(s)
        total += V * D * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp_dense)  # encoder stack
            total += self.n_layers * attn                      # cross attention
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: archs with quadratic full attention skip long_500k (see DESIGN.md)
FULL_ATTENTION_ARCHS = {
    "olmo-1b", "qwen2-7b", "qwen1.5-32b", "qwen2.5-32b", "llava-next-34b",
    "whisper-medium",
}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return False
    return True
