"""Uniform model API: build_model(cfg) returns an object exposing

    init(rng) -> params
    logits(params, batch) -> (B, T, V)
    loss(params, batch) -> scalar
    init_cache(batch, seq_len) -> cache pytree
    prefill(params, batch) -> (cache, last_logits)
    decode_step(params, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

from .config import ModelConfig
from .hybrid import HybridLM
from .transformer import DecoderLM
from .whisper import WhisperModel
from .xlstm_lm import XLSTMLM


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return WhisperModel(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    return DecoderLM(cfg)   # dense | moe | vlm
