"""AdamW with global-norm clipping, cosine schedule, and ZeRO-1-style state
sharding (optimizer moments inherit the parameters' shardings, which are
themselves FSDP-sharded over the data axis — see dist/sharding.py)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state: OptState,
                  cfg: AdamWConfig) -> tuple[dict, OptState, dict]:
    """One AdamW step; returns (params, state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.beta1 * mu + (1 - cfg.beta1) * g
        nu2 = cfg.beta2 * nu + (1 - cfg.beta2) * g * g
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_mu, new_nu), metrics
