"""Candidate evaluation backends + oracle validation (paper Section 4).

Three ways to score a config vector:

  * ``CostModelEvaluator`` — the fast path: compile the candidate
    ParamApproach through the ``repro.compile`` driver (Schedule + Lower on
    the fixed Selection) and score the resulting ``CompiledKernel``'s
    modeled makespan.  A cheap tile-count pre-check rejects degenerate
    configs (tiny tiles on huge extents explode the simulated stream) with
    ``inf`` instead of minutes of scheduling.

  * ``LearnedEvaluator`` — the *surrogate* path: score by the trained ridge
    model of ``repro.search.model`` (microseconds per candidate, no
    scheduling).  Used to rank large pools; real budgets still settle the
    winner, so the tuned <= greedy contract never rests on a prediction.

  * ``MeasuredGemmEvaluator`` — optional wall-clock: forward the candidate's
    tile choice as the Pallas GEMM BlockSpec (``kernels/gemm.py``) and time
    the kernel.  Only meaningful on a real TPU backend; on CPU the kernel
    runs in interpret mode, which is numerically faithful but slow, so the
    tuner defaults to the cost backend.

``validate_selection`` replays a schedule through ``core.executor`` against
the ``ir.interpret`` oracle.  Because every unroll policy in the search
space keeps reduction offsets ascending per output region and all backends
accumulate in f64, a correct schedule replays **bit-exact** — the validation
reports exactness, not just closeness.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np

from ..compile import CompiledKernel, CompileError, compile_selection
from ..core.approach import Approach
from ..core.executor import execute
from ..core.instructions import is_elementwise
from ..core.ir import Program, interpret, random_inputs
from ..core.isel import Selection
from ..core.scheduler import Schedule
from ..core.sysgraph import SystemGraph
from .space import Config, ParamApproach


# --------------------------------------------------------------------------- #
# Cost-model backend
# --------------------------------------------------------------------------- #


@dataclass
class EvalStats:
    """Throughput counters one evaluator accumulates across a search (the
    ``tune --json`` per-case counters and the ``bench_search`` lanes)."""

    evals: int = 0           # configs scored (scalar + batch)
    guard_rejects: int = 0   # rejected by the tile-count guard (inf)
    memo_hits: int = 0       # scored via the schedule-key memo (no schedule)
    fresh: int = 0           # from-scratch schedules
    delta: int = 0           # incremental (anchor-resumed) schedules
    schedule_s: float = 0.0  # wall time in guard + scheduling
    predict_s: float = 0.0   # wall time in learned prediction

    def as_dict(self) -> dict:
        return {"evals": self.evals, "guard_rejects": self.guard_rejects,
                "memo_hits": self.memo_hits, "fresh": self.fresh,
                "delta": self.delta,
                "schedule_s": round(self.schedule_s, 6),
                "predict_s": round(self.predict_s, 6)}


class CostModelEvaluator:
    """Score a config by the modeled makespan of its ``CompiledKernel``.

    ``evaluate_many`` is the throughput tier: the feasibility guard runs
    vectorized over the whole population (``repro.search.batch``), configs
    that alias to the same schedule key are scored once, and fresh keys go
    through the incremental ``DeltaScheduler`` so local-walk neighbors reuse
    the parent schedule's unchanged instruction prefix.  Scores are
    bit-identical to the scalar ``__call__`` path on every config.
    """

    def __init__(self, selection: Selection, graph: SystemGraph,
                 max_tiles: int = 4096, incremental: bool = True):
        self.sel = selection
        self.graph = graph
        self.max_tiles = max_tiles
        self.incremental = incremental
        self.stats = EvalStats()
        self._plan = None
        self._delta = None
        self._memo: dict[tuple, float] = {}

    @property
    def plan(self):
        """Lazy ``BatchPlan`` (selection-static guard/key analysis)."""
        if self._plan is None:
            from .batch import BatchPlan
            self._plan = BatchPlan(self.sel, self.graph)
        return self._plan

    def evaluate_many(self, configs) -> list[float]:
        """Population scoring: one vectorized guard pass, one schedule per
        *distinct schedule key* (memoized), incremental re-scheduling for
        keys sharing an instruction prefix with a scheduled anchor."""
        configs = list(configs)
        if not configs:
            return []
        t0 = time.perf_counter()
        feasible, keys = self.plan.analyze(configs, self.max_tiles)
        out: list[float] = []
        for cfg, ok, key in zip(configs, feasible, keys):
            self.stats.evals += 1
            if not ok:
                self.stats.guard_rejects += 1
                out.append(float("inf"))
                continue
            cost = self._memo.get(key)
            if cost is None:
                cost = self._schedule_cost(key, cfg)
                self._memo[key] = cost
            else:
                self.stats.memo_hits += 1
            out.append(cost)
        self.stats.schedule_s += time.perf_counter() - t0
        return out

    def _schedule_cost(self, key: tuple, config: Config) -> float:
        """Modeled makespan for one distinct schedule key (== the cost
        ``compile(config).cost`` would report: Pipeline.assemble sets the
        artifact cost to the schedule makespan)."""
        from ..core.scheduler import ScheduleError, schedule
        if self.plan.unschedulable:
            return float("inf")     # some instr has no device: compile fails
        approach = ParamApproach(config)
        try:
            if self.incremental:
                if self._delta is None:
                    from ..compile.driver import DeltaScheduler
                    self._delta = DeltaScheduler(self.sel, self.graph)
                sched = self._delta.schedule_for(approach, key)
                self.stats.fresh = self._delta.stats["fresh"]
                self.stats.delta = self._delta.stats["delta"]
            else:
                sched = schedule(self.sel, self.graph, approach)
                self.stats.fresh += 1
            return float(sched.makespan)
        except (CompileError, ScheduleError):
            return float("inf")

    def estimated_tiles(self, approach: Approach) -> int:
        """Upper-bound the compute-tile count the scheduler would unroll,
        using only the approach's tile request (no scheduling).  Elementwise
        needles coalesce their outer axes, so they count one call."""
        prog = self.sel.program
        total = 0
        for si in self.sel.instrs:
            devices = self.graph.compute_nodes_for(si.needle.name)
            if not devices:
                continue
            hw_tile = devices[0].matmul_tile
            extents = {na: prog.axis(ha).size
                       for na, ha in si.mapping.axis_map}
            req = approach.choose_tile_shape(
                si.needle.name, extents, hw_tile,
                vmem_budget=self.graph.staging_budget(devices))
            mapped = 1
            for na, ext in extents.items():
                mapped *= math.ceil(ext / max(1, min(req.get(na, ext), ext)))
            calls = 1 if is_elementwise(si.needle.name) \
                else si.mapping.calls(prog)
            total += mapped * calls
        return total

    def compile(self, config: Config) -> CompiledKernel:
        """The candidate's ``CompiledKernel`` (Schedule + Lower through the
        ``repro.compile`` driver on this evaluator's fixed Selection)."""
        return compile_selection(self.sel, self.graph, ParamApproach(config))

    def schedule_config(self, config: Config) -> Schedule:
        return self.compile(config).schedule

    def __call__(self, config: Config) -> float:
        t0 = time.perf_counter()
        self.stats.evals += 1
        try:
            approach = ParamApproach(config)
            if self.estimated_tiles(approach) > self.max_tiles:
                self.stats.guard_rejects += 1
                return float("inf")
            try:
                cost = self.compile(config).cost
            except CompileError:
                return float("inf")
            self.stats.fresh += 1
            return cost
        finally:
            self.stats.schedule_s += time.perf_counter() - t0


class LearnedEvaluator:
    """Score a config by the **learned** cost model's prediction — no
    scheduling, no compile; microseconds per candidate.

    This is the ranking half of surrogate-guided search: predictions order a
    large pool, and the real trial budget (``CostModelEvaluator`` /
    measured) is reserved for the top of that order.  The evaluator keeps
    the analytical tile-count guard so degenerate configs stay ``inf`` —
    the model never trains on infeasible points, so it has no basis to
    reject them itself.

    ``for_selection`` resolves the model from a ``ModelStore`` (default:
    the process-wide store) and returns ``None`` when no model covers the
    program's family on this graph — callers fall back to the cost backend.
    """

    def __init__(self, model, selection: Selection, graph: SystemGraph,
                 max_tiles: int = 4096):
        self.model = model
        self.sel = selection
        self.graph = graph
        from ..compile.features import role_extents
        self._guard = CostModelEvaluator(selection, graph,
                                         max_tiles=max_tiles)
        self._predict = model.predictor(selection.program, graph,
                                        role_extents(selection))
        self.stats = self._guard.stats
        #: config key -> guard verdict.  Surrogate search scores the same
        #: configs repeatedly (pool ranking, then the neighbor walk, then
        #: the final sweep); without the memo every ranking pays the
        #: tile-count guard again for every config it has already screened.
        self._feas: dict[tuple, bool] = {}

    @classmethod
    def for_selection(cls, selection: Selection, graph: SystemGraph,
                      store=None, backend: str = "cost"
                      ) -> "LearnedEvaluator | None":
        from .model import get_default_store
        store = store if store is not None else get_default_store()
        if store is None:
            return None
        model = store.model_for(selection.program, graph, backend)
        if model is None:
            return None
        return cls(model, selection, graph)

    @property
    def predictor(self):
        """The raw (unguarded) ``config -> predicted seconds`` closure with
        ``predict_many`` — for diagnostics like ``model.topk_regret`` that
        score pre-screened configs.  Rankings that *choose* what to spend
        real budget on must go through the evaluator itself (``__call__`` /
        ``predict_many``), which keeps the tile-count guard."""
        return self._predict

    @property
    def anchors(self) -> list[Config]:
        """The cache-winner configs the model was trained on (its program
        family's "known good" set) — surrogate search seeds."""
        return [dict(c) for c in self.model.meta.get("anchors", [])]

    def _feasible(self, config: Config) -> bool:
        from .space import config_key
        k = config_key(config)
        got = self._feas.get(k)
        if got is None:
            got = self._feas[k] = bool(
                self._guard.estimated_tiles(ParamApproach(config))
                <= self._guard.max_tiles)
        return got

    def _feasible_many(self, configs: list) -> list[bool]:
        """Memoized batch guard: unseen configs go through the vectorized
        ``BatchPlan`` guard in one pass; seen configs are dict lookups."""
        from .space import config_key
        keys = [config_key(c) for c in configs]
        todo = [(c, k) for c, k in zip(configs, keys) if k not in self._feas]
        if todo:
            feas, _ = self._guard.plan.analyze([c for c, _ in todo],
                                               self._guard.max_tiles)
            for (_, k), ok in zip(todo, feas):
                self._feas[k] = bool(ok)
        return [self._feas[k] for k in keys]

    def predict_many(self, configs) -> list[float]:
        """Guarded batch prediction: infeasible configs score ``inf`` so a
        pool ranking can never put them in front of real-budget trials."""
        configs = list(configs)
        t0 = time.perf_counter()
        scores = self._predict.predict_many(configs)
        self.stats.predict_s += time.perf_counter() - t0
        self.stats.evals += len(configs)
        feasible = self._feasible_many(configs)
        self.stats.guard_rejects += sum(1 for ok in feasible if not ok)
        return [float(s) if ok else float("inf")
                for ok, s in zip(feasible, scores)]

    def __call__(self, config: Config) -> float:
        self.stats.evals += 1
        if not self._feasible(config):
            self.stats.guard_rejects += 1
            return float("inf")
        t0 = time.perf_counter()
        try:
            return self._predict(config)
        finally:
            self.stats.predict_s += time.perf_counter() - t0


def gemm_tile_for(config: Config, graph: SystemGraph,
                  m: int, n: int, k: int) -> tuple[int, int, int]:
    """The (bm, bn, bk) tile a config implies for an (m, n, k) GEMM on
    ``graph`` — the same hw-tile + staging-budget inputs the scheduler hands
    ``choose_tile_shape`` (``SystemGraph.staging_budget``), clamped to the
    problem.  One definition shared by the tuner's cache records, the
    measured backend, and the examples."""
    devices = graph.compute_nodes_for("mxu.matmul")
    if devices:
        hw_tile = min(d.matmul_tile for d in devices)
        vmem = graph.staging_budget(devices)
    else:   # pragma: no cover - graph without an MXU
        hw_tile, vmem = (128, 128, 128), None
    from .cache import clamp_tile
    req = ParamApproach(config).choose_tile_shape(
        "mxu.matmul", {"i": m, "j": n, "k": k}, hw_tile, vmem_budget=vmem)
    return clamp_tile((req["i"], req["j"], req["k"]), m, n, k)


# --------------------------------------------------------------------------- #
# Measured (Pallas wall-clock) backend
# --------------------------------------------------------------------------- #


class MeasuredGemmEvaluator:
    """Score a config by timing the Pallas GEMM with the candidate's tile
    choice as the BlockSpec.  jax is imported lazily so the cost-model path
    stays numpy-only."""

    def __init__(self, m: int, n: int, k: int, graph: SystemGraph,
                 repeats: int = 3, interpret: bool | None = None):
        import warnings

        import jax
        import jax.numpy as jnp
        from ..kernels.gemm import gemm
        if jax.default_backend() != "tpu":
            warnings.warn(
                f"measured GEMM tuning on the {jax.default_backend()!r} "
                "backend runs Pallas in interpret mode — numerically "
                "faithful but extremely slow on large shapes; wall-clock "
                "results are only meaningful on TPU (use --backend cost)",
                stacklevel=2)
        self._gemm = gemm
        self.m, self.n, self.k = m, n, k
        self.graph = graph
        self.repeats = repeats
        self.interpret = interpret
        key = jax.random.PRNGKey(0)
        ka, kb = jax.random.split(key)
        self.a = jax.random.uniform(ka, (m, k), jnp.float32, -1, 1)
        self.b = jax.random.uniform(kb, (k, n), jnp.float32, -1, 1)

    def block_for(self, config: Config) -> tuple[int, int, int]:
        """The candidate's (bm, bn, bk) — the scheduler tile choice forwarded
        to the kernel, clamped to the problem."""
        return gemm_tile_for(config, self.graph, self.m, self.n, self.k)

    def __call__(self, config: Config) -> float:
        block = self.block_for(config)
        try:
            out = self._gemm(self.a, self.b, block=block,
                             interpret=self.interpret)
            out.block_until_ready()          # compile + warm
            best = float("inf")
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                self._gemm(self.a, self.b, block=block,
                           interpret=self.interpret).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            return best
        except Exception:
            return float("inf")


# --------------------------------------------------------------------------- #
# Oracle validation
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ValidationReport:
    exact: bool                 # bit-exact vs the ISAMIR oracle
    max_abs_err: float
    outputs: tuple[str, ...]

    @property
    def ok(self) -> bool:
        """Exact, or within float32 round-off of the f64 oracle."""
        return self.exact or self.max_abs_err < 1e-5


def validate_selection(prog: Program, selection: Selection,
                       graph: SystemGraph, approach: Approach,
                       rng_seed: int = 0) -> ValidationReport:
    """Compile ``selection`` with ``approach`` through the driver, execute
    the recorded stream with real data (core.executor) and compare against
    ``ir.interpret`` on the *original* program ``prog`` (transform steps
    adapted)."""
    art = compile_selection(selection, graph, approach, program=prog)
    return validate_schedule(prog, selection, art.schedule, rng_seed=rng_seed)


def validate_schedule(prog: Program, selection: Selection, sched: Schedule,
                      rng_seed: int = 0) -> ValidationReport:
    rng = np.random.default_rng(rng_seed)
    ins = random_inputs(prog, rng)
    ref = interpret(prog, ins)
    ins2 = ins
    for t in selection.steps:
        ins2 = t.adapt_inputs(ins2)
    got = execute(sched, selection, ins2)
    outs = {k: got[k] for k in ref}
    for t in reversed(selection.steps):
        outs = t.adapt_outputs(outs)
    exact = True
    max_err = 0.0
    for k in ref:
        got_k = np.asarray(outs[k])
        if got_k.shape != ref[k].shape and got_k.size == ref[k].size:
            # FuseAxes.adapt_outputs leaves the un-merge to the caller
            got_k = got_k.reshape(ref[k].shape)
        outs[k] = got_k
        if not np.array_equal(outs[k], ref[k]):
            exact = False
        diff = np.abs(np.asarray(outs[k], np.float64)
                      - np.asarray(ref[k], np.float64))
        if diff.size:
            max_err = max(max_err, float(diff.max()))
    return ValidationReport(exact=exact, max_abs_err=max_err,
                            outputs=tuple(ref))
