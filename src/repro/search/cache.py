"""Persistent tuning cache (paper Section 4 "remember winners").

One JSON file maps tuning keys — ``(program fingerprint, sysgraph, backend,
jax version)``, see ``space.tuning_key`` — to the winning config vector plus
provenance (strategy, trials, modeled costs, resolved GEMM tile).  The cache
is what makes search pay off across runs: ``kernels/gemm.py`` and the
benchmarks consult it at run time, so a shape tuned once keeps its schedule
until the toolchain (jax version) or machine description changes.

Writes are atomic (tmp + rename) and reads are tolerant: a missing file is
an empty cache; a *corrupt* file is an empty cache too, but warns once per
path so a damaged cache never degrades performance silently.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import warnings
from dataclasses import dataclass, field
from functools import lru_cache

try:                                    # POSIX advisory locks
    import fcntl
except ImportError:                     # pragma: no cover - non-POSIX
    fcntl = None

SCHEMA_VERSION = 1

#: Override the default cache location (e.g. in CI).
CACHE_ENV_VAR = "REPRO_TUNING_CACHE"

#: The error types a persistent-cache lookup can legitimately raise — what
#: cache-consulting call sites (``kernels.ops.plan_gemm``,
#: ``kernels.gemm.tuned_block``) catch instead of a bare ``Exception``.
#: Shared with the ``repro.compile`` artifact cache.
CACHE_ERRORS = (OSError, ValueError, KeyError, TypeError)

_warned_corrupt: set[str] = set()


def warn_corrupt_cache(path: str, err: Exception) -> None:
    """Warn exactly once per path about an unreadable cache file (the
    tuning cache and the ``repro.compile`` artifact cache both degrade a
    corrupt file to an empty cache, but never silently)."""
    if path in _warned_corrupt:
        return
    _warned_corrupt.add(path)
    warnings.warn(f"ignoring corrupt cache file {path}: {err}", stacklevel=3)


@contextlib.contextmanager
def file_lock(path: str):
    """Advisory inter-process lock on ``path + '.lock'``.

    Serializes the merge-on-save read-modify-write of the persistent caches
    so parallel tuner workers (``tune --workers N``) cannot interleave
    between a save's re-read and its atomic replace — without the lock a
    racing pair can each merge against the *pre*-race file and the second
    ``os.replace`` silently drops the first writer's keys.  Locking is
    best-effort: on platforms without ``fcntl`` the context is a no-op and
    saves fall back to the documented last-writer-wins-per-key race."""
    if fcntl is None:                   # pragma: no cover - non-POSIX
        yield
        return
    lock_path = os.path.abspath(path) + ".lock"
    os.makedirs(os.path.dirname(lock_path), exist_ok=True)
    with open(lock_path, "w") as f:
        fcntl.flock(f, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(f, fcntl.LOCK_UN)


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tuning.json")


@dataclass
class TuningRecord:
    """The winner for one (program, machine, backend, toolchain) cell."""

    key: str
    config: dict
    cost: float                     # tuned cost (modeled s, or measured s)
    baseline_cost: float            # GreedyApproach cost at tuning time
    backend: str = "cost"           # 'cost' | 'measure'
    strategy: str = ""
    trials: int = 0
    tile: tuple | None = None       # resolved (bm, bn, bk) for GEMM cases
    meta: dict = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        return self.baseline_cost / self.cost if self.cost > 0 else 1.0

    def to_dict(self) -> dict:
        d = {"key": self.key, "config": self.config, "cost": self.cost,
             "baseline_cost": self.baseline_cost, "backend": self.backend,
             "strategy": self.strategy, "trials": self.trials,
             "meta": self.meta}
        if self.tile is not None:
            d["tile"] = list(self.tile)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuningRecord":
        tile = d.get("tile")
        return cls(key=d["key"], config=dict(d.get("config", {})),
                   cost=float(d.get("cost", 0.0)),
                   baseline_cost=float(d.get("baseline_cost", 0.0)),
                   backend=d.get("backend", "cost"),
                   strategy=d.get("strategy", ""),
                   trials=int(d.get("trials", 0)),
                   tile=tuple(int(x) for x in tile) if tile else None,
                   meta=dict(d.get("meta", {})))


class JsonStore:
    """Shared keyed-JSON-artifact persistence — the one implementation of
    lazy load with corrupt-file tolerance, merge-on-save, and atomic
    replace behind both the tuning cache and the learned-cost-model store
    (``repro.search.model.ModelStore``).

    Subclasses set ``payload_key``/``schema`` and the entry codecs
    (``_decode`` raising ``KeyError/TypeError/ValueError`` on malformed
    entries, which are skipped).  Entries expose ``.key``.
    """

    payload_key = "records"
    schema = SCHEMA_VERSION

    def __init__(self, path: str | None = None):
        self.path = path or self.default_path()
        self._entries: dict | None = None

    def default_path(self) -> str:          # pragma: no cover - subclassed
        raise NotImplementedError

    def _decode(self, d: dict):             # pragma: no cover - subclassed
        raise NotImplementedError

    def _encode(self, obj) -> dict:
        return obj.to_dict()

    # -- persistence ---------------------------------------------------------
    def load(self) -> dict:
        if self._entries is None:
            entries: dict = {}
            raw = None
            try:
                with open(self.path) as f:
                    raw = json.load(f)
            except OSError:
                pass                        # missing file = empty store
            except ValueError as e:         # json.JSONDecodeError
                warn_corrupt_cache(self.path, e)
            if isinstance(raw, dict):
                for d in raw.get(self.payload_key, []):
                    try:
                        obj = self._decode(d)
                        entries[obj.key] = obj
                    except (KeyError, TypeError, ValueError):
                        continue            # skip malformed entry
            self._entries = entries
        return self._entries

    def save(self) -> None:
        # Merge-on-save under the advisory file lock: re-read the file so
        # entries another process stored since our first load survive (last
        # writer wins per *key*, not per file), and no concurrent save can
        # interleave between the re-read and the atomic replace.
        with file_lock(self.path):
            self._save_locked()

    def _save_locked(self) -> None:
        ours = dict(self.load())
        entries = type(self)(self.path).load()
        entries.update(ours)
        self._entries = entries
        payload = {"schema": self.schema,
                   self.payload_key: [self._encode(o)
                                      for o in entries.values()]}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- access ---------------------------------------------------------------
    def lookup(self, key: str):
        return self.load().get(key)

    def store(self, obj, save: bool = True) -> None:
        self.load()[obj.key] = obj
        if save:
            self.save()

    def keys(self):
        return self.load().keys()

    def __len__(self) -> int:
        return len(self.load())

    def __contains__(self, key: str) -> bool:
        return key in self.load()


class TuningCache(JsonStore):
    """Dict-of-``TuningRecord`` with JSON persistence."""

    payload_key = "records"
    schema = SCHEMA_VERSION

    def default_path(self) -> str:
        return default_cache_path()

    def _decode(self, d: dict) -> TuningRecord:
        return TuningRecord.from_dict(d)


# --------------------------------------------------------------------------- #
# Process-wide default cache (what the kernels consult at run time)
# --------------------------------------------------------------------------- #

_default_cache: TuningCache | None = None


def get_default_cache() -> TuningCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = TuningCache()
    return _default_cache


def set_default_cache(cache: TuningCache | None) -> None:
    """Point the process at a specific cache (tests, --tuned launches)."""
    global _default_cache
    _default_cache = cache


# --------------------------------------------------------------------------- #
# GEMM convenience lookups (the kernels' entry point)
# --------------------------------------------------------------------------- #


def clamp_tile(tile, m: int, n: int, k: int) -> tuple[int, int, int]:
    """Clamp a recorded/requested (bm, bn, bk) tile to an (m, n, k) problem
    — the one definition shared by ``kernels.gemm.tuned_block``,
    ``kernels.ops.plan_gemm`` and ``search.evaluate.gemm_tile_for``."""
    bm, bn, bk = (int(x) for x in tile)
    return (max(1, min(bm, m)), max(1, min(bn, n)), max(1, min(bk, k)))


def gemm_tuning_key(m: int, n: int, k: int, graph=None,
                    backend: str = "cost") -> str:
    """Cache key for the canonical (m, n, k) GEMM program on ``graph``
    (default: the single-core v5e graph the kernels schedule against)."""
    if graph is None:
        return _default_gemm_key(m, n, k, backend)
    from ..core import kernels_ir as K
    from .space import tuning_key
    return tuning_key(K.matmul(m, n, k), graph, backend)


@lru_cache(maxsize=1024)
def _default_gemm_key(m: int, n: int, k: int, backend: str) -> str:
    from ..core import kernels_ir as K
    from ..core.sysgraph import tpu_v5e
    from .space import tuning_key
    return tuning_key(K.matmul(m, n, k), tpu_v5e(1), backend)


def lookup_gemm(m: int, n: int, k: int, graph=None,
                cache: TuningCache | None = None) -> TuningRecord | None:
    """Best tuned record for an (m, n, k) GEMM; measured wall-clock wins
    over cost-model records when both exist."""
    cache = cache or get_default_cache()
    for backend in ("measure", "cost"):
        rec = cache.lookup(gemm_tuning_key(m, n, k, graph, backend))
        if rec is not None:
            return rec
    return None
