"""Vectorized population evaluation (the throughput tier of repro.search).

Scalar tuning pays two per-config costs: the tile-count feasibility guard
(``CostModelEvaluator.estimated_tiles`` — a Python loop over instructions)
and the schedule itself.  ``BatchPlan`` amortizes the first and exposes the
structure that lets the evaluator skip the second:

  * **Vectorized guard** — ``choose_tile_shape`` + the tile-count bound are
    replayed as numpy array arithmetic over a whole config population at
    once.  The arithmetic mirrors ``Approach.choose_tile_shape`` exactly
    (including truncation and floor-division behavior), so batch
    feasibility is bit-identical to the scalar guard.

  * **Schedule keys** — a config influences the scheduler only through
    (a) each instruction's resolved mapped-axis tile sizes (clamped to the
    extents, as ``Scheduler._tiles_for`` clamps them), (b) the unroll
    policy, and (c) the device/source policies *where they can matter*.
    On a single-core graph every device policy picks the same device and
    every source policy sees at most one candidate copy, so those axes are
    dropped from the key — configs that alias to the same key provably
    produce the same schedule, and the evaluator scores them once.

The plan is deliberately selection-static: everything that does not depend
on the config (extents, hardware tiles, VMEM budgets, call counts, policy
droppability) is computed once in ``__init__``.
"""
from __future__ import annotations

import numpy as np

from ..core.approach import GreedyApproach
from ..core.instructions import is_elementwise
from ..core.isel import Selection
from ..core.scheduler import Scheduler
from ..core.sysgraph import SystemGraph
from .space import ParamApproach

#: schedule key: (per-instr clamped tile tuples, unroll, device, source)
ScheduleKey = tuple


class _InstrPlan:
    """Config-independent data of one SelectedInstr (guard + key inputs)."""

    __slots__ = ("axes", "extents", "hw_tile", "vmem_budget", "calls",
                 "has_k", "ext_i", "ext_j", "ext_k")

    def __init__(self, si, prog, graph: SystemGraph):
        devices = graph.compute_nodes_for(si.needle.name)
        # axis_map order is the deterministic per-instr axis order everywhere
        self.axes = [na for na, _ in si.mapping.axis_map]
        self.extents = {na: prog.axis(ha).size for na, ha in si.mapping.axis_map}
        self.hw_tile = devices[0].matmul_tile
        self.vmem_budget = graph.staging_budget(devices)
        self.calls = 1 if is_elementwise(si.needle.name) \
            else si.mapping.calls(prog)
        self.has_k = "k" in self.extents
        self.ext_i = self.extents.get("i")
        self.ext_j = self.extents.get("j")
        self.ext_k = self.extents.get("k")


class BatchPlan:
    """Population-level feasibility + schedule-key analysis for one
    (selection, graph) pair."""

    def __init__(self, selection: Selection, graph: SystemGraph):
        self.sel = selection
        self.graph = graph
        prog = selection.program
        self.instrs = [_InstrPlan(si, prog, graph) for si in selection.instrs
                       if graph.compute_nodes_for(si.needle.name)]
        #: some instruction has no executing device: every compile fails,
        #: so every config scores inf without scheduling anything
        self.unschedulable = len(self.instrs) != len(selection.instrs)
        self.device_droppable, self.source_droppable = \
            self._droppable_policies(selection, graph)

    @staticmethod
    def _droppable_policies(selection: Selection,
                            graph: SystemGraph) -> tuple[bool, bool]:
        """Which policy axes provably cannot change the schedule.

        * device: with at most one candidate device per instruction, every
          ``choose_device`` call returns the same node under any policy.
        * source: with a single level-1 HBM that is every buffer's home and
          a single compute memory, the holder set of any routed region is a
          subset of {home, destination vmem}; ``choose_source`` then never
          sees two options, and the reconcile/writeback/evict paths do not
          consult the policy at all.
        """
        try:
            dev_drop = all(
                len(graph.compute_nodes_for(si.needle.name)) <= 1
                for si in selection.instrs)
            hbms = [m.name for m in graph.memories.values() if m.level == 1]
            homes = Scheduler(selection, graph, GreedyApproach()).homes
            compute_mems = {d.memory for si in selection.instrs
                            for d in graph.compute_nodes_for(si.needle.name)}
            src_drop = (len(hbms) == 1
                        and all(h == hbms[0] for h in homes.values())
                        and len(compute_mems) <= 1)
        except Exception:
            return False, False
        return dev_drop, src_drop

    # -- population analysis -------------------------------------------------
    def analyze(self, configs: list[dict],
                max_tiles: int) -> tuple[np.ndarray, list[ScheduleKey]]:
        """(feasible mask, schedule key) per config.

        Feasibility is bit-identical to
        ``CostModelEvaluator.estimated_tiles(...) <= max_tiles``; equal keys
        guarantee equal schedules (and so equal modeled cost).
        """
        n = len(configs)
        if n == 0:
            return np.zeros(0, dtype=bool), []
        # Normalize through ParamApproach so batch parity inherits every
        # scalar fallback rule (falsy caps -> None, bad frac -> 1.0,
        # unknown policy names -> greedy defaults).
        aps = [ParamApproach(c) for c in configs]
        capi = np.array([a.tile_caps[0] or 0 for a in aps], np.int64)
        capj = np.array([a.tile_caps[1] or 0 for a in aps], np.int64)
        capk = np.array([a.tile_caps[2] or 0 for a in aps], np.int64)
        frac = np.array([a.vmem_frac for a in aps], np.float64)
        grow = np.array([a.grow_j for a in aps], bool)
        budget0 = np.array([a.tile_vmem_budget for a in aps], np.int64)

        total = np.zeros(n, np.int64)
        instr_tiles: list[np.ndarray] = []   # one (n, n_axes) array per instr
        for ip in self.instrs:
            out = self._tile_shapes(ip, capi, capj, capk, frac, grow, budget0)
            mapped = np.ones(n, np.int64)
            cols = []
            for axis in ip.axes:
                ext = ip.extents[axis]
                tile = np.maximum(1, np.minimum(out[axis], ext))
                mapped *= -(-ext // tile)            # ceil(ext / tile)
                cols.append(tile)
            total += mapped * ip.calls
            instr_tiles.append(np.stack(cols, axis=1) if cols
                               else np.zeros((n, 0), np.int64))
        feasible = total <= max_tiles

        if self.device_droppable:
            dev = [""] * n
        else:
            dev = [a.device_policy for a in aps]
        if self.source_droppable:
            src = [""] * n
        else:
            src = [a.source_policy for a in aps]
        keys: list[ScheduleKey] = []
        for i in range(n):
            tiles = tuple(tuple(int(x) for x in mat[i])
                          for mat in instr_tiles)
            keys.append((tiles, aps[i].unroll_policy, dev[i], src[i]))
        return feasible, keys

    def first_changed(self, key_a: ScheduleKey, key_b: ScheduleKey) -> int:
        """Index of the first SelectedInstr whose resolved tiles differ
        between two same-policy keys (``len(instrs)`` when none differ)."""
        for idx, (ta, tb) in enumerate(zip(key_a[0], key_b[0])):
            if ta != tb:
                return idx
        return len(key_a[0])

    # -- choose_tile_shape, vectorized ---------------------------------------
    @staticmethod
    def _tile_shapes(ip: _InstrPlan, capi, capj, capk, frac, grow,
                     budget0) -> dict[str, np.ndarray]:
        """``Approach.choose_tile_shape`` over a config population.

        Mirrors the scalar code line by line; numpy int64 floor division
        matches Python ``//`` on negatives, and the budget truncation uses
        the same toward-zero semantics as ``int(...)`` on the (positive)
        scalar product.
        """
        ti, tj, tk = ip.hw_tile
        cap_i = np.where(capi == 0, ti, capi)
        cap_j = np.where(capj == 0, tj, capj)
        out: dict[str, np.ndarray] = {}
        if ip.ext_i is not None:
            out["i"] = np.minimum(ip.ext_i, cap_i)
        if ip.ext_j is not None:
            out["j"] = np.minimum(ip.ext_j, cap_j)
        budget = (np.minimum(budget0, ip.vmem_budget)
                  * frac).astype(np.int64)
        if ip.has_k:
            bm = out.get("i", cap_i)
            bn = out.get("j", cap_j)
            k_capped = np.minimum(ip.ext_k, np.maximum(tk, capk))
            k_max = np.maximum(tk, (budget // 4 - bm * bn)
                               // np.maximum(bm + bn, 1))
            k_stream = np.minimum(ip.ext_k, k_max)
            # ParamApproach: stream_k <=> tile_k cap is None, so the scalar
            # "neither cap nor stream" branch is unreachable here
            out["k"] = np.where(capk > 0, k_capped, k_stream)
            bk = out["k"]
            if ip.ext_j is not None:
                j_max = (budget // 4 - bm * bk) // np.maximum(bk + bm, 1)
                j_max = np.maximum(tj, (j_max // tj) * tj)
                grown = np.minimum(ip.ext_j, np.maximum(out["j"], j_max))
                out["j"] = np.where(grow, grown, out["j"])
        hw_max = max(ti, tj, tk)
        for axis, ext in ip.extents.items():
            if axis not in out:
                out[axis] = np.full(len(capi), min(ext, hw_max), np.int64)
        return out
