"""The searchable mapping/schedule space (paper Section 4).

``ParamApproach`` turns the Approach interface into data: every decision the
compiler routes through an Approach — tile shapes, reduction streaming,
VMEM budget, unroll order, device allocation, copy-source choice — is driven
by one explicit config vector (a flat ``dict``).  ``SearchSpace`` enumerates
and mutates those vectors; the strategies in ``strategies.py`` never need to
know what the dimensions mean.

The distinguished ``baseline()`` point reproduces ``GreedyApproach``
*exactly*, which gives every search a sound anchor: a tuner that evaluates
the baseline first can never report a config worse than the paper's
heuristics.

Fingerprinting: cache keys must survive process restarts and distinguish
programs/machines structurally, so they hash ``Program.signature()`` and the
system graph's node/edge structure rather than relying on names alone.
"""
from __future__ import annotations

import functools
import hashlib
import random
from dataclasses import dataclass
from typing import Iterator, Mapping

from ..core.approach import (Approach, DEVICE_POLICIES, SOURCE_POLICIES,
                             UNROLL_POLICIES)
from ..core.ir import Program
from ..core.sysgraph import SystemGraph

Config = dict   # a point in the space: {axis name -> value}


# --------------------------------------------------------------------------- #
# ParamApproach — config-vector-driven Approach
# --------------------------------------------------------------------------- #


class ParamApproach(Approach):
    """An Approach whose decision points are set from a config vector.

    Missing keys fall back to the greedy defaults, so configs stored by
    older caches (or hand-written partial configs) keep working.
    """

    def __init__(self, config: Mapping | None = None):
        cfg = dict(config or {})
        self.config = cfg

        def _cap(v):
            return int(v) if isinstance(v, (int, float)) and v else None

        self.tile_caps = (_cap(cfg.get("tile_i")), _cap(cfg.get("tile_j")),
                          _cap(cfg.get("tile_k")))
        self.stream_k = self.tile_caps[2] is None
        try:
            frac = float(cfg.get("vmem_frac", 1.0))
        except (TypeError, ValueError):
            frac = 1.0
        self.vmem_frac = frac if 0.0 < frac <= 1.0 else 1.0
        self.grow_j = bool(cfg.get("grow_j", True))
        # Unknown policy names (e.g. records written by a newer version)
        # fall back to the greedy defaults — cache reads stay tolerant.
        self.unroll_policy = cfg.get("unroll", "out_major")
        if self.unroll_policy not in UNROLL_POLICIES:
            self.unroll_policy = "out_major"
        self.device_policy = cfg.get("device", "locality")
        if self.device_policy not in DEVICE_POLICIES:
            self.device_policy = "locality"
        self.source_policy = cfg.get("source", "cheapest")
        if self.source_policy not in SOURCE_POLICIES:
            self.source_policy = "cheapest"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ParamApproach({self.config!r})"


# --------------------------------------------------------------------------- #
# SearchSpace
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class SpaceAxis:
    """One named decision dimension and its finite choice set."""

    name: str
    choices: tuple


class SearchSpace:
    """Finite, enumerable space of Approach config vectors.

    Tile choices are derived from the target's hardware matmul tile: caps
    below the hardware shape only waste MXU passes (the cost model charges
    whole passes), so the space spans [hw, 4*hw] for output dims and
    [hw, 8*hw] or budget-streaming for the reduction.

    ``fabric_axes`` extends the space with the distributed-mapping
    dimensions (``part_axis`` over the given partition axes, ``collective``
    over the ring algorithms) so ``repro.fabric.FabricEvaluator`` can tune
    the partition jointly with the per-chip tiles.  The fabric baseline is
    (first axis, ``ring``) — the untuned multi-chip default.
    """

    def __init__(self, hw_tile: tuple[int, int, int] = (128, 128, 128),
                 fabric_axes: tuple[str, ...] = ()):
        ti, tj, tk = hw_tile
        self.hw_tile = hw_tile
        self.fabric_axes = tuple(fabric_axes)
        self.axes: tuple[SpaceAxis, ...] = (
            SpaceAxis("tile_i", (None, ti, 2 * ti, 4 * ti)),
            SpaceAxis("tile_j", (None, tj, 2 * tj, 4 * tj)),
            SpaceAxis("tile_k", (None, tk, 2 * tk, 4 * tk, 8 * tk)),
            SpaceAxis("vmem_frac", (1.0, 0.5, 0.25)),
            SpaceAxis("grow_j", (True, False)),
            SpaceAxis("unroll", tuple(UNROLL_POLICIES)),
            SpaceAxis("device", DEVICE_POLICIES),
            SpaceAxis("source", SOURCE_POLICIES),
        )
        if self.fabric_axes:
            from ..fabric.collectives import ALGORITHMS
            self.axes += (SpaceAxis("part_axis", self.fabric_axes),
                          SpaceAxis("collective", tuple(ALGORITHMS)))
        self._by_name = {a.name: a for a in self.axes}

    @classmethod
    def for_graph(cls, graph: SystemGraph) -> "SearchSpace":
        return cls(graph.min_matmul_tile())

    @classmethod
    def for_fabric(cls, kernel: str = "gemm") -> "SearchSpace":
        """The joint (partition axis, collective algorithm, per-chip tile)
        space for distributed tuning over v5e chips."""
        from ..fabric.partition import partition_axes
        from ..fabric.topology import Topology
        return cls(Topology.chip_graph().min_matmul_tile(),
                   fabric_axes=partition_axes(kernel))

    # -- points --------------------------------------------------------------
    def baseline(self) -> Config:
        """The greedy-equivalent point: ParamApproach(baseline()) makes the
        same decisions as GreedyApproach on every program (plus, in fabric
        spaces, the untuned multi-chip default partition)."""
        base = {"tile_i": None, "tile_j": None, "tile_k": None,
                "vmem_frac": 1.0, "grow_j": True, "unroll": "out_major",
                "device": "locality", "source": "cheapest"}
        if self.fabric_axes:
            base["part_axis"] = self.fabric_axes[0]
            base["collective"] = "ring"
        return base

    def random_config(self, rng: random.Random) -> Config:
        return {a.name: rng.choice(a.choices) for a in self.axes}

    def mutate(self, config: Config, rng: random.Random,
               n_mutations: int = 1) -> Config:
        """Flip ``n_mutations`` randomly chosen dimensions to new values."""
        out = dict(config)
        for _ in range(max(1, n_mutations)):
            ax = rng.choice(self.axes)
            alts = [c for c in ax.choices if c != out.get(ax.name)]
            if alts:
                out[ax.name] = rng.choice(alts)
        return out

    def crossover(self, a: Config, b: Config, rng: random.Random) -> Config:
        """Uniform crossover of two parent configs."""
        return {ax.name: (a if rng.random() < 0.5 else b).get(ax.name)
                for ax in self.axes}

    def neighbors(self, config: Config) -> Iterator[Config]:
        """All single-dimension mutations, in deterministic order."""
        for ax in self.axes:
            for c in ax.choices:
                if c != config.get(ax.name):
                    yield {**config, ax.name: c}

    def size(self) -> int:
        n = 1
        for a in self.axes:
            n *= len(a.choices)
        return n

    def enumerate_configs(self) -> Iterator[Config]:
        """Every point of the space, in deterministic (axis-major) order —
        what the surrogate strategy ranks when the space is small enough to
        score exhaustively (a prediction costs microseconds, so even ~10^4
        points are cheap to rank)."""
        import itertools
        names = [a.name for a in self.axes]
        for values in itertools.product(*(a.choices for a in self.axes)):
            yield dict(zip(names, values))

    def to_approach(self, config: Config) -> ParamApproach:
        return ParamApproach(config)


def config_key(config: Config) -> tuple:
    """Hashable canonical form of a config vector (for dedup / storage)."""
    return tuple(sorted(config.items()))


# --------------------------------------------------------------------------- #
# Fingerprinting
# --------------------------------------------------------------------------- #


def _short_hash(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


@functools.lru_cache(maxsize=512)
def program_fingerprint(prog: Program) -> str:
    """Stable structural hash of a haystack program (axes, buffers, access
    matrices) — survives renaming-free rebuilds across processes.  Cached
    (Program is frozen/hashable): artifact keying sits on the evaluator hot
    path and re-fingerprints the same program every trial."""
    return _short_hash(prog.signature())


def sysgraph_fingerprint(graph: SystemGraph) -> str:
    """Structural hash of a system graph: target family, memory
    capacities/levels/roles, compute capabilities, and movement edges.
    Two targets that differ in any of these can never share an artifact,
    tuning record or learned model (the cross-backend isolation the
    portability tests pin down)."""
    parts = [graph.name, f"F{getattr(graph, 'family', 'generic')}"]
    for m in sorted(graph.memories.values(), key=lambda m: m.name):
        parts.append(f"M{m.name}:{m.capacity}:{m.level}:{m.role}")
    for c in sorted(graph.computes.values(), key=lambda c: c.name):
        parts.append(f"C{c.name}:{c.memory}:{sorted(c.instructions)}:"
                     f"{c.flops_per_sec}:{c.matmul_tile}:{c.vector_lanes}:"
                     f"{c.clock_hz}")
    for e in sorted(graph.edges, key=lambda e: (e.src, e.dst)):
        parts.append(f"E{e.src}>{e.dst}:{e.bandwidth}:{e.latency}")
    return _short_hash(";".join(parts))


@functools.lru_cache(maxsize=1)
def jax_version() -> str:
    """jax version without importing jax (keeps core/search numpy-only).
    Cached: the metadata scan costs milliseconds and the result is a
    process-constant, while ``tuning_key``/``artifact_key`` sit on the
    evaluator hot path."""
    try:
        from importlib.metadata import version
        return version("jax")
    except Exception:  # pragma: no cover - metadata unavailable
        return "unknown"


def tuning_key(prog: Program, graph: SystemGraph | str,
               backend: str = "cost") -> str:
    """Persistent cache key: (program fingerprint, sysgraph, backend,
    jax version) per the tuning-cache contract."""
    if isinstance(graph, SystemGraph):
        gname = f"{graph.name}@{sysgraph_fingerprint(graph)}"
    else:
        gname = graph
    return (f"{prog.name}@{program_fingerprint(prog)}|{gname}"
            f"|{backend}|jax={jax_version()}")
