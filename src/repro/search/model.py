"""Learned cost model over ``ParamApproach`` config vectors (paper Section 4).

The paper's search framework explicitly reserves a slot for "machine
learning to facilitate this search problem".  This module is that leg: a
deterministic, numpy-only **ridge regression** over the engineered feature
vectors of ``repro.compile.features``, trained on records harvested from the
persistent tuning cache plus fresh ``CostModelEvaluator`` labels, predicting
``log(modeled cost)``.

Why ridge, not a tree/NN: the training sets are small (tens to a few
thousand labels), the features are engineered to be near-linear in log-cost,
closed-form ridge is exactly reproducible across platforms (one
``np.linalg.solve``), and the whole artifact — feature names, scaler,
weights — round-trips through JSON in a few hundred bytes.

Model artifacts are keyed like tuning records — ``(program family, sysgraph
fingerprint, backend, jax version)`` — and live in a ``ModelStore`` JSON
file.  Consumers:

  * ``search.evaluate.LearnedEvaluator`` — scores configs by prediction
    (microseconds) instead of scheduling them (milliseconds to seconds);
  * ``search.strategies.surrogate_search`` — ranks a large candidate pool by
    predicted cost and spends the real trial budget on the top of the
    ranking;
  * ``kernels.gemm.tuned_block`` — on a tuning-cache miss, a process-default
    model picks the BlockSpec tile for never-tuned shapes.

CLI::

    python -m repro.search.model train --suite gemm,conv --cache PATH \\
        --store PATH [--samples N] [--holdout F] [--json PATH]
    python -m repro.search.model eval  --store PATH --suite gemm \\
        [--samples N] [--topk K] [--json PATH]
    python -m repro.search.model export --store PATH [--key KEY] [--out P]
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import random
import sys
from dataclasses import dataclass, field

import numpy as np

from ..compile.features import (FEATURE_SCHEMA, feature_dict, feature_names,
                                program_family, role_extents)
from ..core.ir import Program
from ..core.sysgraph import SystemGraph
from .cache import CACHE_ERRORS, JsonStore, TuningCache
from .space import (Config, SearchSpace, config_key, jax_version,
                    sysgraph_fingerprint)

MODEL_SCHEMA = 1

#: Override the default model-store location (e.g. in CI).
MODEL_ENV_VAR = "REPRO_MODEL_STORE"

#: Below this many training labels a family model is not trained at all —
#: callers fall back to the analytical cost backend.
MIN_TRAIN_SAMPLES = 16


def default_store_path() -> str:
    env = os.environ.get(MODEL_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "models.json")


def model_key(family: str, graph: SystemGraph | str,
              backend: str = "cost") -> str:
    """Mirror of ``space.tuning_key`` at program-*family* granularity: one
    model covers every shape of a family on one machine/toolchain."""
    if isinstance(graph, SystemGraph):
        gname = f"{graph.name}@{sysgraph_fingerprint(graph)}"
    else:
        gname = graph
    return f"{family}|{gname}|{backend}|jax={jax_version()}"


# --------------------------------------------------------------------------- #
# Samples
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Sample:
    """One training label: a config and its (modeled) cost on a program.
    ``roles`` carries the matmul role extents of the case's selection, so
    tile-cap features bind against the right axes (conv extractions map
    the MXU roles onto fused haystack axes)."""

    config: dict
    cost: float                 # seconds, > 0 and finite
    program: Program
    case: str = ""
    source: str = "fresh"       # 'cache' | 'fresh'
    roles: dict = field(default_factory=dict)


def harvest_cache(cache: TuningCache, cases, graph: SystemGraph,
                  backend: str = "cost") -> list[Sample]:
    """Labels mined from the persistent tuning cache: every matching record
    contributes its winner (config, cost) and its baseline (greedy config,
    baseline cost).  ``cases`` are ``tune.TuneCase``-likes (``.program`` +
    ``.name``); records are matched by tuning key, so only cases actually
    tuned on this graph/backend/toolchain yield samples."""
    from .space import tuning_key
    out: list[Sample] = []
    space = SearchSpace.for_graph(graph)
    for case in cases:
        try:
            rec = cache.lookup(tuning_key(case.program, graph, backend))
        except CACHE_ERRORS:
            rec = None
        if rec is None:
            continue
        roles = role_extents(case.selection)
        if np.isfinite(rec.cost) and rec.cost > 0 and rec.config:
            out.append(Sample(dict(rec.config), float(rec.cost),
                              case.program, case.name, "cache", roles))
        if np.isfinite(rec.baseline_cost) and rec.baseline_cost > 0:
            out.append(Sample(space.baseline(), float(rec.baseline_cost),
                              case.program, case.name, "cache", roles))
    return out


def fresh_labels(case, graph: SystemGraph, n: int = 48, seed: int = 0,
                 anchors: list[Config] | None = None,
                 baseline_pool: bool = True) -> list[Sample]:
    """Fresh ``CostModelEvaluator`` labels for one case: the baseline, a
    deterministic walk of its single-mutation neighborhood, then seeded
    random configs — the same candidate distribution the strategies explore,
    so the model trains on the region it will be asked to rank.  ``anchors``
    (e.g. harvested cache winners) and their neighborhoods are labeled too:
    the data flywheel concentrates samples where past searches found wins.
    Infeasible configs (``inf``) are skipped (log-cost is undefined).

    ``baseline_pool=False`` drops the deterministic baseline-neighborhood
    block and labels seeded-random configs only — what a held-out *eval*
    set needs, since training always contains that block (``topk_regret``
    must not score the model on its own training points)."""
    from .evaluate import CostModelEvaluator
    rng = random.Random(seed)
    space = SearchSpace.for_graph(graph)
    ev = CostModelEvaluator(case.selection, graph)
    pool: list[Config] = []
    if baseline_pool:
        pool.append(space.baseline())
        pool += list(space.neighbors(space.baseline()))
    for a in (anchors or []):
        pool.append(dict(a))
        pool += list(space.neighbors(a))
    configs, seen = [], set()
    for c in pool:
        if config_key(c) not in seen:
            seen.add(config_key(c))
            configs.append(c)
    attempts = 0
    while len(configs) < n and attempts < n * 50:
        attempts += 1
        c = space.random_config(rng)
        if config_key(c) not in seen:
            seen.add(config_key(c))
            configs.append(c)
    roles = role_extents(case.selection)
    cut = max(n, len(pool)) if anchors else n   # always label the anchors
    out = []
    for c in configs[:cut]:
        cost = ev(c)
        if np.isfinite(cost) and cost > 0:
            out.append(Sample(dict(c), float(cost), case.program,
                              case.name, "fresh", roles))
    return out


# --------------------------------------------------------------------------- #
# The ridge model
# --------------------------------------------------------------------------- #


@dataclass
class CostModel:
    """Closed-form ridge regression predicting log(cost seconds).

    ``names`` is the feature schema the weights are aligned to; prediction
    recomputes features by name, so a model stays valid as long as the
    feature definitions (``FEATURE_SCHEMA``) do."""

    key: str
    family: str
    names: tuple[str, ...]
    weights: np.ndarray          # (n_features,)
    intercept: float
    x_mean: np.ndarray
    x_scale: np.ndarray
    alpha: float = 1.0
    n_samples: int = 0
    feature_schema: int = FEATURE_SCHEMA
    meta: dict = field(default_factory=dict)

    # -- fit / predict -------------------------------------------------------
    @classmethod
    def fit(cls, key: str, family: str, names: tuple[str, ...],
            X: np.ndarray, y_cost: np.ndarray, alpha: float = 1.0,
            meta: dict | None = None) -> "CostModel":
        """Ridge on standardized features vs log-cost.  Deterministic: no
        iteration, no randomness — one normal-equations solve."""
        X = np.asarray(X, np.float64)
        y = np.log(np.asarray(y_cost, np.float64))
        mean = X.mean(axis=0)
        scale = X.std(axis=0)
        scale[scale < 1e-12] = 1.0
        Z = (X - mean) / scale
        n = Z.shape[1]
        A = Z.T @ Z + alpha * np.eye(n)
        w = np.linalg.solve(A, Z.T @ (y - y.mean()))
        return cls(key=key, family=family, names=tuple(names), weights=w,
                   intercept=float(y.mean()), x_mean=mean, x_scale=scale,
                   alpha=float(alpha), n_samples=int(len(y)),
                   meta=dict(meta or {}))

    def predict_rows(self, X: np.ndarray) -> np.ndarray:
        """Predicted cost (seconds) for rows already in ``names`` order.
        The reshape keeps an *empty* batch well-formed — ``np.array([])``
        is shape (0,), which would not broadcast against the scaler."""
        X = np.asarray(X, np.float64).reshape(-1, len(self.names))
        Z = (X - self.x_mean) / self.x_scale
        return np.exp(Z @ self.weights + self.intercept)

    def predict(self, config: Config, prog: Program, graph: SystemGraph,
                roles: dict | None = None) -> float:
        return float(self.predict_rows(
            _rows([config], prog, graph, self.names, roles))[0])

    def predictor(self, prog: Program, graph: SystemGraph,
                  roles: dict | None = None):
        """A fast ``config -> predicted cost`` closure with the static
        (program/graph/role) features precomputed once.  Also exposes
        ``.predict_many(configs) -> np.ndarray`` for pool ranking."""
        from ..compile.features import (_default_roles, _interactions,
                                        config_features)
        roles = roles or _default_roles(prog)
        static = feature_dict({}, prog, graph, roles)
        rf = {k: static[k] for k in static if k.startswith("log_role_")}
        hw = graph.min_matmul_tile()

        def row(config: Config) -> list[float]:
            cfg = config_features(config, hw, roles)
            d = {**static, **cfg, **_interactions(cfg, static, rf)}
            return [d[n] for n in self.names]

        def predict_many(configs) -> np.ndarray:
            return self.predict_rows(np.array([row(c) for c in configs],
                                              np.float64))

        def predict_one(config: Config) -> float:
            return float(predict_many([config])[0])

        predict_one.predict_many = predict_many
        predict_one.model = self
        return predict_one

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": MODEL_SCHEMA, "key": self.key,
                "family": self.family, "names": list(self.names),
                "weights": [float(w) for w in self.weights],
                "intercept": self.intercept,
                "x_mean": [float(v) for v in self.x_mean],
                "x_scale": [float(v) for v in self.x_scale],
                "alpha": self.alpha, "n_samples": self.n_samples,
                "feature_schema": self.feature_schema,
                "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        m = cls(key=d["key"], family=d.get("family", ""),
                names=tuple(d.get("names", [])),
                weights=np.asarray(d.get("weights", []), np.float64),
                intercept=float(d.get("intercept", 0.0)),
                x_mean=np.asarray(d.get("x_mean", []), np.float64),
                x_scale=np.asarray(d.get("x_scale", []), np.float64),
                alpha=float(d.get("alpha", 1.0)),
                n_samples=int(d.get("n_samples", 0)),
                feature_schema=int(d.get("feature_schema", -1)),
                meta=dict(d.get("meta", {})))
        if m.feature_schema != FEATURE_SCHEMA:
            raise ValueError(
                f"model {m.key!r} was trained with feature schema "
                f"{m.feature_schema}, current is {FEATURE_SCHEMA}")
        if not (len(m.names) == len(m.weights) == len(m.x_mean)
                == len(m.x_scale)):
            raise ValueError(f"model {m.key!r} has inconsistent shapes")
        return m


def _rows(configs, prog: Program, graph: SystemGraph,
          names: tuple[str, ...], roles: dict | None = None) -> np.ndarray:
    return np.array([[feature_dict(c, prog, graph, roles)[n] for n in names]
                     for c in configs], np.float64)


def train_family(key: str, family: str, samples: list[Sample],
                 graph: SystemGraph, alpha: float = 1.0,
                 holdout: float = 0.25, seed: int = 0
                 ) -> tuple[CostModel | None, dict]:
    """Fit one family model on ``samples``; returns ``(model, metrics)``.
    ``model`` is ``None`` (and metrics say why) below ``MIN_TRAIN_SAMPLES``.
    The holdout split is a seeded shuffle, so metrics are reproducible."""
    if len(samples) < MIN_TRAIN_SAMPLES:
        return None, {"key": key, "family": family, "trained": False,
                      "reason": f"{len(samples)} samples "
                                f"< {MIN_TRAIN_SAMPLES} required",
                      "n_samples": len(samples)}
    names = feature_names(samples[0].program, graph)
    order = list(range(len(samples)))
    random.Random(seed).shuffle(order)
    n_hold = int(len(order) * holdout) if len(order) >= 8 else 0
    hold, tr = order[:n_hold], order[n_hold:]

    def matrix(idx):
        X = np.concatenate([_rows([samples[i].config], samples[i].program,
                                  graph, names, samples[i].roles or None)
                            for i in idx])
        y = np.array([samples[i].cost for i in idx], np.float64)
        return X, y

    Xtr, ytr = matrix(tr)
    model = CostModel.fit(key, family, names, Xtr, ytr, alpha=alpha,
                          meta={"sources": _source_counts(samples),
                                "holdout": n_hold, "seed": seed,
                                "anchors": _anchor_configs(samples, graph)})
    metrics = {"key": key, "family": family, "trained": True,
               "n_samples": len(samples), "n_train": len(tr),
               "n_holdout": n_hold, "alpha": alpha,
               "sources": _source_counts(samples)}
    pred_tr = model.predict_rows(Xtr)
    metrics["train_mae_log"] = float(
        np.mean(np.abs(np.log(pred_tr) - np.log(ytr))))
    if n_hold:
        Xh, yh = matrix(hold)
        pred = model.predict_rows(Xh)
        metrics["holdout_mae_log"] = float(
            np.mean(np.abs(np.log(pred) - np.log(yh))))
        metrics["holdout_mape"] = float(
            np.mean(np.abs(pred - yh) / yh))
    return model, metrics


#: Cap on the winner configs a model artifact carries as search seeds.
MAX_ANCHORS = 16


def _anchor_configs(samples: list[Sample], graph: SystemGraph) -> list[dict]:
    """The cache-winner configs among ``samples``, deduped and ordered by
    their recorded cost — the family's "known good" set.  Stored in the
    model artifact so surrogate-guided search can seed its real trials with
    past winners (the tuning cache's "remember winners" philosophy lifted
    from exact program keys to the whole program family)."""
    base = config_key(SearchSpace.for_graph(graph).baseline())
    winners = [s for s in sorted(samples, key=lambda s: s.cost)
               if s.source == "cache" and s.config
               and config_key(s.config) != base]
    out, seen = [], set()
    for s in winners:
        k = config_key(s.config)
        if k not in seen:
            seen.add(k)
            out.append(dict(s.config))
        if len(out) >= MAX_ANCHORS:
            break
    return out


def _source_counts(samples: list[Sample]) -> dict:
    counts: dict[str, int] = {}
    for s in samples:
        counts[s.source] = counts.get(s.source, 0) + 1
    return counts


# --------------------------------------------------------------------------- #
# ModelStore — JSON persistence, keyed like the tuning cache
# --------------------------------------------------------------------------- #


class ModelStore(JsonStore):
    """Dict of ``CostModel`` artifacts with JSON persistence — the same
    lazy-load / merge-on-save / atomic-replace behavior as ``TuningCache``
    (both derive from ``cache.JsonStore``).  Models whose feature schema
    drifted fail ``CostModel.from_dict`` and are skipped on load — the
    graceful no-model fallback, not a crash."""

    payload_key = "models"
    schema = MODEL_SCHEMA

    def default_path(self) -> str:
        return default_store_path()

    def _decode(self, d: dict) -> CostModel:
        return CostModel.from_dict(d)

    def model_for(self, prog: Program | str, graph: SystemGraph,
                  backend: str = "cost") -> CostModel | None:
        return self.lookup(model_key(program_family(prog), graph, backend))


_default_store: ModelStore | None = None


def get_default_store() -> ModelStore | None:
    """The process-wide model store, if one was activated (``--tuned``
    launches / tests).  Unlike the tuning cache this defaults to **None**:
    learned predictions only happen when explicitly opted in."""
    return _default_store


def set_default_store(store: ModelStore | None) -> None:
    global _default_store
    _default_store = store


def predict_gemm_block(m: int, n: int, k: int, store: ModelStore | None = None,
                       graph: SystemGraph | None = None
                       ) -> tuple[int, int, int] | None:
    """Model-picked (bm, bn, bk) BlockSpec for a *never-tuned* GEMM shape:
    rank the tile sub-space (policies at baseline) plus the model's anchors
    by predicted cost and return the winner's resolved tile.  ``None`` when
    no model store is active or no matmul-family model exists — the caller
    (``kernels.gemm.tuned_block``) keeps its static default.  Pure numpy
    prediction: safe to call at jit trace time.

    Candidates go through the same tile-count guard the search evaluators
    use — the model never trains on infeasible points, so an extrapolating
    prediction must not be able to hand a degenerate BlockSpec to a real
    Pallas kernel."""
    store = store if store is not None else get_default_store()
    if store is None:
        return None
    from ..compile import gemm_selection
    from ..core.sysgraph import tpu_v5e
    from .evaluate import CostModelEvaluator, gemm_tile_for
    graph = graph if graph is not None else tpu_v5e(1)
    try:
        prog, sel = gemm_selection(m, n, k)
        model = store.model_for(prog, graph)
    except CACHE_ERRORS:
        return None
    if model is None:
        return None
    guard = CostModelEvaluator(sel, graph)
    space = SearchSpace.for_graph(graph)
    base = space.baseline()
    tile_axes = [a for a in space.axes if a.name.startswith("tile_")]
    pool = [dict(base)]
    for values in itertools.product(*(a.choices for a in tile_axes)):
        pool.append({**base, **dict(zip((a.name for a in tile_axes),
                                        values))})
    pool += [dict(a) for a in model.meta.get("anchors", [])]
    from .space import ParamApproach
    configs = [c for c in pool
               if guard.estimated_tiles(ParamApproach(c)) <= guard.max_tiles]
    if not configs:
        return None
    pred = model.predictor(prog, graph)
    scores = pred.predict_many(configs)
    order = np.argsort(np.asarray(scores), kind="stable")
    best = configs[int(order[0])]
    return gemm_tile_for(best, graph, m, n, k)


# --------------------------------------------------------------------------- #
# Train / eval drivers (shared by the CLI and the nightly lane)
# --------------------------------------------------------------------------- #


def _suite_cases(suites: str):
    from .tune import build_cases
    cases = []
    for s in suites.split(","):
        s = s.strip()
        if s:
            cases += build_cases("all" if s == "all" else s)
    return cases


def train_suites(suites: str, graph: SystemGraph, cache: TuningCache,
                 store: ModelStore, samples_per_case: int = 48,
                 alpha: float = 1.0, holdout: float = 0.25, seed: int = 0,
                 backend: str = "cost") -> list[dict]:
    """Harvest (cache + fresh) -> group by family -> fit -> store.  Returns
    one metrics row per family; untrainable families report why."""
    cases = _suite_cases(suites)
    samples = harvest_cache(cache, cases, graph, backend)
    winners: dict[str, list[dict]] = {}
    for s in samples:
        if s.config and s.source == "cache":
            winners.setdefault(s.case, []).append(s.config)
    for i, case in enumerate(cases):
        samples += fresh_labels(case, graph, n=samples_per_case,
                                seed=seed + i,
                                anchors=winners.get(case.name))
    by_family: dict[str, list[Sample]] = {}
    for s in samples:
        by_family.setdefault(program_family(s.program), []).append(s)
    rows = []
    for family in sorted(by_family):
        key = model_key(family, graph, backend)
        model, metrics = train_family(key, family, by_family[family], graph,
                                      alpha=alpha, holdout=holdout, seed=seed)
        if model is not None:
            store.store(model, save=False)
        rows.append(metrics)
    store.save()
    return rows


def topk_regret(model: CostModel, case, graph: SystemGraph,
                samples: int = 32, topk: int = 8, seed: int = 1) -> dict:
    """Ranking quality on *held-out* labels: evaluate ``samples`` candidate
    configs with the real cost backend, rank them by model prediction, and
    report ``regret@k`` = (best true cost within the predicted top-k) /
    (best true cost overall).  1.0 means the model's top-k contains the true
    winner — exactly the property surrogate-guided search relies on.

    The candidates are seeded-random only (no baseline-neighborhood block —
    training always labels that block, so including it would score the
    model on its own training points) under a seed offset far from the
    per-case training seeds; residual overlap is down to random collision."""
    labeled = fresh_labels(case, graph, n=samples,
                           seed=seed * 7919 + 104_729,
                           baseline_pool=False)
    if len(labeled) < 2:
        # Not enough feasible labels to rank anything; regret is
        # unmeasurable (None keeps the JSON report strict-parseable).
        return {"case": case.name, "regret_at_k": None,
                "n_labeled": len(labeled)}
    pred = model.predictor(case.program, graph, role_extents(case.selection))
    scores = pred.predict_many([s.config for s in labeled])
    true = np.array([s.cost for s in labeled])
    k = min(topk, len(labeled))
    top = np.argsort(scores, kind="stable")[:k]
    best_all = float(true.min())
    best_topk = float(true[top].min())
    mae = float(np.mean(np.abs(np.log(scores) - np.log(true))))
    return {"case": case.name, "n_labeled": len(labeled), "topk": k,
            "best_true": best_all, "best_in_topk": best_topk,
            "regret_at_k": best_topk / best_all if best_all > 0 else 1.0,
            "mae_log": mae}


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #


def _add_common(ap):
    ap.add_argument("--store", default=None,
                    help=f"model store path (default {default_store_path()})")
    ap.add_argument("--graph", choices=["v5e", "paper"], default="v5e")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write the report here")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.search.model",
        description="Train / evaluate / export the learned cost model.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    tr = sub.add_parser("train", help="harvest cache + fresh labels, fit, "
                                      "store per-family ridge models")
    tr.add_argument("--suite", default="gemm,conv",
                    help="comma list of gemm/gru/conv, or 'all'")
    tr.add_argument("--cache", default=None,
                    help="tuning cache to harvest (default: the repro.search "
                         "default cache)")
    tr.add_argument("--samples", type=int, default=48,
                    help="fresh CostModelEvaluator labels per case")
    tr.add_argument("--alpha", type=float, default=1.0)
    tr.add_argument("--holdout", type=float, default=0.25)
    _add_common(tr)

    ev = sub.add_parser("eval", help="holdout-style ranking eval: "
                                     "MAE + top-k regret vs the cost backend")
    ev.add_argument("--suite", default="gemm")
    ev.add_argument("--samples", type=int, default=32)
    ev.add_argument("--topk", type=int, default=8)
    _add_common(ev)

    ex = sub.add_parser("export", help="list stored models, or export one "
                                       "as a standalone JSON artifact")
    ex.add_argument("--key", default=None)
    ex.add_argument("--out", default=None)
    _add_common(ex)

    args = ap.parse_args(argv)
    from .tune import make_graph
    graph = make_graph(args.graph)
    store = ModelStore(args.store)

    if args.cmd == "train":
        cache = TuningCache(args.cache)
        rows = train_suites(args.suite, graph, cache, store,
                            samples_per_case=args.samples, alpha=args.alpha,
                            holdout=args.holdout, seed=args.seed)
        trained = [r for r in rows if r.get("trained")]
        for r in rows:
            if r.get("trained"):
                mae = r.get("holdout_mae_log", r.get("train_mae_log"))
                print(f"[ok] {r['family']}: {r['n_samples']} samples "
                      f"(cache={r['sources'].get('cache', 0)} "
                      f"fresh={r['sources'].get('fresh', 0)}), "
                      f"mae_log={mae:.4f}")
            else:
                print(f"[skip] {r['family']}: {r['reason']}")
        print(f"# wrote {len(trained)} model(s) to {store.path}")
        _write_json(args.json, {"schema": 1, "cmd": "train",
                                "store": store.path, "rows": rows})
        return 0 if trained else 1

    if args.cmd == "eval":
        rows = []
        regrets = []
        for case in _suite_cases(args.suite):
            model = store.model_for(case.program, graph)
            if model is None:
                rows.append({"case": case.name, "error": "no model"})
                print(f"[skip] {case.name}: no model in {store.path}")
                continue
            r = topk_regret(model, case, graph, samples=args.samples,
                            topk=args.topk, seed=args.seed + 1)
            rows.append(r)
            if r.get("regret_at_k") is None:
                # Too few feasible labels to rank: report it, never fold an
                # unmeasured case into worst_regret (it would read as a
                # perfect score).
                print(f"[skip] {case.name}: only {r['n_labeled']} feasible "
                      "label(s), regret unmeasurable")
                continue
            regrets.append(r["regret_at_k"])
            print(f"[ok] {case.name}: regret@{r['topk']}="
                  f"{r['regret_at_k']:.3f} mae_log={r['mae_log']:.4f} "
                  f"({r['n_labeled']} labels)")
        worst = max(regrets, default=None)
        _write_json(args.json, {"schema": 1, "cmd": "eval",
                                "store": store.path, "topk": args.topk,
                                "worst_regret": worst,
                                "unmeasured": len(rows) - len(regrets),
                                "rows": rows})
        return 0 if regrets else 1

    # export
    models = store.load()
    if args.key is None:
        for key, m in sorted(models.items()):
            print(f"{key}: {len(m.names)} features, "
                  f"{m.n_samples} samples")
        _write_json(args.json, {"schema": 1, "cmd": "export",
                                "keys": sorted(models)})
        return 0 if models else 1
    m = models.get(args.key)
    if m is None:
        print(f"no model for key {args.key!r} in {store.path}",
              file=sys.stderr)
        return 2
    payload = m.to_dict()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# exported {args.key} -> {args.out}")
    else:
        json.dump(payload, sys.stdout, indent=2, sort_keys=True)
        print()
    return 0


def _write_json(path, payload) -> None:
    if path:
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# report: {path}")


if __name__ == "__main__":
    raise SystemExit(main())
