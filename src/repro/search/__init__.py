"""repro.search — joint mapping/schedule autotuning (paper Section 4).

The paper frames the compiler's combinatorial choices as a *flexible
framework that allows heuristics, cost models, and potentially machine
learning*.  This package is that framework's search driver:

  * ``space``      — ``ParamApproach``: every Approach decision point driven
                     by an explicit, enumerable config vector; program and
                     system-graph fingerprinting.
  * ``strategies`` — seeded, deterministic search strategies over the space
                     (random sampling, greedy hill-climb, evolutionary).
  * ``evaluate``   — evaluation backends: fast ``scheduler.cost_model()``
                     dry-runs and optional measured Pallas wall-clock, plus
                     executor-vs-oracle validation of winning schedules.
  * ``cache``      — persistent JSON tuning cache keyed by (program
                     fingerprint, sysgraph, backend, jax version), consulted
                     by ``repro.kernels`` and the benchmarks at run time.
  * ``tune``       — the ``python -m repro.search.tune`` CLI.
"""
from .cache import TuningCache, TuningRecord, default_cache_path, get_default_cache
from .space import ParamApproach, SearchSpace, program_fingerprint, tuning_key
from .strategies import STRATEGIES, SearchOutcome, Trial

__all__ = [
    "ParamApproach", "SearchSpace", "program_fingerprint", "tuning_key",
    "STRATEGIES", "SearchOutcome", "Trial",
    "TuningCache", "TuningRecord", "default_cache_path", "get_default_cache",
]
