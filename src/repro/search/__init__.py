"""repro.search — joint mapping/schedule autotuning (paper Section 4).

The paper frames the compiler's combinatorial choices as a *flexible
framework that allows heuristics, cost models, and potentially machine
learning*.  This package is that framework's search driver:

  * ``space``      — ``ParamApproach``: every Approach decision point driven
                     by an explicit, enumerable config vector; program and
                     system-graph fingerprinting.
  * ``strategies`` — seeded, deterministic search strategies over the space
                     (random sampling, greedy hill-climb, evolutionary).
  * ``evaluate``   — evaluation backends: fast ``scheduler.cost_model()``
                     dry-runs and optional measured Pallas wall-clock, plus
                     executor-vs-oracle validation of winning schedules.
  * ``cache``      — persistent JSON tuning cache keyed by (program
                     fingerprint, sysgraph, backend, jax version), consulted
                     by ``repro.kernels`` and the benchmarks at run time.
  * ``model``      — the **learned** cost model: deterministic numpy ridge
                     regression over engineered feature vectors, trained
                     from cache records + fresh cost-model labels, stored as
                     JSON artifacts keyed per (program family, sysgraph,
                     backend, jax version); drives ``surrogate`` search.
  * ``tune``       — the ``python -m repro.search.tune`` CLI.
"""
from .cache import TuningCache, TuningRecord, default_cache_path, get_default_cache
from .space import ParamApproach, SearchSpace, program_fingerprint, tuning_key
from .strategies import STRATEGIES, SearchOutcome, Trial

_MODEL_EXPORTS = ("CostModel", "ModelStore", "default_store_path",
                  "model_key")


def __getattr__(name):
    # Lazy: ``python -m repro.search.model`` must not find the submodule
    # pre-imported (runpy warns), and the cache/space fast paths shouldn't
    # pay for numpy-heavy model code they never use.
    if name in _MODEL_EXPORTS:
        from . import model
        return getattr(model, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ParamApproach", "SearchSpace", "program_fingerprint", "tuning_key",
    "STRATEGIES", "SearchOutcome", "Trial",
    "TuningCache", "TuningRecord", "default_cache_path", "get_default_cache",
    "CostModel", "ModelStore", "default_store_path", "model_key",
]
