"""Autotuner CLI — joint mapping/schedule search with a persistent cache.

    PYTHONPATH=src python -m repro.search.tune --suite gemm --trials 32 \\
        --backend cost [--strategy hillclimb] [--cache PATH] [--json PATH]

Suites (the paper's evaluation set, Section 6):

  * ``gemm``   — the DeepBench GEMM shapes of Figure 3,
  * ``gru``    — the GRU cell (Figure 4 sizes),
  * ``conv``   — conv→matmul extraction cases (``core/kernels_ir.py`` convs
                 through the ``fuse_axes_for_calls`` ISAM-TVM path),
  * ``fabric`` — distributed GEMMs on a multi-chip fabric (``--chips`` /
                 ``--topology``): tunes (partition axis, collective
                 algorithm, per-chip tiles) *jointly* against the
                 ``repro.fabric`` event-driven simulator, anchored to the
                 untuned multi-chip baseline (axis=m, ring, greedy tiles),
  * ``all``    — every single-chip suite (fabric stays explicit).

For every case the tuner (1) maps + selects instructions once, (2) searches
the ParamApproach config space with the chosen strategy — the greedy-
equivalent baseline is always trial 0, so the reported best can only match
or beat ``GreedyApproach`` — (3) replays the winning schedule through
``core.executor`` against the ``ir.interpret`` oracle on a capped-size proxy
of the same program (full DeepBench shapes do not fit a NumPy oracle), and
(4) stores the winner in the persistent cache, where ``kernels/gemm.py`` and
the benchmarks pick it up at run time.

Exit status: 0 iff every case tuned (cost <= greedy) and validated.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from dataclasses import dataclass, field

from ..compile import conv_selection, gemm_selection, gru_selection
from ..core.ir import Program
from ..core.isel import Selection
from ..core.sysgraph import (SystemGraph, gpu_sm, paper_accelerator,
                             tpu_v5e)
from .cache import TuningCache, TuningRecord, default_cache_path
from .evaluate import (CostModelEvaluator, LearnedEvaluator,
                       MeasuredGemmEvaluator, ValidationReport, gemm_tile_for,
                       validate_selection)
from .space import ParamApproach, SearchSpace, tuning_key
from .strategies import STRATEGIES, SearchOutcome

# DeepBench train/inference GEMM shapes (paper Figure 3): a library-friendly
# head and the awkward odd/skinny tail.  bench_tuned.py reuses this list.
DEEPBENCH_GEMM_SIZES = [
    (1024, 128, 1024),
    (2048, 64, 2048),
    (1760, 128, 1760),
    (2560, 64, 2560),
    (5124, 700, 2048),
    (3072, 128, 1024),
    (35, 700, 2048),
    (7680, 1, 2560),
]

# DeepBench RNN sizes (batch, hidden), input = hidden (paper Figure 4).
GRU_SIZES = [(16, 256), (32, 512)]

# Fabric-suite shapes: one large library-friendly GEMM and one awkward one
# (the strong-scaling pair bench_fabric.py also sweeps).
FABRIC_GEMM_SIZES = [(5124, 700, 2048), (1760, 128, 1760)]

# conv→matmul extraction cases: (name, conv2d kwargs).  Small enough that
# per-trial rescheduling stays cheap; the mapping structure (im2col-style
# axis fusion onto mxu.matmul) is identical to the ResNet suite.
CONV_CASES = [
    ("conv3x3", dict(batch=4, h=14, w=14, kh=3, kw=3, cin=32, cout=64)),
    ("conv1x1", dict(batch=4, h=28, w=28, kh=1, kw=1, cin=64, cout=64)),
]

#: Validation proxies cap each axis so the NumPy oracle stays tractable.
VALIDATE_DIM_CAP = 192


@dataclass
class TuneCase:
    """One tunable workload: full-size program for costing + a small proxy
    for oracle validation (same mapping structure, capped extents)."""

    name: str
    program: Program                  # full-size (possibly transformed)
    selection: Selection
    original: Program                 # pre-transform program (oracle input)
    proxy_original: Program
    proxy_selection: Selection
    gemm_shape: tuple[int, int, int] | None = None


def _gemm_case(m: int, n: int, k: int) -> TuneCase:
    prog, sel = gemm_selection(m, n, k)
    proxy, psel = gemm_selection(min(m, VALIDATE_DIM_CAP),
                                 min(n, VALIDATE_DIM_CAP),
                                 min(k, VALIDATE_DIM_CAP))
    return TuneCase(f"gemm_{m}x{n}x{k}", prog, sel, prog, proxy, psel,
                    gemm_shape=(m, n, k))


def _gru_case(batch: int, hidden: int) -> TuneCase:
    prog, sel = gru_selection(batch, hidden)
    proxy, psel = gru_selection(min(batch, 4), min(hidden, 16))
    return TuneCase(f"gru_{batch}x{hidden}", prog, sel, prog, proxy, psel)


def _conv_case(name: str, kw: dict) -> TuneCase:
    orig, sel = conv_selection(**kw)
    pkw = dict(kw, batch=min(kw["batch"], 2), h=min(kw["h"], 6),
               w=min(kw["w"], 6), cin=min(kw["cin"], 8),
               cout=min(kw["cout"], 8))
    porig, psel = conv_selection(**pkw)
    return TuneCase(f"{name}_{kw['batch']}x{kw['h']}x{kw['w']}"
                    f"x{kw['cin']}x{kw['cout']}",
                    sel.program, sel, orig, porig, psel)


def build_cases(suite: str, limit: int | None = None) -> list[TuneCase]:
    cases: list[TuneCase] = []
    if suite in ("gemm", "all"):
        cases += [_gemm_case(*s) for s in DEEPBENCH_GEMM_SIZES]
    if suite in ("gru", "all"):
        cases += [_gru_case(*s) for s in GRU_SIZES]
    if suite in ("conv", "all"):
        cases += [_conv_case(n, kw) for n, kw in CONV_CASES]
    return cases[:limit] if limit else cases


#: ``--graph`` / ``--target`` vocabulary of the tuner (the historical
#: ``v5e``/``paper`` spellings plus the canonical target names).
GRAPH_NAMES = ("v5e", "tpu_v5e", "gpu", "gpu_sm", "paper")


def make_graph(name: str) -> SystemGraph:
    if name == "paper":
        return paper_accelerator(2)
    if name in ("gpu", "gpu_sm"):
        return gpu_sm(8)
    return tpu_v5e(1)


@dataclass
class CaseReport:
    name: str
    key: str
    backend: str                # effective backend ('measure' downgrades to
    greedy_cost: float          # 'cost' for cases without a measured kernel)
    tuned_cost: float
    outcome: SearchOutcome
    validation: ValidationReport | None
    elapsed_s: float
    config: dict = field(default_factory=dict)
    counters: dict = field(default_factory=dict)  # evaluator EvalStats + rates

    @property
    def ok(self) -> bool:
        if self.tuned_cost > self.greedy_cost:
            return False
        return self.validation is None or self.validation.ok

    def row(self) -> dict:
        return {
            "case": self.name, "key": self.key,
            "greedy_cost_s": self.greedy_cost,
            "tuned_cost_s": self.tuned_cost,
            "speedup": (self.greedy_cost / self.tuned_cost
                        if self.tuned_cost else 1.0),
            "trials": self.outcome.evaluations,
            "strategy": self.outcome.strategy,
            "config": self.config,
            "validated": None if self.validation is None
            else self.validation.ok,
            "exact": None if self.validation is None
            else self.validation.exact,
            "max_abs_err": None if self.validation is None
            else self.validation.max_abs_err,
            "elapsed_s": round(self.elapsed_s, 3),
            "counters": self.counters,
        }


def tune_case(case: TuneCase, graph: SystemGraph, strategy: str,
              trials: int, seed: int, backend: str,
              validate: bool = True, model_store=None,
              strategy_explicit: bool = True) -> CaseReport:
    t0 = time.time()
    space = SearchSpace.for_graph(graph)
    cost_eval = CostModelEvaluator(case.selection, graph)
    predict = None
    if backend == "learned":
        # The learned backend is surrogate-guided search: a trained model
        # ranks the pool, the *cost* backend settles the real trials — so
        # records land under 'cost' (one scale, and the kernels' lookup
        # finds them).  No model for this family => plain cost backend.
        learned = LearnedEvaluator.for_selection(case.selection, graph,
                                                store=model_store)
        backend = "cost"
        if learned is not None:
            predict = learned     # guarded: infeasible configs rank last
            if strategy_explicit and strategy != "surrogate":
                print(f"# {case.name}: --backend learned runs the "
                      f"surrogate strategy (--strategy {strategy} ignored)",
                      file=sys.stderr)
        else:
            print(f"# {case.name}: no trained model for this program "
                  "family; falling back to the cost backend "
                  "(train one: python -m repro.search.model train)",
                  file=sys.stderr)
    if backend == "measure" and case.gemm_shape is not None:
        m, n, k = case.gemm_shape
        evaluate = MeasuredGemmEvaluator(m, n, k, graph)
    else:
        backend = "cost"
        evaluate = cost_eval

    if predict is not None:
        outcome = STRATEGIES["surrogate"](space, evaluate, trials=trials,
                                          seed=seed, predict=predict,
                                          seeds=learned.anchors)
    else:
        outcome = STRATEGIES[strategy](space, evaluate, trials=trials,
                                       seed=seed)
    if evaluate is not cost_eval and not math.isfinite(outcome.best_cost):
        # No candidate measured successfully (kernel errors / OOM): a
        # "measure" record would be meaningless yet preferred by
        # lookup_gemm, so fall back to the cost backend outright.
        print(f"# {case.name}: measured backend produced no finite "
              "result; falling back to cost model", file=sys.stderr)
        backend = "cost"
        evaluate = cost_eval
        outcome = STRATEGIES[strategy](space, evaluate, trials=trials,
                                       seed=seed)

    # Modeled costs for the report are always cost-model numbers so the
    # tuned <= greedy contract is judged on one scale.
    greedy_cost = (outcome.baseline_cost if evaluate is cost_eval
                   else cost_eval(space.baseline()))
    tuned_cost = (outcome.best_cost if evaluate is cost_eval
                  else cost_eval(outcome.best_config))
    if tuned_cost > greedy_cost:      # measured winner may model worse
        outcome.best_config = space.baseline()
        tuned_cost = greedy_cost

    validation = None
    if validate:
        validation = validate_selection(
            case.proxy_original, case.proxy_selection, graph,
            ParamApproach(outcome.best_config), rng_seed=seed)

    key = tuning_key(case.program, graph, backend)
    return CaseReport(name=case.name, key=key, backend=backend,
                      greedy_cost=greedy_cost, tuned_cost=tuned_cost,
                      outcome=outcome, validation=validation,
                      elapsed_s=time.time() - t0,
                      config=dict(outcome.best_config),
                      counters=_case_counters(cost_eval, predict))


def _case_counters(cost_eval: CostModelEvaluator, predict=None) -> dict:
    """Per-case throughput counters for ``--json`` rows: the cost
    evaluator's ``EvalStats`` (evals, guard rejects, schedule-key memo hits,
    fresh vs incremental schedules, schedule/predict wall split) plus the
    surrogate predictor's prediction time when one ranked the pool, and the
    resulting configs/sec over the evaluator's own wall time."""
    counters = cost_eval.stats.as_dict()
    if predict is not None and getattr(predict, "stats", None) is not None \
            and predict.stats is not cost_eval.stats:
        counters["evals"] += predict.stats.evals
        counters["guard_rejects"] += predict.stats.guard_rejects
        counters["predict_s"] = round(
            counters["predict_s"] + predict.stats.predict_s, 6)
    wall = counters["schedule_s"] + counters["predict_s"]
    counters["configs_per_sec"] = (round(counters["evals"] / wall, 1)
                                   if wall > 0 else 0.0)
    return counters


def tune_fabric_case(m: int, n: int, k: int, topo, strategy: str,
                     trials: int, seed: int,
                     validate: bool = True) -> CaseReport:
    """Joint distributed tuning of one GEMM shape on one fabric: the config
    vector spans (partition axis, collective algorithm, per-chip tiles) and
    candidates are scored by the ``repro.fabric`` simulator's distributed
    makespan.  Trial 0 is the untuned multi-chip baseline, so the tuned
    config is <= the untuned fabric default by construction."""
    from ..core.kernels_ir import matmul
    from ..fabric.partition import partition_gemm, replay_bitexact
    from ..fabric.simulate import VALIDATE_DIM_CAP as FAB_CAP
    from ..fabric.simulate import FabricEvaluator
    from ..fabric.topology import Topology

    t0 = time.time()
    space = SearchSpace.for_fabric("gemm")
    evaluate = FabricEvaluator("gemm", (m, n, k), topo)
    outcome = STRATEGIES[strategy](space, evaluate, trials=trials, seed=seed)

    validation = None
    if validate:
        pm, pn, pk = (max(topo.n_chips, min(d, FAB_CAP)) for d in (m, n, k))
        axis = outcome.best_config.get("part_axis", "m")
        proxy = partition_gemm(pm, pn, pk, axis, topo.n_chips)
        validation = replay_bitexact(proxy, Topology.chip_graph(),
                                     ParamApproach(outcome.best_config),
                                     rng_seed=seed)

    key = tuning_key(matmul(m, n, k), topo.build_graph(), "fabric")
    return CaseReport(name=f"fabric_gemm_{m}x{n}x{k}_{topo.name}", key=key,
                      backend="fabric",
                      greedy_cost=outcome.baseline_cost,
                      tuned_cost=outcome.best_cost,
                      outcome=outcome, validation=validation,
                      elapsed_s=time.time() - t0,
                      config=dict(outcome.best_config))


def fabric_record_for(report: CaseReport, topo, strategy: str) -> TuningRecord:
    return TuningRecord(
        key=report.key, config=report.config, cost=report.tuned_cost,
        baseline_cost=report.greedy_cost, backend="fabric",
        strategy=strategy, trials=report.outcome.evaluations,
        meta={"case": report.name, "topology": topo.name,
              "chips": topo.n_chips,
              "speedup": round(report.greedy_cost
                               / max(report.tuned_cost, 1e-30), 4)})


def record_for(case: TuneCase, report: CaseReport, graph: SystemGraph,
               strategy: str) -> TuningRecord:
    tile = None
    if case.gemm_shape is not None:
        tile = gemm_tile_for(report.config, graph, *case.gemm_shape)
    return TuningRecord(
        key=report.key, config=report.config, cost=report.tuned_cost,
        baseline_cost=report.greedy_cost, backend=report.backend,
        strategy=strategy,
        trials=report.outcome.evaluations, tile=tile,
        meta={"case": case.name, "graph": graph.name,
              "speedup": round(report.greedy_cost
                               / max(report.tuned_cost, 1e-30), 4)})


def _tune_worker(payload: dict) -> tuple[int, CaseReport]:
    """One ``--workers`` subprocess unit: rebuild the case from the suite
    descriptor (programs/selections are cheap to rebuild and the descriptor
    is trivially picklable, unlike a live Selection closure) and tune it.
    Returns ``(case index, report)`` so the parent merges reports — and
    cache records — in deterministic case order regardless of which worker
    finishes first."""
    idx = payload["idx"]
    if payload["suite"] == "fabric":
        from ..fabric.topology import make_topology
        topo = make_topology(payload["topology"], payload["chips"])
        m, n, k = payload["shape"]
        return idx, tune_fabric_case(m, n, k, topo, payload["strategy"],
                                     payload["trials"], payload["seed"],
                                     validate=payload["validate"])
    case = build_cases(payload["suite"], payload["limit"])[idx]
    model_store = None
    if payload["backend"] == "learned":
        from .model import ModelStore
        model_store = ModelStore(payload["model"])
    return idx, tune_case(case, make_graph(payload["graph"]),
                          payload["strategy"], payload["trials"],
                          payload["seed"], payload["backend"],
                          validate=payload["validate"],
                          model_store=model_store,
                          strategy_explicit=payload["strategy_explicit"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.search.tune",
        description="Joint mapping/schedule autotuner with persistent cache.")
    ap.add_argument("--suite",
                    choices=["gemm", "gru", "conv", "fabric", "all"],
                    default="gemm")
    ap.add_argument("--chips", type=int, default=4,
                    help="fabric suite: number of chips")
    ap.add_argument("--topology", choices=["ring", "torus", "host"],
                    default="ring", help="fabric suite: fabric shape")
    ap.add_argument("--trials", type=int, default=32)
    ap.add_argument("--strategy", choices=sorted(STRATEGIES), default=None,
                    help="search strategy (default hillclimb; --backend "
                         "learned always runs 'surrogate')")
    ap.add_argument("--backend", choices=["cost", "measure", "learned"],
                    default="cost",
                    help="'measure' times the Pallas GEMM (TPU-meaningful; "
                         "falls back to 'cost' for non-GEMM cases); "
                         "'learned' runs surrogate-guided search — a "
                         "trained repro.search.model ranks the pool, the "
                         "cost model settles the real trials (falls back "
                         "to 'cost' when no model is trained)")
    ap.add_argument("--model", default=None, metavar="PATH",
                    help="model store for --backend learned (default: the "
                         "repro.search.model default store)")
    ap.add_argument("--graph", choices=list(GRAPH_NAMES), default=None,
                    help="historical spelling of --target (v5e/paper)")
    ap.add_argument("--target", choices=list(GRAPH_NAMES), default=None,
                    help="modeled hardware target to tune against "
                         "(default tpu_v5e); per-target caches never "
                         "collide — keys embed the sysgraph fingerprint")
    ap.add_argument("--cache", default=None,
                    help=f"cache path (default {default_cache_path()})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1,
                    help="tune cases in N parallel processes; per-case "
                         "results are bit-identical to --workers 1 and "
                         "reports/cache records merge in deterministic "
                         "case order")
    ap.add_argument("--limit", type=int, default=None,
                    help="tune only the first N cases of the suite")
    ap.add_argument("--no-validate", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)
    # The resolved strategy (what the header/meta report): learned-backend
    # runs are surrogate-guided unless the user forced something else —
    # and then tune_case warns that the flag is ignored.
    strategy = args.strategy or ("surrogate" if args.backend == "learned"
                                 else "hillclimb")

    if args.target and args.graph and args.target != args.graph:
        print(f"--target {args.target} and --graph {args.graph} disagree; "
              "pass one of them", file=sys.stderr)
        return 2
    target = args.target or args.graph or "v5e"
    args.graph = target          # worker payloads carry the resolved name
    graph = make_graph(target)
    cache = TuningCache(args.cache)
    reports: list[CaseReport] = []
    failures = 0

    if args.suite == "fabric":
        if args.backend == "learned":
            # No fabric-family models yet (the feature schema has no
            # part_axis/collective terms — ROADMAP follow-up); silently
            # running the default path would misreport what was tuned.
            print("--backend learned is not supported for --suite fabric "
                  "(train targets single-chip program families); use "
                  "--backend cost", file=sys.stderr)
            return 2
        from ..fabric.topology import make_topology
        topo = make_topology(args.topology, args.chips)
        shapes = FABRIC_GEMM_SIZES[:args.limit] if args.limit \
            else FABRIC_GEMM_SIZES
        print(f"# tuning {len(shapes)} fabric case(s): chips={args.chips} "
              f"topology={topo.name} strategy={strategy} "
              f"trials={args.trials}")
        print(f"# cache: {cache.path}")
        runs = [(f"fabric_gemm_{m}x{n}x{k}_{topo.name}",
                 lambda m=m, n=n, k=k: tune_fabric_case(
                     m, n, k, topo, strategy, args.trials, args.seed,
                     validate=not args.no_validate))
                for m, n, k in shapes]
        payloads = [{"idx": i, "suite": "fabric", "shape": shapes[i],
                     "topology": args.topology, "chips": args.chips,
                     "strategy": strategy, "trials": args.trials,
                     "seed": args.seed, "validate": not args.no_validate}
                    for i in range(len(shapes))]
        recorder = lambda rep: fabric_record_for(  # noqa: E731
            rep, topo, rep.outcome.strategy)
    else:
        cases = build_cases(args.suite, args.limit)
        if not cases:
            print("no cases selected", file=sys.stderr)
            return 2
        print(f"# tuning {len(cases)} case(s): suite={args.suite} "
              f"strategy={strategy} trials={args.trials} "
              f"backend={args.backend} graph={graph.name}")
        print(f"# cache: {cache.path}")
        model_store = None
        if args.backend == "learned":
            from .model import ModelStore
            model_store = ModelStore(args.model)
        by_name = {}
        runs = []
        for case in cases:
            by_name[case.name] = case
            runs.append((case.name,
                         lambda case=case: tune_case(
                             case, graph, strategy, args.trials,
                             args.seed, args.backend,
                             validate=not args.no_validate,
                             model_store=model_store,
                             strategy_explicit=args.strategy is not None)))
        payloads = [{"idx": i, "suite": args.suite, "limit": args.limit,
                     "graph": args.graph, "strategy": strategy,
                     "trials": args.trials, "seed": args.seed,
                     "backend": args.backend, "model": args.model,
                     "validate": not args.no_validate,
                     "strategy_explicit": args.strategy is not None}
                    for i in range(len(cases))]
        # Provenance from the outcome, not the CLI flag: --backend
        # learned swaps the strategy to 'surrogate' per case.
        recorder = lambda rep: record_for(  # noqa: E731
            by_name[rep.name], rep, graph, rep.outcome.strategy)

    def emit(rep: CaseReport) -> None:
        reports.append(rep)
        cache.store(recorder(rep), save=False)
        v = rep.validation
        vtxt = ("-" if v is None else
                ("exact" if v.exact else f"err={v.max_abs_err:.2e}"))
        status = "ok" if rep.ok else "FAIL"
        print(f"[{status}] {rep.name}: greedy={rep.greedy_cost:.3e}s "
              f"tuned={rep.tuned_cost:.3e}s "
              f"speedup={rep.greedy_cost / max(rep.tuned_cost, 1e-30):.2f}x "
              f"oracle={vtxt} ({rep.outcome.evaluations} trials, "
              f"{rep.elapsed_s:.1f}s)", flush=True)

    if args.workers > 1:
        # Fan cases across processes; collect by index so reports and cache
        # records land in the same order a sequential run produces (the
        # cache file diffs empty against --workers 1).
        from concurrent.futures import ProcessPoolExecutor
        print(f"# workers: {args.workers}")
        with ProcessPoolExecutor(max_workers=args.workers) as ex:
            done = dict(ex.map(_tune_worker, payloads))
        for i in range(len(payloads)):
            emit(done[i])
    else:
        for _name, run in runs:
            emit(run())
    failures = sum(1 for r in reports if not r.ok)
    cache.save()
    print(f"# wrote {len(reports)} record(s) to {cache.path}")

    if args.json:
        meta = {"schema": 1, "suite": args.suite,
                "strategy": strategy, "trials": args.trials,
                "backend": args.backend, "graph": graph.name,
                "cache": cache.path, "failures": failures}
        if args.suite == "fabric":
            meta["chips"] = args.chips
            meta["topology"] = args.topology
        with open(args.json, "w") as f:
            json.dump({**meta, "rows": [r.row() for r in reports]}, f,
                      indent=2)
        print(f"# report: {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
