"""Search strategies over the Approach config space (paper Section 4).

Three drivers with one shared contract: ``strategy(space, evaluate, trials,
seed) -> SearchOutcome`` where ``evaluate(config) -> cost`` (lower is
better, ``inf`` = infeasible).  All strategies

  * are **deterministic** under a fixed seed (a private ``random.Random``),
  * evaluate the space's greedy-equivalent **baseline first**, so the
    reported best is never worse than ``GreedyApproach``,
  * dedupe configs, so a trial budget is a budget of *distinct* evaluations.

Ties are broken toward the earliest-evaluated config, i.e. toward the
baseline — search only moves off the paper's heuristics when a candidate is
strictly better.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable

from .space import Config, SearchSpace, config_key

Evaluator = Callable[[Config], float]


@dataclass(frozen=True)
class Trial:
    """One evaluated point."""

    index: int
    config: Config
    cost: float


@dataclass
class SearchOutcome:
    strategy: str
    best_config: Config
    best_cost: float
    baseline_cost: float
    trials: list[Trial] = field(default_factory=list)

    @property
    def evaluations(self) -> int:
        return len(self.trials)

    @property
    def speedup(self) -> float:
        """Modeled baseline/tuned ratio (>= 1.0 by construction)."""
        if self.best_cost <= 0:
            return 1.0
        return self.baseline_cost / self.best_cost


class _Scorer:
    """Batched scoring front-end.

    When the evaluator exposes ``evaluate_many`` (``CostModelEvaluator``),
    populations go through it in one call — vectorized guard, schedule-key
    memoization, incremental re-scheduling — and the per-config scores land
    in a local cache the scalar path reads back.  Scores are identical to
    calling ``evaluate(config)`` directly (the batch tier's contract), so
    strategies that prefetch stay bit-identical to the sequential path.
    """

    def __init__(self, evaluate: Evaluator):
        self.evaluate = evaluate
        self.many = getattr(evaluate, "evaluate_many", None)
        self.cache: dict[tuple, float] = {}

    def prefetch(self, configs: list[Config]) -> None:
        """Score a population ahead of the runner's walk (no-op for scalar
        evaluators — nothing would be saved by batching them)."""
        if self.many is None:
            return
        todo, seen = [], set()
        for c in configs:
            k = config_key(c)
            if k not in self.cache and k not in seen:
                seen.add(k)
                todo.append(c)
        if todo:
            for c, s in zip(todo, self.many(todo)):
                self.cache[config_key(c)] = float(s)

    def __call__(self, config: Config) -> float:
        k = config_key(config)
        got = self.cache.get(k)
        if got is None:
            got = float(self.many([config])[0] if self.many is not None
                        else self.evaluate(config))
            self.cache[k] = got
        return got


class _Runner:
    """Shared bookkeeping: dedup, trial log, best tracking."""

    def __init__(self, space: SearchSpace, evaluate: Evaluator, trials: int):
        self.space = space
        self.evaluate = evaluate
        self.scorer = _Scorer(evaluate)
        self.budget = max(1, trials)
        self.seen: set[tuple] = set()
        self.trials: list[Trial] = []
        self.best: Trial | None = None

    @property
    def exhausted(self) -> bool:
        return len(self.trials) >= self.budget

    def prefetch(self, configs: list[Config]) -> None:
        self.scorer.prefetch(configs)

    def run(self, config: Config) -> Trial | None:
        """Evaluate ``config`` unless duplicate / over budget."""
        key = config_key(config)
        if key in self.seen or self.exhausted:
            return None
        self.seen.add(key)
        cost = self.scorer(config)
        t = Trial(len(self.trials), dict(config), cost)
        self.trials.append(t)
        if self.best is None or cost < self.best.cost:
            self.best = t
        return t

    def outcome(self, strategy: str) -> SearchOutcome:
        baseline = self.trials[0].cost if self.trials else float("inf")
        assert self.best is not None
        return SearchOutcome(strategy=strategy,
                             best_config=dict(self.best.config),
                             best_cost=self.best.cost,
                             baseline_cost=baseline,
                             trials=list(self.trials))


def random_search(space: SearchSpace, evaluate: Evaluator,
                  trials: int = 32, seed: int = 0) -> SearchOutcome:
    """Baseline + uniform random sampling of distinct configs.

    The candidate stream and the accept/reject decisions are both
    cost-independent (the loop stops on budget / attempt count / dedupe
    only), so the exact consumed prefix is simulated up front and scored as
    one population; the runner walk below replays the sequential loop's
    decisions bit-identically."""
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    base = space.baseline()
    sim_seen = {config_key(base)}
    n_trials, attempts, consumed = 1, 0, []
    while n_trials < r.budget and attempts < trials * 50:
        attempts += 1
        c = space.random_config(rng)
        consumed.append(c)
        k = config_key(c)
        if k not in sim_seen:
            sim_seen.add(k)
            n_trials += 1
    r.prefetch([base] + consumed)
    r.run(base)
    for c in consumed:
        r.run(c)
    return r.outcome("random")


def hill_climb(space: SearchSpace, evaluate: Evaluator,
               trials: int = 32, seed: int = 0) -> SearchOutcome:
    """Greedy first-improvement hill-climb from the baseline.

    The incumbent's single-mutation neighborhood is walked in the space's
    deterministic order; the first strictly better neighbor becomes the new
    incumbent (restarting the walk there).  A fully explored neighborhood
    with no improvement is a local optimum — the climb then restarts from a
    random config (the incumbent is global, so restarts can only help).
    The seed only influences restart points, so small budgets behave
    identically across seeds until the first local optimum.  The outcome's
    best is global across all restarts (the runner tracks it), while the
    climb itself descends from wherever it restarted."""
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    current = r.run(space.baseline())

    def recenter(config: Config):
        """Materialize + batch-score the incumbent's neighborhood (which
        neighbors actually *run* still depends on the walk, but scoring the
        frontier as one population is what the throughput tier is for)."""
        neigh = list(space.neighbors(config))
        r.prefetch(neigh)
        return iter(neigh)

    frontier = recenter(current.config)
    attempts = 0
    while not r.exhausted and attempts < trials * 50:
        attempts += 1
        cand = next(frontier, None)
        if cand is None:               # local optimum: random restart
            restart = r.run(space.random_config(rng))
            if restart is not None:
                current = restart
                frontier = recenter(current.config)
            continue
        t = r.run(cand)
        if t is not None and t.cost < current.cost:
            current = t
            frontier = recenter(current.config)
    return r.outcome("hillclimb")


def evolutionary(space: SearchSpace, evaluate: Evaluator,
                 trials: int = 32, seed: int = 0,
                 population: int = 8, elite: int = 3) -> SearchOutcome:
    """(mu + lambda)-style beam/evolutionary search.

    Generation 0 is the baseline plus random configs; each later generation
    keeps the ``elite`` best evaluated so far as parents and fills the
    population with crossovers + mutations of the parents.

    Each generation is drawn in full before any of it is scored: within a
    generation the parents are fixed and a child's accept/reject depends
    only on dedupe (never on its cost), so the rng stream and the accepted
    set are simulated exactly, the batch goes through the evaluator as one
    population, and the runner replays the sequential decisions
    bit-identically.
    """
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    base = space.baseline()
    gen0, sim_seen, sim_trials = [], {config_key(base)}, 1
    for _ in range(population - 1):
        if sim_trials >= r.budget:
            break
        c = space.random_config(rng)
        gen0.append(c)
        k = config_key(c)
        if k not in sim_seen:
            sim_seen.add(k)
            sim_trials += 1
    r.prefetch([base] + gen0)
    r.run(base)
    for c in gen0:
        r.run(c)
    attempts = 0
    while not r.exhausted and attempts < trials * 50:
        parents = sorted(r.trials, key=lambda t: (t.cost, t.index))[:elite]
        sim_seen = set(r.seen)
        sim_trials = len(r.trials)
        batch, made = [], 0
        while made < population and sim_trials < r.budget \
                and attempts + len(batch) < trials * 50:
            pa, pb = rng.choice(parents), rng.choice(parents)
            child = space.crossover(pa.config, pb.config, rng)
            child = space.mutate(child, rng, n_mutations=1)
            batch.append(child)
            k = config_key(child)
            if k not in sim_seen:
                sim_seen.add(k)
                sim_trials += 1
                made += 1
        r.prefetch(batch)
        made = 0
        for child in batch:
            attempts += 1
            if r.run(child) is not None:
                made += 1
        if made == 0:       # space exhausted around the elites
            break
    return r.outcome("evolve")


#: Above this size the surrogate ranks a seeded sample instead of the full
#: enumeration (predictions are cheap, but not free).
SURROGATE_POOL_CAP = 20_000


def surrogate_search(space: SearchSpace, evaluate: Evaluator,
                     trials: int = 32, seed: int = 0,
                     predict: Callable[[Config], float] | None = None,
                     seeds: list[Config] | None = None,
                     pool: int = 4096) -> SearchOutcome:
    """Surrogate-guided search: rank a large candidate pool by a *learned*
    cost predictor (``repro.search.model``), then spend the real evaluation
    budget only on the top of the ranking.

    Budget split (all real evaluations go through the shared runner, so
    baseline-first and tuned <= greedy hold exactly as for the other
    strategies):

      1. the greedy-equivalent baseline (1 trial);
      1b. the ``seeds`` — a trained model carries the cache-winner configs
         of its program *family* as anchors (``CostModel.meta['anchors']``),
         so past winners for sibling shapes are tried first: the tuning
         cache's "remember winners" transferred across shapes.  At most
         half the budget, best-predicted first;
      2. **model-ordered local search** (~2/3 of the remaining budget):
         hill-climbing from the baseline, but each incumbent's
         single-mutation neighborhood is walked in *predicted-cost order*
         instead of the space's axis order — the same moves ``hill_climb``
         makes, reached in fewer real evaluations because the model fronts
         the promising mutations;
      3. **global probes** (the rest): the best-predicted configs of the
         whole space (enumerated when small, else a seeded sample), for
         optima the local walk cannot reach — this is where the surrogate
         pays off beyond accelerating hillclimb.

    Without a predictor there is nothing to rank, so the call degrades to
    ``hill_climb`` — the documented fallback when no model is trained.  The
    predictor may expose ``predict_many(configs)`` (the
    ``CostModel.predictor`` closure does) to score pools in one shot.
    """
    if predict is None:
        out = hill_climb(space, evaluate, trials=trials, seed=seed)
        out.strategy = "surrogate:fallback-hillclimb"
        return out

    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    r.run(space.baseline())

    # -- phase 1b: family anchors (cache winners), best-predicted first ----
    if seeds:
        sseeds = [dict(s) for s in seeds]
        s_scores = _predict_all(predict, sseeds)
        seed_budget = 1 + max(1, (trials - 1) // 2)
        r.prefetch(sseeds)
        for _, cand in sorted(zip(s_scores, sseeds), key=_rank_key):
            if len(r.trials) >= min(seed_budget, r.budget):
                break
            r.run(cand)

    # -- phase 2: model-ordered first-improvement local search -------------
    global_budget = max(1, (trials - 1) // 3)
    assert r.best is not None
    current = r.best

    def recenter(config: Config):
        frontier = _ordered_neighbors(space, predict, config, r.seen)
        r.prefetch(frontier)
        return iter(frontier)

    frontier = recenter(current.config)
    while len(r.trials) < r.budget - global_budget:
        cand = next(frontier, None)
        if cand is None:               # neighborhood exhausted: local optimum
            break
        t = r.run(cand)
        if t is not None and t.cost < current.cost:
            current = t                # first improvement: re-center
            frontier = recenter(current.config)

    # -- phase 3: global top-predicted probes ------------------------------
    if space.size() <= SURROGATE_POOL_CAP:
        candidates = list(space.enumerate_configs())
    else:                                   # pragma: no cover - huge spaces
        candidates = list(space.neighbors(space.baseline()))
        seen = {config_key(c) for c in candidates}
        while len(candidates) < pool:
            c = space.random_config(rng)
            if config_key(c) not in seen:
                seen.add(config_key(c))
                candidates.append(c)
    candidates = [c for c in candidates if config_key(c) not in r.seen]
    scores = _predict_all(predict, candidates)
    ranked = [c for _, c in sorted(zip(scores, candidates), key=_rank_key)]
    # the candidates are distinct and unseen, so exactly the remaining
    # budget's worth will run — batch-score just that prefix
    r.prefetch(ranked[:max(0, r.budget - len(r.trials))])
    for cand in ranked:
        if r.exhausted:
            break
        r.run(cand)
    return r.outcome("surrogate")


def _ordered_neighbors(space: SearchSpace, predict, config: Config,
                       seen: set) -> list[Config]:
    """The unseen single-mutation neighborhood of ``config``, best-predicted
    first (deterministic ties — see ``_rank_key``)."""
    neigh = [c for c in space.neighbors(config) if config_key(c) not in seen]
    scores = _predict_all(predict, neigh)
    return [c for _, c in sorted(zip(scores, neigh), key=_rank_key)]


def _rank_key(sc):
    """Deterministic (score, config) ordering: ties break on the config's
    canonical *string* form — config values mix None/int/str, which are not
    mutually comparable, and prediction ties do happen (policy dims a model
    learned to ignore produce identical scores)."""
    return (sc[0], repr(config_key(sc[1])))


def _predict_all(predict, configs: list[Config]) -> list[float]:
    many = getattr(predict, "predict_many", None)
    if many is not None:
        return [float(s) for s in many(configs)]
    return [float(predict(c)) for c in configs]


STRATEGIES: dict[str, Callable[..., SearchOutcome]] = {
    "random": random_search,
    "hillclimb": hill_climb,
    "evolve": evolutionary,
    "surrogate": surrogate_search,
}
