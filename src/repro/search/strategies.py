"""Search strategies over the Approach config space (paper Section 4).

Three drivers with one shared contract: ``strategy(space, evaluate, trials,
seed) -> SearchOutcome`` where ``evaluate(config) -> cost`` (lower is
better, ``inf`` = infeasible).  All strategies

  * are **deterministic** under a fixed seed (a private ``random.Random``),
  * evaluate the space's greedy-equivalent **baseline first**, so the
    reported best is never worse than ``GreedyApproach``,
  * dedupe configs, so a trial budget is a budget of *distinct* evaluations.

Ties are broken toward the earliest-evaluated config, i.e. toward the
baseline — search only moves off the paper's heuristics when a candidate is
strictly better.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable

from .space import Config, SearchSpace, config_key

Evaluator = Callable[[Config], float]


@dataclass(frozen=True)
class Trial:
    """One evaluated point."""

    index: int
    config: Config
    cost: float


@dataclass
class SearchOutcome:
    strategy: str
    best_config: Config
    best_cost: float
    baseline_cost: float
    trials: list[Trial] = field(default_factory=list)

    @property
    def evaluations(self) -> int:
        return len(self.trials)

    @property
    def speedup(self) -> float:
        """Modeled baseline/tuned ratio (>= 1.0 by construction)."""
        if self.best_cost <= 0:
            return 1.0
        return self.baseline_cost / self.best_cost


class _Runner:
    """Shared bookkeeping: dedup, trial log, best tracking."""

    def __init__(self, space: SearchSpace, evaluate: Evaluator, trials: int):
        self.space = space
        self.evaluate = evaluate
        self.budget = max(1, trials)
        self.seen: set[tuple] = set()
        self.trials: list[Trial] = []
        self.best: Trial | None = None

    @property
    def exhausted(self) -> bool:
        return len(self.trials) >= self.budget

    def run(self, config: Config) -> Trial | None:
        """Evaluate ``config`` unless duplicate / over budget."""
        key = config_key(config)
        if key in self.seen or self.exhausted:
            return None
        self.seen.add(key)
        cost = float(self.evaluate(config))
        t = Trial(len(self.trials), dict(config), cost)
        self.trials.append(t)
        if self.best is None or cost < self.best.cost:
            self.best = t
        return t

    def outcome(self, strategy: str) -> SearchOutcome:
        baseline = self.trials[0].cost if self.trials else float("inf")
        assert self.best is not None
        return SearchOutcome(strategy=strategy,
                             best_config=dict(self.best.config),
                             best_cost=self.best.cost,
                             baseline_cost=baseline,
                             trials=list(self.trials))


def random_search(space: SearchSpace, evaluate: Evaluator,
                  trials: int = 32, seed: int = 0) -> SearchOutcome:
    """Baseline + uniform random sampling of distinct configs."""
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    r.run(space.baseline())
    attempts = 0
    while not r.exhausted and attempts < trials * 50:
        attempts += 1
        r.run(space.random_config(rng))
    return r.outcome("random")


def hill_climb(space: SearchSpace, evaluate: Evaluator,
               trials: int = 32, seed: int = 0) -> SearchOutcome:
    """Greedy first-improvement hill-climb from the baseline.

    The incumbent's single-mutation neighborhood is walked in the space's
    deterministic order; the first strictly better neighbor becomes the new
    incumbent (restarting the walk there).  A fully explored neighborhood
    with no improvement is a local optimum — the climb then restarts from a
    random config (the incumbent is global, so restarts can only help).
    The seed only influences restart points, so small budgets behave
    identically across seeds until the first local optimum.  The outcome's
    best is global across all restarts (the runner tracks it), while the
    climb itself descends from wherever it restarted."""
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    current = r.run(space.baseline())
    frontier = space.neighbors(current.config)
    attempts = 0
    while not r.exhausted and attempts < trials * 50:
        attempts += 1
        cand = next(frontier, None)
        if cand is None:               # local optimum: random restart
            restart = r.run(space.random_config(rng))
            if restart is not None:
                current = restart
                frontier = space.neighbors(current.config)
            continue
        t = r.run(cand)
        if t is not None and t.cost < current.cost:
            current = t
            frontier = space.neighbors(current.config)
    return r.outcome("hillclimb")


def evolutionary(space: SearchSpace, evaluate: Evaluator,
                 trials: int = 32, seed: int = 0,
                 population: int = 8, elite: int = 3) -> SearchOutcome:
    """(mu + lambda)-style beam/evolutionary search.

    Generation 0 is the baseline plus random configs; each later generation
    keeps the ``elite`` best evaluated so far as parents and fills the
    population with crossovers + mutations of the parents."""
    rng = random.Random(seed)
    r = _Runner(space, evaluate, trials)
    r.run(space.baseline())
    for _ in range(population - 1):
        if r.exhausted:
            break
        r.run(space.random_config(rng))
    attempts = 0
    while not r.exhausted and attempts < trials * 50:
        parents = sorted(r.trials, key=lambda t: (t.cost, t.index))[:elite]
        made = 0
        while made < population and not r.exhausted and attempts < trials * 50:
            attempts += 1
            pa, pb = rng.choice(parents), rng.choice(parents)
            child = space.crossover(pa.config, pb.config, rng)
            child = space.mutate(child, rng, n_mutations=1)
            if r.run(child) is not None:
                made += 1
        if made == 0:       # space exhausted around the elites
            break
    return r.outcome("evolve")


STRATEGIES: dict[str, Callable[..., SearchOutcome]] = {
    "random": random_search,
    "hillclimb": hill_climb,
    "evolve": evolutionary,
}
