"""Fault-tolerance runtime: restartable training loop, straggler detection,
elastic re-meshing.

Designed for 1000+ node operation:

  * **Checkpoint/restart** — the loop is a pure function of (checkpoint,
    step): any crash resumes from the last committed step; the data pipeline
    is step-keyed so there is no replay drift.
  * **Straggler mitigation** — per-step wall times feed an EWMA; steps slower
    than ``threshold x EWMA`` fire a callback (in production: re-shard away
    from the slow host / alert; here: recorded + surfaced in metrics).
  * **Elastic re-meshing** — on restart the checkpoint is re-sharded onto
    whatever mesh is available (restore takes the *new* shardings).
  * **Preemption hooks** — SIGTERM triggers a final synchronous checkpoint.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

from ..checkpoint.ckpt import Checkpointer


@dataclass
class StragglerDetector:
    alpha: float = 0.2
    threshold: float = 2.0
    ewma: float = 0.0
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.slow_steps.append((step, dt, self.ewma))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


@dataclass
class RunState:
    step: int = 0
    crashed: int = 0
    resumed: int = 0
    preempted: bool = False


class TrainingRuntime:
    """Wraps a compiled step function with checkpoint/restart + monitoring."""

    def __init__(self, ckpt: Checkpointer, save_every: int = 50,
                 async_save: bool = True,
                 straggler: StragglerDetector | None = None):
        self.ckpt = ckpt
        self.save_every = save_every
        self.async_save = async_save
        self.straggler = straggler or StragglerDetector()
        self.state = RunState()
        self._stop = False

    def install_preemption_handler(self):
        def handler(signum, frame):
            self.state.preempted = True
            self._stop = True
        signal.signal(signal.SIGTERM, handler)

    # -- resume -----------------------------------------------------------------
    def try_restore(self, template, shardings=None):
        """Latest committed checkpoint -> (state_tree, step) or None."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return None
        tree, step = self.ckpt.restore(latest, template, shardings)
        self.state.step = step
        self.state.resumed += 1
        return tree, step

    # -- loop ----------------------------------------------------------------------
    def run(self, carry, step_fn, batch_fn, n_steps: int,
            on_metrics=None, inject_fault_at: int | None = None):
        """carry: (params, opt_state).  step_fn(carry, batch) -> (carry,
        metrics).  batch_fn(step) -> batch.  ``inject_fault_at`` simulates a
        crash (tests restart semantics)."""
        start = self.state.step
        for step in range(start, n_steps):
            if self._stop:
                break
            t0 = time.perf_counter()
            batch = batch_fn(step)
            carry, metrics = step_fn(carry, batch)
            dt = time.perf_counter() - t0
            slow = self.straggler.observe(step, dt)
            self.state.step = step + 1
            if on_metrics is not None:
                on_metrics(step, metrics, dt, slow)
            if inject_fault_at is not None and step + 1 == inject_fault_at:
                self.state.crashed += 1
                raise RuntimeError(f"injected fault at step {step + 1}")
            if (step + 1) % self.save_every == 0:
                self.ckpt.save(step + 1, carry, blocking=not self.async_save)
        self.ckpt.save(self.state.step, carry, blocking=True)
        return carry
