"""Layer 4 — fabric checker (``fab.*`` rules).

Validates the distributed layer statically:

  * **task graphs** — ``EventSim`` task lists (or any ``(tid, deps)``
    pairs) must have unique ids, known deps, and be acyclic; a cycle or a
    dangling dep would hang or silently drop work in a relaxation replay.
  * **collective plans** — lowered ``CollectiveStep`` lists must form
    unbroken per-(direction, chunk) chains; an all-gather must deliver
    every chunk to every chip, a reduce must fold exactly ``p - 1`` hops
    per chunk.
  * **partition contract** — shard chips are dense, shard outputs
    reassemble the global output (``concat``: extents along the output
    axis sum; ``chain_sum``: full-size partials), collective chunks tile
    the buffer extent.

Imports from ``repro.fabric`` are deferred into the functions — the fabric
package imports ``repro.compile`` which (via cached-artifact checks) imports
``repro.verify``.
"""
from __future__ import annotations

from .diagnostics import Diagnostic, diag


def _as_dep_pairs(tasks) -> list[tuple[str, tuple[str, ...]]]:
    """Accept an EventSim, its ``_Task`` list, or raw (tid, deps) pairs."""
    if hasattr(tasks, "_tasks"):            # EventSim
        tasks = tasks._tasks
    out = []
    for t in tasks:
        if isinstance(t, tuple):
            tid, deps = t[0], t[1]
        else:
            tid, deps = t.tid, t.deps
        out.append((str(tid), tuple(deps)))
    return out


def verify_task_graph(tasks) -> list[Diagnostic]:
    """Unique ids, known deps, acyclic, fully reachable (Kahn's algorithm)."""
    diags: list[Diagnostic] = []
    pairs = _as_dep_pairs(tasks)
    known: set[str] = set()
    for tid, _ in pairs:
        if tid in known:
            diags.append(diag(
                "fab.duplicate-task", f"task id {tid!r} appears more than "
                f"once", subject=tid))
        known.add(tid)

    indeg: dict[str, int] = {tid: 0 for tid, _ in pairs}
    succs: dict[str, list[str]] = {tid: [] for tid, _ in pairs}
    for tid, deps in pairs:
        for d in deps:
            if d not in known:
                diags.append(diag(
                    "fab.unknown-dep",
                    f"task {tid!r} depends on unknown task {d!r}",
                    subject=tid))
                continue
            indeg[tid] += 1
            succs[d].append(tid)

    ready = [tid for tid, n in indeg.items() if n == 0]
    seen = 0
    while ready:
        tid = ready.pop()
        seen += 1
        for s in succs[tid]:
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    if seen < len(indeg):
        stuck = sorted(tid for tid, n in indeg.items() if n > 0)
        diags.append(diag(
            "fab.cycle",
            f"{len(stuck)} task(s) unreachable behind a dependency cycle "
            f"(e.g. {stuck[:3]})", subject=stuck[0] if stuck else ""))
    return diags


def verify_collective(kind: str, steps, p: int) -> list[Diagnostic]:
    """Chain linkage + delivery coverage of a lowered collective plan."""
    diags: list[Diagnostic] = []
    if p <= 1:
        if steps:
            diags.append(diag(
                "fab.chain-broken",
                f"{kind}: {len(steps)} step(s) lowered for a 1-chip fabric",
                subject=kind))
        return diags

    chains: dict[tuple[int, int], list] = {}
    for st in steps:
        for chip in (st.src, st.dst):
            if not (0 <= chip < p):
                diags.append(diag(
                    "fab.contract",
                    f"{kind}: step {st.step} references chip {chip} outside "
                    f"[0, {p - 1}]", subject=kind, uid=st.step))
        chains.setdefault((st.direction, st.chunk), []).append(st)

    for (direction, chunk), chain in sorted(chains.items()):
        chain.sort(key=lambda s: s.step)
        for a, b in zip(chain, chain[1:]):
            if a.dst != b.src:
                diags.append(diag(
                    "fab.chain-broken",
                    f"{kind}: chunk {chunk} dir {direction} hops "
                    f"{a.src}->{a.dst} then {b.src}->{b.dst}; the chain is "
                    f"broken at step {b.step}", subject=kind, uid=b.step))
        n_reduce = sum(1 for s in chain if s.reduce)
        if kind in ("reduce_scatter", "all_reduce") and n_reduce != p - 1:
            diags.append(diag(
                "fab.chain-broken",
                f"{kind}: chunk {chunk} dir {direction} reduced over "
                f"{n_reduce} hop(s), expected {p - 1}",
                subject=kind, uid=chunk))

    if kind == "all_gather":
        # chunk c starts on chip c; every chip must end up possessing it
        possession = {i: {i} for i in range(p)}
        by_step: dict[int, list] = {}
        for st in steps:
            by_step.setdefault(st.step, []).append(st)
        for s in sorted(by_step):
            received = []
            for st in by_step[s]:
                if st.chunk not in possession.get(st.src, set()):
                    diags.append(diag(
                        "fab.unreachable",
                        f"{kind}: step {s} sends chunk {st.chunk} from chip "
                        f"{st.src}, which never received it",
                        subject=kind, uid=s))
                received.append((st.dst, st.chunk))
            for dst, chunk in received:
                possession.setdefault(dst, set()).add(chunk)
        missing = [(i, c) for i in range(p) for c in range(p)
                   if c not in possession.get(i, set())]
        for i, c in missing:
            diags.append(diag(
                "fab.unreachable",
                f"{kind}: chip {i} never receives chunk {c}",
                subject=kind, uid=c))
    return diags


def verify_partition(pp) -> list[Diagnostic]:
    """Sharded-output contract of a ``PartitionedProgram``."""
    diags: list[Diagnostic] = []
    base = pp.base
    out = pp.output
    out_shape = base.buffer(out).shape

    chips = sorted(s.chip for s in pp.shards)
    if chips != list(range(pp.n_chips)):
        diags.append(diag(
            "fab.contract",
            f"shard chips {chips} are not exactly 0..{pp.n_chips - 1}",
            subject=pp.axis))
        return diags

    if pp.out_mode == "chain_sum":
        for s in pp.shards:
            got = s.program.buffer(out).shape
            if got != out_shape:
                diags.append(diag(
                    "fab.contract",
                    f"chain_sum shard {s.chip}: partial output shape {got} "
                    f"!= global {out_shape}", subject=out, uid=s.chip))
    else:
        total = 0
        for s in sorted(pp.shards, key=lambda s: s.chip):
            shp = s.program.buffer(out).shape
            total += shp[pp.out_axis]
            for d, (a, b) in enumerate(zip(shp, out_shape)):
                if d != pp.out_axis and a != b:
                    diags.append(diag(
                        "fab.contract",
                        f"concat shard {s.chip}: output dim {d} is {a}, "
                        f"global is {b}", subject=out, uid=s.chip))
        if total != out_shape[pp.out_axis]:
            diags.append(diag(
                "fab.contract",
                f"concat shards cover {total} of output axis "
                f"{pp.out_axis} extent {out_shape[pp.out_axis]}",
                subject=out))

    for spec in pp.collectives:
        ext = base.buffer(spec.buffer).shape[spec.axis]
        off = 0
        for i, (o, ln) in enumerate(spec.chunks):
            if o != off or ln <= 0:
                diags.append(diag(
                    "fab.contract",
                    f"{spec.kind} on {spec.buffer}: chunk {i} is "
                    f"({o}, {ln}), expected contiguous from {off}",
                    subject=spec.buffer, uid=i))
                break
            off += ln
        else:
            if off != ext:
                diags.append(diag(
                    "fab.contract",
                    f"{spec.kind} on {spec.buffer}: chunks cover {off} of "
                    f"axis {spec.axis} extent {ext}", subject=spec.buffer))
        if len(spec.chunks) != pp.n_chips:
            diags.append(diag(
                "fab.contract",
                f"{spec.kind} on {spec.buffer}: {len(spec.chunks)} chunks "
                f"for {pp.n_chips} chips", subject=spec.buffer))
    return diags


def verify_fabric(pp, topo, approach=None, algorithm: str = "ring",
                  chip_graph=None) -> list[Diagnostic]:
    """Full distributed check: partition contract, lowered collectives,
    the assembled ``EventSim`` task graph, and every distinct per-chip
    compile through the program/selection/schedule layers."""
    from ..compile import compile_selection
    from ..fabric.simulate import _lower, simulate_partition
    from .program import verify_program
    from .schedule import verify_schedule
    from .selection import verify_selection

    diags = verify_partition(pp)
    for spec in pp.collectives:
        steps = _lower(spec, pp, topo, algorithm)
        diags.extend(verify_collective(spec.kind, steps, topo.n_chips))

    sim_out: list = []
    simulate_partition(pp, topo, approach, algorithm, chip_graph,
                       sim_out=sim_out)
    for sim in sim_out:
        diags.extend(verify_task_graph(sim))

    seen: set[str] = set()
    for shard in pp.shards:
        sig = shard.program.signature()
        if sig in seen:
            continue
        seen.add(sig)
        sel = pp.shard_selection(shard)
        art = compile_selection(sel, chip_graph or _default_chip_graph(),
                                approach)
        diags.extend(verify_program(sel.program))
        diags.extend(verify_selection(sel, approach))
        diags.extend(verify_schedule(art.schedule, approach))
    return diags


def _default_chip_graph():
    from ..fabric.topology import Topology
    return Topology.chip_graph()
