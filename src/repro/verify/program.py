"""Layer 1 — ISAMIR program legality (``prg.*`` rules).

Checks a ``core.ir.Program`` statically: access ranks and matrix widths,
affine in-bounds under the axis extents, temps written before read, declared
outputs actually written, dtypes known to ``core/dtypes.py``.

``Program.__post_init__`` raises ``IRError`` on some of these at construction
time; the verifier re-checks them because mutated/deserialized programs
bypass the constructor (``object.__setattr__``, pickles, cache payloads) —
and because a Diagnostic with a rule id is more useful than a bare exception.
"""
from __future__ import annotations

from ..core.dtypes import DTYPE_BYTES
from ..core.ir import Access, Program
from .diagnostics import Diagnostic, diag


def _access_extremes(acc: Access, extents: list[int]) -> list[tuple[int, int]]:
    """Per-dim (min, max) index of an affine access over the axis domain."""
    out = []
    for row, off in zip(acc.matrix, acc.offset):
        lo = hi = off
        for coeff, ext in zip(row, extents):
            if coeff == 0 or ext <= 0:      # ext 0 = symbolic axis: skip
                continue
            span = coeff * (ext - 1)
            if span > 0:
                hi += span
            else:
                lo += span
        out.append((lo, hi))
    return out


def verify_program(prog: Program) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    bufs = {b.name: b for b in prog.buffers}
    extents = [a.size for a in prog.axes]
    ncols = len(prog.axes)

    for b in prog.buffers:
        if b.dtype not in DTYPE_BYTES:
            diags.append(diag(
                "prg.dtype", f"buffer {b.name!r} has unknown dtype "
                f"{b.dtype!r} (not in core/dtypes.py)", subject=b.name))

    written: set[str] = set()
    for i, s in enumerate(prog.statements):
        for side, acc in (("lhs", s.lhs), ("rhs", s.rhs)):
            b = bufs.get(acc.buffer)
            if b is None:
                diags.append(diag(
                    "prg.unknown-buffer",
                    f"stmt {i} {side} accesses unknown buffer "
                    f"{acc.buffer!r}", subject=acc.buffer, uid=i))
                continue
            if acc.rank != b.rank:
                diags.append(diag(
                    "prg.rank",
                    f"stmt {i} {side}: access rank {acc.rank} != buffer "
                    f"{b.name!r} rank {b.rank}", subject=b.name, uid=i))
                continue
            bad_width = [len(row) for row in acc.matrix if len(row) != ncols]
            if bad_width:
                diags.append(diag(
                    "prg.axis",
                    f"stmt {i} {side}: access matrix row width "
                    f"{bad_width[0]} != {ncols} declared axes",
                    subject=b.name, uid=i))
                continue
            for d, (lo, hi) in enumerate(_access_extremes(acc, extents)):
                if lo < 0 or hi >= b.shape[d]:
                    diags.append(diag(
                        "prg.bounds",
                        f"stmt {i} {side}: dim {d} of {b.name!r} spans "
                        f"[{lo}, {hi}] outside [0, {b.shape[d] - 1}]",
                        subject=b.name, uid=i))
        # temps must be written before read (non-temps are inputs, implicitly
        # zero/user-initialized; temps are pure scratch).  An accumulating
        # op's *own* lhs is exempt: ``T += ...`` as the first write is the
        # idiomatic zero-init (``interpret`` zero-fills missing buffers).
        try:
            stmt_reads = prog.reads(s)
        except KeyError:
            stmt_reads = ()
        for r in stmt_reads:
            if r == s.lhs.buffer:
                continue
            b = bufs.get(r)
            if b is not None and b.temp and r not in written:
                diags.append(diag(
                    "prg.temp-read",
                    f"stmt {i} reads temp {r!r} before any write",
                    subject=r, uid=i))
        written.add(s.lhs.buffer)

    for name in prog.outputs:
        if name not in bufs:
            diags.append(diag(
                "prg.unknown-buffer",
                f"declared output {name!r} is not a buffer", subject=name))
        elif name not in written:
            diags.append(diag(
                "prg.output-unwritten",
                f"output {name!r} is never written", subject=name))
    return diags
