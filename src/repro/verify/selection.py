"""Layer 2 — instruction-selection legality (``sel.*`` rules).

A ``Selection`` must cover every haystack statement exactly once, its
``axis_map``/``buffer_map`` bindings must be injective over axes/buffers
that exist on both sides (the PR-4 role-keyed tile-plan fix showed role
confusion is a live bug class), and the approach's tiling knobs
(``tile_caps``, ``vmem_frac``) must be sane against the axis extents.
"""
from __future__ import annotations

from ..core.ir import Program
from ..core.isel import Selection
from .diagnostics import Diagnostic, diag


def verify_selection(sel: Selection, approach=None) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    prog: Program = sel.program
    n_stmts = len(prog.statements)
    axis_names = set(prog.axis_names)
    buf_names = {b.name for b in prog.buffers}

    # -- statement coverage: exactly once -----------------------------------
    cover: dict[int, int] = {}
    for si in sel.instrs:
        for hi in si.mapping.stmt_map:
            if hi < 0 or hi >= n_stmts:
                diags.append(diag(
                    "sel.coverage-gap",
                    f"{si.needle.name} covers statement index {hi} outside "
                    f"program range [0, {n_stmts - 1}]",
                    subject=si.needle.name, uid=hi))
                continue
            cover[hi] = cover.get(hi, 0) + 1
    for hi in range(n_stmts):
        n = cover.get(hi, 0)
        if n == 0 and hi not in sel.uncovered:
            diags.append(diag(
                "sel.coverage-gap",
                f"statement {hi} is covered by no instruction and not "
                f"declared uncovered", uid=hi))
        elif n > 1:
            diags.append(diag(
                "sel.coverage-overlap",
                f"statement {hi} is covered by {n} instructions", uid=hi))
    for hi in sel.uncovered:
        if cover.get(hi):
            diags.append(diag(
                "sel.coverage-overlap",
                f"statement {hi} is declared uncovered but covered by "
                f"{cover[hi]} instruction(s)", uid=hi))

    # -- per-instruction mapping consistency --------------------------------
    for idx, si in enumerate(sel.instrs):
        m = si.mapping
        needle_axes = {a.name for a in si.needle.axes}
        needle_bufs = {b.name for b in si.needle.buffers}
        seen_n: set[str] = set()
        seen_h: set[str] = set()
        for na, ha in m.axis_map:
            if na not in needle_axes:
                diags.append(diag(
                    "sel.axis-role",
                    f"instr {idx} ({si.needle.name}): axis_map binds "
                    f"unknown needle axis {na!r}",
                    subject=si.needle.name, uid=idx))
            if ha not in axis_names:
                diags.append(diag(
                    "sel.axis-role",
                    f"instr {idx} ({si.needle.name}): axis_map binds "
                    f"needle axis {na!r} to unknown haystack axis {ha!r}",
                    subject=si.needle.name, uid=idx))
            if na in seen_n or ha in seen_h:
                diags.append(diag(
                    "sel.axis-role",
                    f"instr {idx} ({si.needle.name}): axis_map is not "
                    f"injective at ({na!r} -> {ha!r})",
                    subject=si.needle.name, uid=idx))
            seen_n.add(na)
            seen_h.add(ha)
        for ha in m.outer_axes:
            if ha not in axis_names:
                diags.append(diag(
                    "sel.axis-role",
                    f"instr {idx} ({si.needle.name}): outer axis {ha!r} "
                    f"is not a program axis",
                    subject=si.needle.name, uid=idx))
            elif ha in seen_h:
                diags.append(diag(
                    "sel.axis-role",
                    f"instr {idx} ({si.needle.name}): axis {ha!r} is both "
                    f"mapped and outer", subject=si.needle.name, uid=idx))
        seen_hb: set[str] = set()
        for nb, hb in m.buffer_map:
            if nb not in needle_bufs:
                diags.append(diag(
                    "sel.buffer-map",
                    f"instr {idx} ({si.needle.name}): buffer_map binds "
                    f"unknown needle buffer {nb!r}",
                    subject=si.needle.name, uid=idx))
            if hb not in buf_names:
                diags.append(diag(
                    "sel.buffer-map",
                    f"instr {idx} ({si.needle.name}): buffer_map binds "
                    f"{nb!r} to unknown haystack buffer {hb!r}",
                    subject=si.needle.name, uid=idx))
            if hb in seen_hb:
                diags.append(diag(
                    "sel.buffer-map",
                    f"instr {idx} ({si.needle.name}): buffer_map is not "
                    f"injective at haystack buffer {hb!r}",
                    subject=si.needle.name, uid=idx))
            seen_hb.add(hb)

    # -- approach tiling knobs ----------------------------------------------
    if approach is not None:
        caps = getattr(approach, "tile_caps", None) or ()
        for role, cap in zip("ijk", caps):
            if cap is not None and (not isinstance(cap, int) or cap < 1):
                diags.append(diag(
                    "sel.tile-cap",
                    f"tile cap for role {role!r} is {cap!r}; must be a "
                    f"positive int or None", subject=role))
        frac = getattr(approach, "vmem_frac", 1.0)
        if not (0.0 < frac <= 1.0):
            diags.append(diag(
                "sel.tile-cap",
                f"vmem_frac {frac!r} outside (0, 1]", subject="vmem_frac"))
    return diags
