"""``repro.verify`` — a pass-structured static analyzer for every artifact
the stack produces.

Four layers, each emitting structured ``Diagnostic`` records (rule id,
severity, offending op/statement, message) instead of bare exceptions:

  1. **program**   (``prg.*``) — ISAMIR legality on ``core.ir`` Programs
  2. **selection** (``sel.*``) — exact statement coverage, axis/buffer-map
     role consistency, tiling-knob sanity
  3. **schedule**  (``sch.*``) — symbolic replay of ``Schedule.ops`` over
     versioned regions: RAW/WAR/WAW hazards, capacity, residency
  4. **fabric**    (``fab.*``) — collective/task-graph acyclicity and the
     sharded-output partition contract
  5. **graph**     (``gra.*``) — ``repro.graph`` kernel-graph wiring,
     topology, per-node program health, and placement capacity
  6. **serve**     (``srv.*``) — ``repro.serve`` run traces: KV-aware
     admission, bucket routing, frozen-replay fidelity, liveness

plus structural checks on cached artifact payloads (``art.*``).

``verify_compile`` is the strict pipeline entry (``VerifyPass``);
``verify_artifact`` checks a live ``CompiledKernel``; the mutation harness
(``repro.verify.mutate``) proves each rule actually fires.
"""
from __future__ import annotations

from .artifact import verify_artifact_dict
from .diagnostics import (ERROR, RULES, WARNING, Diagnostic,
                          DiagnosticReport, VerifyError, diag)
from .fabric import (verify_collective, verify_fabric, verify_partition,
                     verify_task_graph)
from .graph import verify_graph, verify_placement
from .program import verify_program
from .schedule import verify_schedule
from .selection import verify_selection
from .serve import verify_replay, verify_serve_trace

__all__ = [
    "Diagnostic", "DiagnosticReport", "VerifyError", "RULES", "ERROR",
    "WARNING", "diag", "verify_program", "verify_selection",
    "verify_schedule", "verify_collective", "verify_partition",
    "verify_task_graph", "verify_fabric", "verify_artifact_dict",
    "verify_graph", "verify_placement", "verify_serve_trace",
    "verify_replay", "verify_compile", "verify_artifact",
]


def verify_compile(program=None, selection=None, schedule=None,
                   approach=None) -> DiagnosticReport:
    """Check whatever stages a compile has produced so far.  ``program``
    defaults to ``selection.program`` (the possibly-transformed haystack
    the later stages actually consume)."""
    report = DiagnosticReport()
    if program is None and selection is not None:
        program = selection.program
    if program is not None:
        report.extend(verify_program(program))
    if selection is not None:
        report.extend(verify_selection(selection, approach))
    if schedule is not None:
        report.extend(verify_schedule(schedule, approach))
    return report


def verify_artifact(art, approach=None) -> DiagnosticReport:
    """Check a ``CompiledKernel``: its serialized payload plus — when the
    live selection/schedule are attached — the full static stack."""
    report = DiagnosticReport(meta={"key": getattr(art, "key", "")})
    report.extend(verify_artifact_dict(art.to_dict()))
    sel = getattr(art, "selection", None)
    sched = getattr(art, "schedule", None)
    if sel is not None or sched is not None:
        report.extend(verify_compile(
            selection=sel, schedule=sched,
            approach=approach if approach is not None
            else getattr(art, "approach", None)).diagnostics)
    return report
